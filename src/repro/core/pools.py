"""Device pools — the hybrid-cloud substrate made first-class.

The paper's platform spans two very different places to compute: the
on-premises Hadoop/HDFS estate and the cloud (GCP) side — one graph
snapshot may be *resident* in either, both, or neither, and moving it
costs real wall-clock (their FlockDB→HDFS→GCS copies are the dominant
term for cold queries).  Until now this repo planned over
(engine, variant) on one implicit device pool; this module names the
pools so every other layer can plan and execute over them:

* :class:`DevicePool` — a named subset of the process' jax devices
  ("onprem" / "cloud") with the attributes the planner and the service
  runtime price and enforce: cross-pool ``link_bandwidth`` (the
  byte-rate a non-resident snapshot pays to materialize here),
  ``compute_scale`` (relative compute cost — a cloud pool of faster or
  more numerous chips advertises ``< 1.0``), ``capacity`` (queued
  batch-tier tickets before the service spills work to another
  resident pool), ``max_inflight`` (concurrent executions the runtime
  admits onto the pool) and a mutable ``healthy`` flag.
* :class:`PoolSet` — an ordered, named collection with a **generation
  counter**: flipping a pool's health bumps it, and every plan cache
  keys on it, so a cached Plan that placed work onto a now-unhealthy
  pool is re-costed instead of replayed (the residency analogue lives
  in ``GraphContext``).
* :func:`default_pools` — the two-pool development topology: the
  process' devices partitioned into an "onprem" and a "cloud" half
  (on a one-device host both halves alias the same device — the pools
  stay *logically* distinct, and the result contract makes that
  invisible: per-ticket bytes are identical wherever they run).

Results never depend on the pool: a pool changes *where* state lives
and *what the plan costs*, never what the query returns — the same
contract engines and variants already obey.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Optional, Sequence

#: Default cross-pool link byte-rate: a 100 Gb/s private interconnect —
#: the order of magnitude of the paper's on-prem<->GCP link, and far
#: below HBM bandwidth, which is what makes residency matter.
DEFAULT_LINK_BANDWIDTH = 12.5e9


@dataclasses.dataclass(eq=False)
class DevicePool:
    """One named execution substrate.

    ``devices`` are the jax devices the pool owns (empty = the process
    default — a purely logical pool).  ``n_chips`` feeds the
    distributed-engine estimate (``None`` falls back to the graph
    context's configured chip count, which keeps a single-pool service
    bit-compatible with the pre-pool planner).  ``healthy`` is the one
    mutable operational field; flip it through
    :meth:`PoolSet.set_health` so plan caches see the generation bump.
    """

    name: str
    devices: tuple = ()
    n_chips: Optional[int] = None
    link_bandwidth: float = DEFAULT_LINK_BANDWIDTH
    compute_scale: float = 1.0
    capacity: Optional[int] = None
    max_inflight: Optional[int] = None
    healthy: bool = True

    def __post_init__(self):
        self.devices = tuple(self.devices or ())
        if not self.name:
            raise ValueError("pool name must be non-empty")
        if self.link_bandwidth <= 0:
            raise ValueError(
                f"pool {self.name!r}: link_bandwidth must be > 0")
        if self.compute_scale <= 0:
            raise ValueError(
                f"pool {self.name!r}: compute_scale must be > 0")
        if self.capacity is not None and self.capacity < 0:
            raise ValueError(f"pool {self.name!r}: capacity must be >= 0")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError(
                f"pool {self.name!r}: max_inflight must be >= 1")
        if self.n_chips is None and self.devices:
            self.n_chips = len(self.devices)

    def transfer_seconds(self, n_bytes: int) -> float:
        """Wall-clock to materialize ``n_bytes`` of non-resident graph
        onto this pool — the data-locality term of the cost model."""
        return float(n_bytes) / self.link_bandwidth


class PoolSet:
    """Ordered named pools plus the health generation counter.

    The order is the planner's tie-break (earlier pools win equal-cost
    plans) and the runtime's scan order, so a fixed construction order
    keeps scheduling deterministic.
    """

    def __init__(self, pools: Sequence[DevicePool]):
        pools = list(pools)
        if not pools:
            raise ValueError("PoolSet needs at least one pool")
        names = [p.name for p in pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {sorted(names)}")
        self._pools = {p.name: p for p in pools}
        self._order = tuple(names)
        self._generation = 0
        self._lock = threading.Lock()

    # -- access -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._order)

    def __iter__(self):
        return iter(self.pools())

    def __contains__(self, name: str) -> bool:
        return name in self._pools

    def names(self) -> tuple:
        return self._order

    def pools(self) -> tuple:
        return tuple(self._pools[n] for n in self._order)

    def get(self, name: str) -> DevicePool:
        try:
            return self._pools[name]
        except KeyError:
            raise KeyError(f"unknown pool {name!r}; pools: "
                           f"{list(self._order)}") from None

    @property
    def default(self) -> DevicePool:
        """The first pool — where a poolset-free caller's work lands."""
        return self._pools[self._order[0]]

    @property
    def trivial(self) -> bool:
        """One pool, unit compute scale — the configuration whose plans
        must match the pre-pool planner exactly."""
        if len(self._order) != 1:
            return False
        p = self.default
        return p.compute_scale == 1.0 and p.healthy

    def healthy_pools(self) -> tuple:
        return tuple(p for p in self.pools() if p.healthy)

    def validate_names(self, names: Iterable[str]) -> tuple:
        out = tuple(names)
        for n in out:
            self.get(n)
        return out

    # -- health -------------------------------------------------------------
    @property
    def generation(self) -> int:
        """Monotone counter of health flips — plan caches key on it."""
        with self._lock:
            return self._generation

    def set_health(self, name: str, healthy: bool) -> DevicePool:
        """Flip one pool's health; a real change bumps the generation so
        cached plans that referenced the pool are re-costed."""
        pool = self.get(name)
        with self._lock:
            if pool.healthy != bool(healthy):
                pool.healthy = bool(healthy)
                self._generation += 1
        return pool


def default_pools(*, link_bandwidth: float = DEFAULT_LINK_BANDWIDTH,
                  cloud_compute_scale: float = 1.0,
                  capacity: Optional[int] = None,
                  devices: Optional[Sequence] = None) -> PoolSet:
    """The development two-pool topology: the process' devices split
    into an "onprem" first half and a "cloud" second half.

    On a one-device host both pools alias that device — still useful:
    placement, residency, spill and the transfer ledger are all
    observable, and the result contract makes the aliasing invisible.
    ``devices`` overrides discovery (e.g. a partitioned CPU device list
    from ``--xla_force_host_platform_device_count``).
    """
    if devices is None:
        import jax
        devices = jax.devices()
    devices = tuple(devices)
    half = max(len(devices) // 2, 1)
    onprem = devices[:half] or devices
    cloud = devices[half:] or devices
    return PoolSet([
        DevicePool("onprem", devices=onprem,
                   link_bandwidth=link_bandwidth, capacity=capacity),
        DevicePool("cloud", devices=cloud, link_bandwidth=link_bandwidth,
                   compute_scale=cloud_compute_scale, capacity=capacity),
    ])


def single_pool(name: str = "default", **kw) -> PoolSet:
    """A one-pool PoolSet — what a service without an explicit topology
    runs on; its plans are bit-compatible with the pre-pool planner."""
    return PoolSet([DevicePool(name, **kw)])
