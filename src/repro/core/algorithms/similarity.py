"""Node similarity (common-neighbors / Jaccard) on the ELL layout.

The paper lists "node similarity" and "topic similarity" among the jobs
teams kept re-implementing.  On the ELL layout a similarity query for a
batch of (u, v) pairs is two row gathers and one masked intersection —
O(K^2) per pair with K = MaxAdjacentNodes, fully vectorized.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R


@partial(jax.jit, static_argnames=())
def _row_intersection_counts(nbr_u, mask_u, nbr_v, mask_v):
    """[B, K] rows -> |N(u) ∩ N(v)| per batch element."""
    eq = (nbr_u[:, :, None] == nbr_v[:, None, :])
    eq &= mask_u[:, :, None] & mask_v[:, None, :]
    return jnp.sum(eq, axis=(1, 2))


def common_neighbors(ell: G.GraphELL, u: jax.Array, v: jax.Array):
    """Common-neighbor counts for pairs (u[i], v[i])."""
    return _row_intersection_counts(
        ell.nbr[u], ell.mask[u], ell.nbr[v], ell.mask[v])


def jaccard_similarity(ell: G.GraphELL, u: jax.Array, v: jax.Array):
    """|N(u) ∩ N(v)| / |N(u) ∪ N(v)| for pairs (u[i], v[i])."""
    inter = common_neighbors(ell, u, v).astype(jnp.float32)
    du = jnp.sum(ell.mask[u], axis=1).astype(jnp.float32)
    dv = jnp.sum(ell.mask[v], axis=1).astype(jnp.float32)
    union = du + dv - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


# ------------------------------------------------------------ registration

def _vertex_batch(x):
    return tuple(int(i) for i in np.atleast_1d(np.asarray(x)))


def _engine_run(eng, u, v):
    return jaccard_similarity(eng.ell, jnp.asarray(u, jnp.int32),
                              jnp.asarray(v, jnp.int32)), None


def _batch_run(eng, params_list):
    """Fused batch path: K pair-batches concatenated into one gather +
    intersection kernel call, slices scattered back per query.  Each
    row's arithmetic is independent, so every slice is bit-identical to
    running its query alone."""
    u_all = np.concatenate(
        [np.asarray(p["u"], np.int64) for p in params_list])
    v_all = np.concatenate(
        [np.asarray(p["v"], np.int64) for p in params_list])
    sims = jaccard_similarity(eng.ell, jnp.asarray(u_all, jnp.int32),
                              jnp.asarray(v_all, jnp.int32))
    values, off = [], 0
    for p in params_list:
        n = len(p["u"])
        values.append(sims[off: off + n])
        off += n
    return values, None, {"pregel_calls": 0, "kernel_calls": 1}


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    rows = len(params.get("u") or (1,))
    return P.QuerySpec("jaccard", rows, iterations=1, row_bytes=4)


R.register(R.AlgorithmDef(
    name="jaccard",
    run=_engine_run,
    params=(
        R.Param("u", R.REQUIRED, normalize=_vertex_batch),
        R.Param("v", R.REQUIRED, normalize=_vertex_batch),
    ),
    cost=_cost,
    batch_runner=_batch_run,
    fuse=lambda params: (),      # any two pair-batches may share a call
    # the batched ELL-row intersection is an interactive single-device
    # workload — the capability flag keeps the planner honest about it
    engines=("local",),
    example_params={"u": (0, 1), "v": (1, 2)},
    doc="Jaccard similarity for (u[i], v[i]) vertex pairs on ELL rows.",
))
