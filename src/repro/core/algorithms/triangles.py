"""Cohesion workloads: triangle counting and k-core degree-peeling.

**Triangle counting** needs neighborhood *intersection*, which a scalar
message cannot carry.  We use the pregel engine's N-D vertex state and
edge-program messages: vertex state is a packed neighborhood bitset
(``ceil(V/32)`` uint32 words, plus one count word), built in one
superstep (sum of deduped one-hot rows == bitwise OR) and intersected in
a second superstep where each edge reads *both* endpoint states:

    superstep 1:  state[v] <- OR_{(u,v) in E} onehot(u)       (adjacency)
    superstep 2:  count[v] <- sum_{(u,v) in E} popcount(N(u) & N(v))

On the symmetrized graph every triangle is counted six times (three
undirected edges, two directions each), so ``total // 6`` is exact.
Memory is O(V^2/32) bits of state and O(E * V/32) gather traffic — the
quadratic term the planner charges via ``state_bytes_per_vertex``, which
pushes large-V triangle queries onto the distributed engine (and keeps
the local engine for the small-graph interactive regime, Fig. 5 style).

**k-core** is the classic peeling fixpoint as a scalar vertex program:
vertices stay alive while their alive-degree is >= k; one XLA while-loop
runs peeling to convergence on either engine.

Both require a symmetrized graph (``build_coo(..., symmetrize=True)``,
enforced via the ``GraphCOO.symmetric`` flag) — on a directed edge list
they would run fine but return silently wrong answers.  Self-loops are
tolerated: triangle counting clears each vertex's own bit from its
neighborhood bitset, and k-core counts a self-loop once toward degree.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, converged_halt, run_pregel


def _n_words(n_vertices: int) -> int:
    return -(-n_vertices // 32)


# agg = summed one-hot rows of in-neighbors == their OR (edges are
# deduped so no bit is added twice); count word arrives as 0.
_ADJACENCY_SPEC = PregelSpec(
    message=lambda s, w: s,
    combine="sum",
    apply=lambda old, agg, ids, gval: agg.astype(jnp.uint32),
    identity=0)


@lru_cache(maxsize=None)
def _intersect_spec(n_words: int) -> PregelSpec:
    W = n_words

    def message(src_state, w, dst_state):
        sb, db = src_state[:, :W], dst_state[:, :W]
        common = jnp.sum(jnp.bitwise_count(sb & db).astype(jnp.uint32),
                         axis=-1)
        # a self-loop edge intersects N(v) with itself (|N(v)|, not a
        # triangle count).  With own bits cleared, adjacent *distinct*
        # vertices always differ in their bitsets (v is in N(u) but not
        # in N(v)), so bitset equality identifies exactly the loops.
        is_loop = jnp.all(sb == db, axis=-1)
        return jnp.where(is_loop, jnp.uint32(0), common)

    def apply(old, agg, ids, gval):
        return jnp.concatenate(
            [old[:, :W], agg[:, None].astype(jnp.uint32)], axis=-1)

    return PregelSpec(
        message=message, combine="sum", apply=apply, identity=0,
        needs_dst_state=True)


def triangle_count(
    g: G.GraphCOO,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``(n_triangles, per_vertex_pair_counts [V] — popcount sums
    per destination, each triangle contributing 6 across the graph)``.
    """
    G.require_symmetric(g, "triangle_count")
    V = g.n_vertices
    W = _n_words(V)
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    # own-bit bitset rows; the trailing word accumulates the pair counts
    init = np.zeros((sharded.n_pad, W + 1), dtype=np.uint32)
    ids = np.arange(V, dtype=np.int64)
    own_bits = np.uint32(1) << (ids % 32).astype(np.uint32)
    init[ids, ids // 32] = own_bits

    bitsets, _ = run_pregel(_ADJACENCY_SPEC, sharded, jnp.asarray(init),
                            max_iters=1, mesh=mesh)
    # self-loops would put v's own bit in N(v) and inflate every
    # intersection along v's edges — clear it unconditionally
    bitsets = bitsets.at[jnp.asarray(ids), jnp.asarray(ids // 32)].set(
        bitsets[jnp.asarray(ids), jnp.asarray(ids // 32)]
        & ~jnp.asarray(own_bits))
    counted, _ = run_pregel(_intersect_spec(W), sharded, bitsets,
                            max_iters=1, mesh=mesh)
    per_vertex = np.asarray(counted[:V, W]).astype(np.int64)
    return int(per_vertex.sum()) // 6, per_vertex


# ------------------------------------------------------------------- k-core

@lru_cache(maxsize=None)
def _kcore_spec(k: int) -> PregelSpec:
    def apply(alive, deg, ids, gval):
        # peeling is monotone: once dropped, never resurrected
        return jnp.where(alive > 0.5, (deg >= k).astype(jnp.float32), 0.0)

    return PregelSpec(
        message=lambda alive, w: alive,
        combine="sum", apply=apply, identity=0.0,
        halt=converged_halt)


def k_core(
    g: G.GraphCOO,
    k: int,
    max_iters: Optional[int] = None,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``(in_core [V] bool, iters)`` — membership in the maximal
    subgraph where every vertex has degree >= k (a self-loop counts once
    toward its vertex's degree).  ``max_iters=None`` (default) guarantees
    the peeling reaches its fixpoint (at most V rounds; the halt check
    exits far earlier in practice)."""
    G.require_symmetric(g, "k_core")
    V = g.n_vertices
    if max_iters is None:
        max_iters = V
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    init = jnp.ones(sharded.n_pad, jnp.float32)
    alive, iters = run_pregel(_kcore_spec(int(k)), sharded, init,
                              max_iters, mesh=mesh)
    return alive[:V] > 0.5, iters


def core_size(in_core) -> int:
    """Count-only fast path: |k-core| without materializing membership."""
    return int(jnp.sum(in_core))


# ------------------------------------------------------------ registration

def _tri_run(eng):
    count, _per_vertex = triangle_count(eng.coo, mesh=eng.mesh,
                                        sharded=eng.sharded)
    return count, 2


def _tri_cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    # two supersteps over neighborhood bitsets of ceil(V/32) words
    word_bytes = 4.0 * max(g.n_vertices // 32, 1)
    return P.QuerySpec("triangle_count", 1, iterations=2,
                       state_bytes_per_vertex=word_bytes,
                       edge_bytes_factor=max(2 * word_bytes / 12, 1.0))


R.register(R.AlgorithmDef(
    name="triangle_count",
    run=_tri_run,
    cost=_tri_cost,
    requires_symmetric=True,
    doc="Global triangle count via bitset neighborhood intersection.",
))


def _kcore_run(eng, k, max_iters):
    return k_core(eng.coo, k, max_iters=max_iters, mesh=eng.mesh,
                  sharded=eng.sharded)


def _kcore_cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    iters = min(10, params.get("max_iters") or 10)
    return P.QuerySpec("k_core", 1 if count_only else g.n_vertices,
                       iterations=iters, state_bytes_per_vertex=4.0)


R.register(R.AlgorithmDef(
    name="k_core",
    run=_kcore_run,
    params=(
        R.Param("k", R.REQUIRED, check=lambda k: k >= 1, normalize=int),
        R.Param("max_iters", None, check=lambda n: n >= 1, normalize=int),
    ),
    count=core_size,
    count_method="k_core_size",
    cost=_kcore_cost,
    requires_symmetric=True,
    example_params={"k": 3},
    doc="k-core membership via degree peeling to fixpoint.",
))


# ---------------------------------------------------------------- oracles

def triangle_count_reference(src, dst, n_vertices: int) -> int:
    """Dense-matmul oracle: trace(A^3) / 6 on the symmetrized 0/1
    adjacency (small graphs only)."""
    a = np.zeros((n_vertices, n_vertices), dtype=np.int64)
    s = np.asarray(src)
    d = np.asarray(dst)
    a[s, d] = 1
    a[d, s] = 1
    np.fill_diagonal(a, 0)
    return int(np.trace(a @ a @ a)) // 6


def k_core_reference(src, dst, n_vertices: int, k: int) -> np.ndarray:
    """Iterative peeling oracle on the symmetrized edge list."""
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    alive = np.ones(n_vertices, dtype=bool)
    while True:
        keep = alive[s] & alive[d]
        deg = np.bincount(d[keep], minlength=n_vertices)
        drop = alive & (deg < k)
        if not drop.any():
            return alive
        alive[drop] = False
