"""Algorithm-suite tests: every new vertex program vs its pure-numpy
oracle on random graphs, identical results on LocalEngine and
DistributedEngine, count-only fast paths, and the structured-message
pregel machinery itself.  Real multi-device mesh coverage runs in a
subprocess (XLA device flags must precede jax init).
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.partition import partition_1d
from repro.core.pregel import PregelSpec, run_pregel
from repro.core.query import GraphPlatform, GraphQuery
from repro.core.algorithms.traversal import (
    bfs_distances, bfs_reference, reachable_count, sssp, sssp_reference)
from repro.core.algorithms.community import (
    communities_reference, label_propagation, num_communities)
from repro.core.algorithms.triangles import (
    core_size, k_core, k_core_reference, triangle_count,
    triangle_count_reference)
from repro.data import synthetic as S


def _edges(g):
    return (np.asarray(g.src)[: g.n_edges], np.asarray(g.dst)[: g.n_edges],
            np.asarray(g.w)[: g.n_edges])


@pytest.fixture(scope="module")
def digraph():
    src, dst = S.user_follow_graph(600, 4.0, seed=13)
    return G.build_coo(src, dst, 600)


@pytest.fixture(scope="module")
def sym_graph():
    src, dst = S.user_follow_graph(600, 4.0, seed=13)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], 600, symmetrize=True)


# ------------------------------------------------------------- oracles

def test_bfs_matches_queue_oracle(digraph):
    s, d, _ = _edges(digraph)
    for sources in ([0], [1, 17, 200]):
        dist, _ = bfs_distances(digraph, sources)
        ref = bfs_reference(s, d, digraph.n_vertices, sources)
        np.testing.assert_array_equal(np.asarray(dist), ref)


def test_bfs_converges_past_default_small_world_depth():
    """A 200-vertex path graph needs 199 relaxation rounds — the default
    max_iters=None must reach the fixpoint instead of truncating the
    tail of the distance table to inf."""
    n = 200
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    g = G.build_coo(src, dst, n)
    dist, iters = bfs_distances(g, [0])
    np.testing.assert_array_equal(np.asarray(dist), np.arange(n, dtype=np.float32))
    assert reachable_count(dist) == n
    # explicit truncation is opt-in and documented
    dist_t, _ = bfs_distances(g, [0], max_iters=10)
    assert reachable_count(dist_t) == 11


def test_bfs_reachable_count(digraph):
    dist, _ = bfs_distances(digraph, [0])
    assert reachable_count(dist) == int(np.isfinite(np.asarray(dist)).sum())


def test_sssp_matches_dijkstra():
    rng = np.random.default_rng(4)
    src, dst = S.user_follow_graph(500, 4.0, seed=21)
    w = rng.random(src.shape[0]).astype(np.float32) + 0.05
    g = G.build_coo(src, dst, 500, w=w)
    s, d, ww = _edges(g)
    dist, _ = sssp(g, 7)
    ref = sssp_reference(s, d, ww, 500, 7)
    np.testing.assert_allclose(np.asarray(dist), ref, atol=1e-5)


def test_label_propagation_on_disjoint_cliques():
    """Ground-truth communities = connected components (disjoint
    cliques): LPA must produce exactly one label per clique, matching
    the union-find oracle's partition."""
    es, ed, off = [], [], 0
    for size in [5, 9, 2, 14, 3, 7]:
        a, b = np.triu_indices(size, k=1)
        es.append(a + off)
        ed.append(b + off)
        off += size
    es, ed = np.concatenate(es), np.concatenate(ed)
    g = G.build_coo(es, ed, off, symmetrize=True)
    labels, _ = label_propagation(g)
    labels = np.asarray(labels)
    comp = communities_reference(es, ed, off)
    comp_to_labels = {}
    for v in range(off):
        # labels are vertex ids and never cross component boundaries
        assert comp[labels[v]] == comp[v]
        comp_to_labels.setdefault(comp[v], set()).add(labels[v])
    assert all(len(ls) == 1 for ls in comp_to_labels.values())
    assert num_communities(jnp.asarray(labels)) == 6


def test_label_propagation_deterministic(sym_graph):
    a, _ = label_propagation(sym_graph)
    b, _ = label_propagation(sym_graph)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_triangle_count_matches_dense_matmul():
    for seed in (0, 5):
        src, dst = S.user_follow_graph(150, 6.0, seed=seed)
        keep = src != dst
        g = G.build_coo(src[keep], dst[keep], 150, symmetrize=True)
        s, d, _ = _edges(g)
        count, per_vertex = triangle_count(g)
        assert count == triangle_count_reference(s, d, 150)
        assert int(per_vertex.sum()) == 6 * count


def test_triangle_count_known_graph():
    # K4 has exactly 4 triangles
    a, b = np.triu_indices(4, k=1)
    g = G.build_coo(a, b, 4, symmetrize=True)
    count, _ = triangle_count(g)
    assert count == 4


def test_triangle_count_ignores_self_loops():
    # K4 + self-loops on every vertex: still exactly 4 triangles
    a, b = np.triu_indices(4, k=1)
    loops = np.arange(4)
    g = G.build_coo(np.concatenate([a, loops]),
                    np.concatenate([b, loops]), 4, symmetrize=True)
    count, _ = triangle_count(g)
    assert count == 4


def test_undirected_algorithms_reject_directed_graphs():
    """On a directed edge list these would return silently wrong results
    (a directed 3-cycle has no symmetric edges, so 0 triangles / empty
    2-core) — they must raise instead."""
    g = G.build_coo(np.array([0, 1, 2]), np.array([1, 2, 0]), 3)
    with pytest.raises(ValueError, match="symmetriz"):
        triangle_count(g)
    with pytest.raises(ValueError, match="symmetriz"):
        k_core(g, 2)
    with pytest.raises(ValueError, match="symmetriz"):
        label_propagation(g)
    # the documented escape hatch for manually-symmetric edge lists
    gm = G.build_coo(np.array([0, 1]), np.array([1, 0]), 2)
    gm.symmetric = True
    count, _ = triangle_count(gm)
    assert count == 0


def test_k_core_matches_peeling_oracle(sym_graph):
    s, d, _ = _edges(sym_graph)
    for k in (2, 3, 5):
        members, _ = k_core(sym_graph, k)
        ref = k_core_reference(s, d, sym_graph.n_vertices, k)
        np.testing.assert_array_equal(np.asarray(members), ref)
        assert core_size(members) == int(ref.sum())


# ---------------------------------------- engine parity (partitioned path)

def test_local_and_distributed_engines_agree(sym_graph, digraph):
    """The acceptance bar: every new algorithm, identical results on
    both engines (the distributed engine runs the 4-way edge-partitioned
    program; on one device that still exercises shard packing/sentinels).
    """
    lo_d, di_d = LocalEngine(digraph), DistributedEngine(digraph, n_data=4)
    lo_s, di_s = LocalEngine(sym_graph), DistributedEngine(sym_graph, n_data=4)
    np.testing.assert_array_equal(
        np.asarray(lo_d.bfs([0, 3]).value), np.asarray(di_d.bfs([0, 3]).value))
    np.testing.assert_allclose(
        np.asarray(lo_d.sssp(2).value), np.asarray(di_d.sssp(2).value),
        atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(lo_s.label_propagation().value),
        np.asarray(di_s.label_propagation().value))
    assert lo_s.triangle_count().value == di_s.triangle_count().value
    np.testing.assert_array_equal(
        np.asarray(lo_s.k_core(3).value), np.asarray(di_s.k_core(3).value))


def test_count_only_fast_paths(sym_graph, digraph):
    lo_d, lo_s = LocalEngine(digraph), LocalEngine(sym_graph)
    dist = np.asarray(lo_d.bfs([0]).value)
    assert lo_d.reachable_count([0]).value == int(np.isfinite(dist).sum())
    labels = np.asarray(lo_s.label_propagation().value)
    assert lo_s.num_communities().value == len(np.unique(labels))
    members = np.asarray(lo_s.k_core(3).value)
    assert lo_s.k_core_size(3).value == int(members.sum())


# --------------------------------------------------- unified query layer

def test_platform_routes_new_algorithms(sym_graph):
    plat = GraphPlatform(sym_graph, n_data=4)
    queries = [GraphQuery.bfs([0]), GraphQuery.bfs([0], count_only=True),
               GraphQuery.sssp(1), GraphQuery.label_propagation(),
               GraphQuery.label_propagation(count_only=True),
               GraphQuery.triangle_count(), GraphQuery.k_core(3),
               GraphQuery.k_core(3, count_only=True)]
    for q in queries:
        r = plat.query(q)
        plan = r.meta["plan"]
        assert plan.engine in ("local", "distributed")
        assert plan.est_local_s > 0 and plan.est_dist_s > 0
        if q.count_only or q.algorithm == "triangle_count":
            assert isinstance(r.value, int)


def test_platform_query_values_match_engines(sym_graph):
    plat = GraphPlatform(sym_graph)
    eng = LocalEngine(sym_graph)
    r = plat.query(GraphQuery.k_core(4, count_only=True))
    assert r.value == eng.k_core_size(4).value


# -------------------------------------- structured-message pregel engine

def test_pregel_grouped_combine_mixed_monoids():
    """One superstep with a (sum, min) column-grouped message must equal
    per-monoid numpy segment aggregation."""
    rng = np.random.default_rng(8)
    V, E = 40, 200
    src = rng.integers(0, V, E).astype(np.int64)
    dst = rng.integers(0, V, E).astype(np.int64)
    w = rng.random(E).astype(np.float32)
    g = G.build_coo(src, dst, V, w=w, dedup=False)
    sg = partition_1d(g, 1)
    spec = PregelSpec(
        message=lambda x, w: jnp.stack([w, w], axis=-1),
        combine=(("sum", 1), ("min", 1)),
        apply=lambda old, agg, ids, gval: agg,
        identity=(0.0, float("inf")),
    )
    state, _ = run_pregel(spec, sg, jnp.zeros((V, 2)), max_iters=1)
    state = np.asarray(state)
    s, d, ww = _edges(g)
    want_sum = np.zeros(V, np.float32)
    want_min = np.full(V, np.inf, np.float32)
    np.add.at(want_sum, d, ww)
    np.minimum.at(want_min, d, ww)
    np.testing.assert_allclose(state[:, 0], want_sum, rtol=1e-5)
    np.testing.assert_allclose(state[:, 1], want_min, rtol=1e-6)


def test_pregel_dst_state_messages():
    """Edge programs reading both endpoints: sum of dst's own id over
    in-edges == in_degree * id."""
    src = np.array([0, 1, 2, 0])
    dst = np.array([1, 2, 0, 2])
    g = G.build_coo(src, dst, 3)
    sg = partition_1d(g, 1)
    spec = PregelSpec(
        message=lambda s, w, d: d,
        combine="sum",
        apply=lambda old, agg, ids, gval: agg,
        identity=0.0,
        needs_dst_state=True,
    )
    init = jnp.arange(3, dtype=jnp.float32)
    state, _ = run_pregel(spec, sg, init, max_iters=1)
    indeg = np.bincount(dst, minlength=3)
    np.testing.assert_allclose(np.asarray(state), indeg * np.arange(3))


# ------------------------------------------------- real multi-device mesh

MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax.numpy as jnp
    from repro.core import graph as G
    from repro.core.algorithms.traversal import bfs_distances, bfs_reference
    from repro.core.algorithms.community import label_propagation
    from repro.core.algorithms.triangles import (
        triangle_count, triangle_count_reference, k_core, k_core_reference)
    from repro.data import synthetic as S
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4, 2), ('data', 'model'))
    src, dst = S.user_follow_graph(300, 5.0, seed=3)
    keep = src != dst
    g = G.build_coo(src[keep], dst[keep], 300, symmetrize=True)
    s = np.asarray(g.src)[:g.n_edges]; d = np.asarray(g.dst)[:g.n_edges]

    ref_bfs = bfs_reference(s, d, 300, [0])
    lab1, _ = label_propagation(g)
    ref_tri = triangle_count_reference(s, d, 300)
    ref_core = k_core_reference(s, d, 300, 3)
    for nd, nm in [(4, 1), (4, 2)]:
        dist, _ = bfs_distances(g, [0], mesh=mesh, n_data=nd, n_model=nm)
        assert np.array_equal(np.asarray(dist), ref_bfs), ('bfs', nd, nm)
        lab, _ = label_propagation(g, mesh=mesh, n_data=nd, n_model=nm)
        assert np.array_equal(np.asarray(lab), np.asarray(lab1)), ('lpa', nd, nm)
        tri, _ = triangle_count(g, mesh=mesh, n_data=nd, n_model=nm)
        assert tri == ref_tri, ('tri', nd, nm)
        core, _ = k_core(g, 3, mesh=mesh, n_data=nd, n_model=nm)
        assert np.array_equal(np.asarray(core), ref_core), ('core', nd, nm)
    print('ALGO_MESH_OK')
""")


def test_algorithms_on_multi_device_mesh():
    """BFS/LPA/triangles/k-core on an 8-device mesh, 1-D (replicated)
    and 2-D (vertex-sharded) layouts, against single-device results."""
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "ALGO_MESH_OK" in r.stdout, r.stderr[-2000:]
