"""GraphQuery — the unified interface layer (paper Section III-A).

The paper's stack puts "a unified user interface ... and code templates"
above the engines so users never pick Spark-vs-Neo4j by hand.  This is
that layer: a small declarative query object + ``GraphPlatform`` which
owns both engines and routes through the cost-based planner.

    platform = GraphPlatform(coo, mesh=mesh)
    r = platform.query(GraphQuery.connected_components(count_only=True))
    r.value, r.engine, r.meta['plan']

Queries target any algorithm in the registry: the named classmethods are
thin wrappers over the generic, schema-validated constructor

    GraphQuery.of("hits", max_iters=50)

so a newly registered algorithm is queryable with zero edits here.

``GraphPlatform`` is a thin per-graph facade over the service layer
(``repro.core.service``): one ``GraphAnalyticsService`` with a
single-entry catalog.  The service owns the plan cache (cost model +
routing per distinct query shape) and the *result* cache keyed on
``(graph content digest, algorithm, frozen params, count_only)`` — a
repeated identical query on a resident graph returns the cached result
without re-tracing or re-running anything.  Keying on the content
digest (not ``id()``, which CPython recycles the moment a graph is
garbage-collected) makes the cache sound across graph lifetimes and
lets byte-identical reloaded snapshots share entries: pass one mapping
as ``result_cache`` to several platforms and a query answered for a
graph is a hit for every later platform built over the same bytes.
The engine is deliberately *not* in the key — results are
contractually engine-independent, so a re-plan onto the other engine
(``force_engine`` toggled, chip count changed) still hits.

Multi-graph catalogs, admission tiers and fused batch execution live
one level up: build a ``GraphAnalyticsService`` directly and ``submit``
queries for tickets instead of calling ``query`` synchronously.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

from repro.core import graph as G
from repro.core import registry as R
from repro.core.engines import LocalEngine, DistributedEngine, QueryResult
from repro.core.service import GraphAnalyticsService


@dataclasses.dataclass(frozen=True)
class GraphQuery:
    """One declarative query; ``algorithm`` is any registered name
    (``repro.core.registry.names()``).

    ``count_only=True`` selects the algorithm's count-only fast path
    (the paper's '<2 s count vs ~10 min table' query class) where one
    exists; it is a no-op for algorithms whose result is already a
    scalar summary.
    """

    algorithm: str
    count_only: bool = False
    params: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, algorithm: str, count_only: bool = False,
           **params) -> "GraphQuery":
        """Generic constructor: validates ``params`` against the
        algorithm's registered schema (unknown names, missing required
        parameters and out-of-range values all raise here, not at
        execution time) and fills in schema defaults."""
        defn = R.get(algorithm)
        return cls(algorithm, count_only, defn.validate(params))

    def key(self):
        """Hashable identity of this query (cache key component)."""
        return (self.algorithm, R.freeze(self.params), self.count_only)

    # -- named constructors (thin wrappers over ``of``) ---------------------
    @classmethod
    def pagerank(cls, alpha=0.85, tol=1e-8, max_iters=100):
        return cls.of("pagerank", alpha=alpha, tol=tol, max_iters=max_iters)

    @classmethod
    def connected_components(cls, count_only=False, max_iters=200):
        return cls.of("connected_components", count_only,
                      max_iters=max_iters)

    @classmethod
    def two_hop(cls, n_users: int, count_only=False, dedup=True):
        return cls.of("two_hop", count_only, n_users=n_users, dedup=dedup)

    @classmethod
    def degree_stats(cls):
        return cls.of("degree_stats", True)

    @classmethod
    def bfs(cls, sources, count_only=False, max_iters=None):
        """Hop distances from a source set; ``count_only`` returns the
        size of the reachable set instead of the distance table.
        ``max_iters=None`` guarantees convergence."""
        return cls.of("bfs", count_only, sources=tuple(sources),
                      max_iters=max_iters)

    @classmethod
    def sssp(cls, source: int, max_iters=None):
        """Single-source weighted shortest paths (non-negative weights)."""
        return cls.of("sssp", source=source, max_iters=max_iters)

    @classmethod
    def label_propagation(cls, count_only=False, max_iters=30,
                          n_channels=64):
        """Community detection; ``count_only`` returns ``num_communities``."""
        return cls.of("label_propagation", count_only, max_iters=max_iters,
                      n_channels=n_channels)

    @classmethod
    def triangle_count(cls):
        """Global triangle count (inherently count-only)."""
        return cls.of("triangle_count", True)

    @classmethod
    def k_core(cls, k: int, count_only=False, max_iters=None):
        """k-core membership; ``count_only`` returns the core size."""
        return cls.of("k_core", count_only, k=k, max_iters=max_iters)


class GraphPlatform:
    """Per-graph facade over :class:`GraphAnalyticsService`: one graph,
    both engines, synchronous queries routed through the planner and
    served from the service's shared result cache."""

    GRAPH = "default"

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None, cache_size: int = 128,
                 result_cache: Optional[OrderedDict] = None):
        self.coo = coo
        self.mesh = mesh
        # a caller-supplied result_cache mapping may be shared across
        # platforms (the reloaded-snapshot case); entries are keyed on
        # content digests so sharing can never serve a stale result
        self.service = GraphAnalyticsService(cache_size=cache_size,
                                             result_cache=result_cache)
        self._ctx = self.service.add_graph(
            self.GRAPH, coo, mesh=mesh, n_data=n_data, n_model=n_model,
            local_max_degree=local_max_degree, force_engine=force_engine)

    # -- service-layer delegates -------------------------------------------
    @property
    def stats(self):
        return self._ctx.current_stats()

    @property
    def force_engine(self) -> Optional[str]:
        return self._ctx.force_engine

    @property
    def n_chips(self) -> int:
        return self._ctx.n_chips

    @property
    def cache_size(self) -> int:
        return self.service.cache_size

    @property
    def cache_stats(self) -> dict:
        return self.service.cache_stats

    @property
    def local(self) -> LocalEngine:
        return self._ctx.local

    @property
    def distributed(self) -> DistributedEngine:
        return self._ctx.distributed

    # engine memos are service-context state now, but tests and callers
    # probe them to check lazy construction — keep the names working
    @property
    def _local(self) -> Optional[LocalEngine]:
        return self._ctx._local

    @property
    def _dist(self) -> Optional[DistributedEngine]:
        return self._ctx._dist

    @property
    def _result_cache(self) -> OrderedDict:
        return self.service._result_cache

    def plan(self, q: GraphQuery):
        """Cost every (engine, variant) pair and pick one (cached per
        query shape)."""
        return self._ctx.plan(q)

    def query(self, q: GraphQuery) -> QueryResult:
        return self.service.call(self.GRAPH, q)

    def metrics(self) -> dict:
        """The service tier's observability snapshot (queue depths,
        latency histograms, cache hit rate, retry counters) for this
        platform's one-graph service — see
        :meth:`GraphAnalyticsService.metrics`."""
        return self.service.metrics()
