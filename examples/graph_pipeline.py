"""End-to-end driver: the paper's production pipeline, miniaturized.

snapshots (FlockDB dumps) -> SnapshotStore (HDFS/GCS) -> ETL (dedup,
degree-cap, pack) -> hybrid platform (planner routes) -> multi-account
detection + combined connected users -> ResultSink (BigQuery/GCS) for
downstream ML.

    PYTHONPATH=src python examples/graph_pipeline.py [workdir]
"""
import sys
import time

import numpy as np

from repro.core import graph as G
from repro.core.query import GraphQuery, GraphPlatform
from repro.data import synthetic as S
from repro.data.etl import GraphETL, Snapshot, SnapshotStore, ResultSink

workdir = sys.argv[1] if len(sys.argv) > 1 else "/tmp/graph_pipeline"
t_start = time.time()

# ---- 1. Ingest four daily snapshots (paper: 4 daily snapshot datasets) --
store = SnapshotStore(f"{workdir}/snapshots")
rng = np.random.default_rng(0)
N_USERS, N_IDS = 30_000, 10_000
for day in range(4):
    u, i = S.safety_bipartite_graph(N_USERS, N_IDS, seed=day)
    store.write(Snapshot(f"day{day}", u, i + N_USERS))  # ids offset
print(f"[ingest] {len(store.list())} snapshots")

# ---- 2. ETL: union -> dedup -> build (exact COO + capped ELL) ----------
etl = GraphETL(max_adjacent_nodes=100)          # the paper's legacy cap
snaps = [store.read(n) for n in store.list()]
coo, ell, report = etl.build(snaps, n_vertices=N_USERS + N_IDS)
print(f"[etl] edges_in={report.n_edges_in} dedup={report.n_edges_deduped} "
      f"capped_loss={report.lost_fraction:.1%} "
      f"(paper: 27.8% at cap=100) hash={report.content_hash}")

# ---- 3. Multi-account detection (two-hop motif) -------------------------
from repro.core.algorithms.two_hop import multi_account_pairs
u_all = np.concatenate([s.src for s in snaps])
i_all = np.concatenate([s.dst for s in snaps]) - N_USERS
pairs, valid, count, _ = multi_account_pairs(
    u_all, i_all, N_USERS, N_IDS, max_adjacent_nodes=100)
print(f"[multi-account] {int(count)} distinct same-user pairs")

# ---- 4. Combined connected users on the unified graph -------------------
sym = G.build_coo(np.concatenate([u_all, i_all + N_USERS]),
                  np.concatenate([i_all + N_USERS, u_all]),
                  N_USERS + N_IDS)
platform = GraphPlatform(sym)
r = platform.query(GraphQuery.connected_components())
labels = np.asarray(r.value)[:N_USERS]
n_comp = len(np.unique(labels))
print(f"[connected-users] {n_comp} components via {r.engine} "
      f"({r.iterations} supersteps) | {r.meta['plan'].reason}")

# ---- 5. Persist for downstream ML ---------------------------------------
sink = ResultSink(f"{workdir}/results")
sink.write("same_user_pairs",
           {"pairs": np.asarray(pairs)[np.asarray(valid)]},
           {"algo": "two_hop", "cap": 100, "count": int(count)})
sink.write("connected_users",
           {"user": np.arange(N_USERS), "component": labels},
           {"algo": "combined_connected_users", "engine": r.engine})
print(f"[sink] results persisted under {workdir}/results")
print(f"[done] end-to-end {time.time()-t_start:.1f}s")
