"""Serve a small model with batched requests: prefill once, greedy-decode
a continuation per request (the decode_* dry-run cells, live).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.argv = ["serve", "--arch", "smollm-360m", "--reduced",
            "--batch", "4", "--prompt-len", "32", "--gen", "16"]

from repro.launch.serve import main  # noqa: E402
main()
