"""Fault-tolerance machinery: heartbeats, failure injection, straggler
watchdog, and the restart supervisor.

On a real multi-pod deployment the coordinator restarts dead slices and
the job restores from the last committed checkpoint; in this container
we exercise exactly that control flow with *injected* failures
(tests/test_fault_tolerance.py kills the step loop mid-run and asserts
bit-exact continuation from the checkpoint).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional, Sequence


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises at the given steps (once each) — models preemption/crash."""
    fail_at_steps: Sequence[int] = ()

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)

    def check(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class Heartbeat:
    """Periodic liveness file; a monitor (or test) detects stalls."""

    def __init__(self, path: str, interval_s: float = 5.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int, force: bool = False):
        now = time.time()
        if force or now - self._last >= self.interval_s:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"time": now, "step": step}, f)
            os.replace(tmp, self.path)
            self._last = now

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (FileNotFoundError, json.JSONDecodeError):
            return None


class StragglerWatchdog:
    """EWMA step-time monitor.  On real pods a flagged host triggers a
    re-slice; here we record the event stream for the supervisor/tests."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2,
                 warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.count = 0
        self.events: list[dict] = []

    def record(self, step: int, step_time_s: float) -> bool:
        self.count += 1
        if self.ewma is None:
            self.ewma = step_time_s
            return False
        is_straggler = (self.count > self.warmup
                        and step_time_s > self.factor * self.ewma)
        if is_straggler:
            self.events.append({"step": step, "time": step_time_s,
                                "ewma": self.ewma})
        # EWMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time_s
        return is_straggler


@dataclasses.dataclass
class SupervisorReport:
    restarts: int
    completed_steps: int
    straggler_events: int
    final_metrics: dict


def run_supervised(
    train_loop: Callable[[Optional[int]], dict],
    max_restarts: int = 3,
) -> SupervisorReport:
    """Restart-on-failure driver.

    ``train_loop(resume_step)`` runs until done (returns metrics) or
    raises.  The loop is responsible for checkpoint/restore; the
    supervisor just re-invokes it — same division of labour as a real
    cluster controller.
    """
    restarts = 0
    while True:
        try:
            metrics = train_loop(None)
            return SupervisorReport(
                restarts=restarts,
                completed_steps=metrics.get("steps", 0),
                straggler_events=metrics.get("straggler_events", 0),
                final_metrics=metrics,
            )
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
