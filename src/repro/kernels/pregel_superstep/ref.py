"""Pure-jnp oracle for the fused Pregel superstep kernel.

One superstep over the in-neighbor ELL layout, under the exact
signature the Pallas kernel implements:

    agg[v] = reduce_k( op, mask[v,k] ? message(x[nbr[v,k]], w[v,k])
                                     : fill )

where ``fill`` is the monoid identity for min/max and 0 for sum —
matching the dense path's segment-combine semantics (segment_sum drops
padded edges outright, so vertices with no message aggregate to 0
regardless of the declared identity; segment_min/max empties are
normalized to the identity).

Unlike ``ell_combine`` this takes the *edge program* as a parameter:
``message`` must be elementwise in ``(src_state, w)`` and
shape-polymorphic (it is called on ``[V, K]`` gathered tiles here and
on ``[E]`` edge vectors by the dense path — the ``PregelSpec.
elementwise_message`` contract).  Trailing state dims are supported
(messages ``[V, K, ...]`` reduce over axis 1), which is how fused-batch
(``batched_spec``) programs ride the same kernel signature.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _fill_value(op: str, identity):
    return 0 if op == "sum" else identity


@partial(jax.jit, static_argnames=("message", "op", "identity",
                                   "message_dtype"))
def superstep_ref(nbr, mask, w, x, *, message, op: str, identity,
                  message_dtype=None):
    """agg[v] = reduce_k over masked message(x[nbr[v,k]], w[v,k]).

    nbr : [V, K] int32 (sentinel/invalid slots guarded by mask)
    x   : [Vx] or [Vx, ...] gather source (vertex state)
    Returns [V] or [V, ...] aggregates in the message dtype (cast to
    ``message_dtype`` first when set — the reduced-precision channel).
    """
    vals = jnp.take(x, jnp.clip(nbr, 0, x.shape[0] - 1), axis=0)
    msgs = message(vals, w)
    if message_dtype is not None:
        msgs = msgs.astype(message_dtype)
    m = mask != 0
    if msgs.ndim > m.ndim:
        m = m.reshape(m.shape + (1,) * (msgs.ndim - m.ndim))
    fill = jnp.asarray(_fill_value(op, identity), msgs.dtype)
    contrib = jnp.where(m, msgs, fill)
    red = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[op]
    return red(contrib, axis=1)
