"""Algorithm registry — the platform's single extension point.

The paper's platform (Section III-A) puts one unified interface above
heterogeneous engines so adding a use case does not mean re-plumbing
every layer.  This module is that property made concrete: an algorithm
is *data* — an ``AlgorithmDef`` carrying its parameter schema, its
runner, its count-only fast path, its planner cost hook and its engine
capability flags — and every layer (engines, planner, query, benchmarks,
tests) iterates the registry instead of hard-coding names.

Registering a new workload means creating one module under
``repro/core/algorithms/`` that calls :func:`register` at import time.
Nothing else changes: ``ensure_loaded`` auto-discovers every module in
the package, so the engines, the planner and ``GraphQuery`` pick the new
algorithm up without edits (see ``algorithms/hits.py`` for the
canonical example).
"""
from __future__ import annotations

import dataclasses
import importlib
import pkgutil
import threading
import time
from typing import Any, Callable, Mapping, Optional

import numpy as np

from repro.core import obs


class _Required:
    """Sentinel for parameters without a default."""

    def __repr__(self) -> str:  # pragma: no cover - repr cosmetics
        return "<required>"


REQUIRED = _Required()


@dataclasses.dataclass(frozen=True)
class Param:
    """One entry of an algorithm's parameter schema.

    ``default=REQUIRED`` marks a mandatory parameter.  ``normalize`` maps
    user input to the canonical (hashable) form — e.g. a source list to
    a tuple of ints — so validated params can key the platform's result
    cache.  ``check`` receives the normalized value and returns whether
    it is admissible.  Both are skipped for ``None`` values (``None``
    uniformly means "auto" in this codebase).
    """

    name: str
    default: Any = REQUIRED
    check: Optional[Callable[[Any], bool]] = None
    normalize: Optional[Callable[[Any], Any]] = None
    doc: str = ""

    @property
    def required(self) -> bool:
        return self.default is REQUIRED


@dataclasses.dataclass(frozen=True)
class AlgorithmDef:
    """Everything the platform needs to serve one algorithm.

    run     : the full-result runner.  Either a callable
              ``(engine, **params) -> (value, iterations_or_None)`` or a
              ``PregelSpec`` — in the latter case ``init`` must map
              ``(engine, params) -> (init_state, max_iters)`` and the
              engine drives ``run_pregel`` generically.
    count   : optional reducer ``value -> count`` for ``count_only``
              queries that post-process the full result (e.g.
              ``num_components``).
    count_run: optional *dedicated* count-only runner for algorithms
              whose fast path never materializes the full result at all
              (two-hop's degree-sum bound — the paper's '<2 s count vs
              ~10 min table' class).  Takes the same signature as a
              callable ``run`` and may ignore parameters.
    cost    : planner hook ``(GraphStats, params, count_only) ->
              QuerySpec`` — or a *sequence* of QuerySpecs, one per
              execution variant (each with ``variant`` set); receives
              schema defaults merged under any user-supplied params, so
              user caps like ``max_iters`` flow into the cost model.
    variants: optional mapping ``variant name -> runner`` for algorithms
              with several execution strategies that produce identical
              results (triangle counting's bitset vs ELL-intersect
              paths).  The planner picks the cheapest feasible variant
              per (graph, engine) from the cost hook's QuerySpecs; an
              engine invoked without a plan resolves one the same way.
              ``run`` stays the fallback when no variant is selected.
    batch_runner: optional *fused* executor
              ``(engine, [params, ...]) -> (values, iterations, meta)``
              that answers K compatible queries in ONE stacked/vmapped
              execution (K BFS frontiers as one ``[V, K]`` pregel
              program; K jaccard pair-batches as one kernel call) and
              returns one value per query, scatter-ready.  Each value
              must be bit-identical to running its query alone — the
              service's fusion contract.
    fuse    : compatibility key hook ``validated params -> hashable``;
              two queries may share one ``batch_runner`` call iff they
              target the same algorithm on the same graph and their fuse
              keys are equal (BFS fuses across ``sources`` but never
              across differing ``max_iters``).  ``None`` disables
              fusion even when a ``batch_runner`` exists.
    engines : capability flags; which engines can execute the
              definition (``("local",)`` for ELL-batch workloads that
              are inherently single-device).
    requires_symmetric : undirected semantics — the engine rejects
              non-symmetrized edge lists up front.
    method / count_method : engine method aliases (``eng.k_core(...)``,
              ``eng.k_core_size(...)``); ``method`` defaults to ``name``.
    example_params : a representative parameter set (satisfying the
              schema) used by the generic benchmark sweep and the parity
              test suite; ``None`` opts out of generic sweeps.
    warm_start : optional seeded runner
              ``(engine, params, seed) -> (value, iterations) | None``
              for fixpoint algorithms that can start iterating from a
              previous snapshot's converged result (``seed`` is a
              ``CachedResult``-like object with ``.value``).  Returning
              ``None`` declines — the engine falls back to the cold
              runner, so a bad seed can cost time but never correctness.
              The answer must equal the cold answer within the
              algorithm's stated tolerance; only iterations may differ.
    incremental : optional delta-maintenance runner
              ``(engine, params, seed, delta) -> (value, iters) | None``
              for algorithms that can repair a previous result against a
              ``GraphDelta`` (seeding the frontier from
              ``delta.touched``) instead of recomputing the whole graph.
              Must be *exact*: byte-identical to cold recompute, or
              decline with ``None`` (e.g. a monotone-add algorithm
              handed a delta containing removals).
    """

    name: str
    run: Any
    params: tuple[Param, ...] = ()
    init: Optional[Callable[[Any, dict], tuple]] = None
    count: Optional[Callable[[Any], Any]] = None
    count_run: Optional[Callable[..., tuple]] = None
    cost: Optional[Callable[..., Any]] = None
    variants: Optional[Mapping[str, Any]] = None
    batch_runner: Optional[Callable[..., tuple]] = None
    fuse: Optional[Callable[[dict], Any]] = None
    engines: tuple[str, ...] = ("local", "distributed")
    requires_symmetric: bool = False
    method: Optional[str] = None
    count_method: Optional[str] = None
    example_params: Optional[Mapping[str, Any]] = dataclasses.field(
        default_factory=dict)
    doc: str = ""
    warm_start: Optional[Callable[..., Optional[tuple]]] = None
    incremental: Optional[Callable[..., Optional[tuple]]] = None

    @property
    def has_count_path(self) -> bool:
        return self.count is not None or self.count_run is not None

    @property
    def fusable(self) -> bool:
        """Whether the service scheduler may coalesce compatible queries
        into one fused execution."""
        return self.batch_runner is not None and self.fuse is not None

    def runner_for(self, variant: Optional[str]):
        """Resolve the runner for ``variant`` (None -> default ``run``)."""
        if variant is None:
            return self.run
        if not self.variants or variant not in self.variants:
            known = sorted(self.variants or ())
            raise ValueError(
                f"{self.name}: unknown variant {variant!r}; "
                f"registered: {known}")
        return self.variants[variant]

    def defaults(self) -> dict:
        """Schema defaults (required parameters omitted)."""
        return {p.name: p.default for p in self.params if not p.required}

    def validate(self, params: Optional[Mapping[str, Any]] = None,
                 partial: bool = False) -> dict:
        """Check ``params`` against the schema; returns the normalized
        dict with defaults filled in.

        ``partial=True`` tolerates missing required parameters (the
        planner costs queries it cannot yet run — e.g. a spec sweep).
        Unknown parameter names are always an error.
        """
        params = dict(params or {})
        known = {p.name for p in self.params}
        unknown = sorted(set(params) - known)
        if unknown:
            raise ValueError(
                f"{self.name}: unknown parameter(s) {unknown}; "
                f"schema: {sorted(known)}")
        out = {}
        for p in self.params:
            if p.name in params:
                v = params[p.name]
            elif p.required:
                if partial:
                    continue
                raise ValueError(
                    f"{self.name}: missing required parameter {p.name!r}")
            else:
                v = p.default
            if v is not None:
                if p.normalize is not None:
                    v = p.normalize(v)
                if p.check is not None and not p.check(v):
                    raise ValueError(
                        f"{self.name}: invalid value {v!r} for "
                        f"parameter {p.name!r}")
            out[p.name] = v
        return out


# ---------------------------------------------------------------------------
# The registry proper
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, AlgorithmDef] = {}
_METHOD_TABLE: Optional[dict] = None
_LOADED = False
_LOADING = False

_ALGORITHMS_PKG = "repro.core.algorithms"


def register(defn: AlgorithmDef, replace: bool = False) -> AlgorithmDef:
    """Add a definition; modules call this at import time."""
    global _METHOD_TABLE
    if not replace and defn.name in _REGISTRY \
            and _REGISTRY[defn.name] is not defn:
        raise ValueError(f"algorithm {defn.name!r} is already registered")
    _REGISTRY[defn.name] = defn
    _METHOD_TABLE = None
    return defn


def unregister(name: str) -> None:
    """Remove a definition (tests registering throwaway algorithms)."""
    global _METHOD_TABLE
    _REGISTRY.pop(name, None)
    _METHOD_TABLE = None


def ensure_loaded() -> None:
    """Import every module under ``repro.core.algorithms`` so their
    ``register`` calls have run.  Auto-discovery is what makes adding an
    algorithm a one-file change: a new module in the package is found
    here without touching any dispatch table.

    Marked loaded only once every import succeeded — a failing module
    (e.g. a broken user algorithm) raises on *every* call rather than
    leaving a silently half-populated registry."""
    global _LOADED, _LOADING
    if _LOADED or _LOADING:      # _LOADING: reentrant import of this pkg
        return
    _LOADING = True
    try:
        pkg = importlib.import_module(_ALGORITHMS_PKG)
        for mod in pkgutil.iter_modules(pkg.__path__):
            importlib.import_module(f"{_ALGORITHMS_PKG}.{mod.name}")
        _LOADED = True
    finally:
        _LOADING = False


def get(name: str) -> AlgorithmDef:
    ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {names()}") from None


def names() -> list[str]:
    ensure_loaded()
    return sorted(_REGISTRY)


def items() -> list[tuple[str, AlgorithmDef]]:
    ensure_loaded()
    return sorted(_REGISTRY.items())


def method_table() -> dict[str, tuple[AlgorithmDef, bool]]:
    """Engine method name -> (definition, count_only) — the table behind
    ``Engine.__getattr__`` dispatch (``eng.num_components()`` ==
    ``eng.run("connected_components", count_only=True)``).  Memoized;
    ``register``/``unregister`` invalidate."""
    global _METHOD_TABLE
    ensure_loaded()
    if _METHOD_TABLE is None:
        table: dict[str, tuple[AlgorithmDef, bool]] = {}
        for defn in _REGISTRY.values():
            table[defn.method or defn.name] = (defn, False)
            if defn.count_method:
                table[defn.count_method] = (defn, True)
        _METHOD_TABLE = table
    return _METHOD_TABLE


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------
#
# The registry is where runners live, so it is also the seam where the
# service's failure paths are driven deterministically: a FaultPolicy
# installed against an algorithm name wraps every execution of that
# algorithm (the engines call ``apply_fault`` immediately before
# invoking the runner — solo and fused paths alike).  Production code
# never installs one; the runtime test harness uses them to exercise
# retry, dead-letter and slow-batch behaviour without flaky sleeps or
# monkeypatching engine internals.

class FaultInjected(RuntimeError):
    """The error a fault policy raises — a *retryable* runtime failure
    (unlike schema ``ValueError``s, which dead-letter immediately)."""


class FaultPolicy:
    """One injected failure behaviour.  ``apply`` runs right before the
    algorithm's runner; it may raise (failure) or sleep (delay).  Stock
    policies below; anything with an ``apply(algorithm)`` works."""

    def apply(self, algorithm: str) -> None:  # pragma: no cover - abstract
        raise NotImplementedError


class FailNTimes(FaultPolicy):
    """Fail the first ``n`` executions, then succeed forever — the
    retry-then-success driver.  Thread-safe: concurrent workers see
    exactly ``n`` failures in total."""

    def __init__(self, n: int, message: str = "injected fault"):
        self.n = int(n)
        self.message = message
        self._remaining = int(n)
        self._lock = threading.Lock()

    def apply(self, algorithm: str) -> None:
        with self._lock:
            if self._remaining <= 0:
                return
            self._remaining -= 1
            k = self.n - self._remaining
        raise FaultInjected(
            f"{algorithm}: {self.message} ({k}/{self.n})")


class FailAlways(FaultPolicy):
    """Every execution fails — the dead-letter driver."""

    def __init__(self, message: str = "injected fault"):
        self.message = message

    def apply(self, algorithm: str) -> None:
        raise FaultInjected(f"{algorithm}: {self.message}")


class Delay(FaultPolicy):
    """Every execution sleeps ``seconds`` first — the slow-batch-ticket
    driver for latency/overlap tests (optionally failing afterwards)."""

    def __init__(self, seconds: float, then_fail: bool = False):
        self.seconds = float(seconds)
        self.then_fail = then_fail

    def apply(self, algorithm: str) -> None:
        time.sleep(self.seconds)
        if self.then_fail:
            raise FaultInjected(f"{algorithm}: injected fault after "
                                f"{self.seconds}s delay")


_FAULTS: dict[str, FaultPolicy] = {}
_FAULTS_LOCK = threading.Lock()


def install_fault(name: str, policy: FaultPolicy) -> FaultPolicy:
    """Install ``policy`` against algorithm ``name`` (replacing any
    previous one).  Returns the policy for chaining."""
    with _FAULTS_LOCK:
        _FAULTS[name] = policy
    return policy


def uninstall_fault(name: Optional[str] = None) -> None:
    """Remove one algorithm's fault policy, or all of them (``None``)."""
    with _FAULTS_LOCK:
        if name is None:
            _FAULTS.clear()
        else:
            _FAULTS.pop(name, None)


def apply_fault(name: str) -> None:
    """Run the installed fault policy for ``name``, if any — the hook
    the engines call per execution attempt.  Injections surface on the
    observability event stream (``obs.emit``) so traced drains can see
    which attempts a policy actually hit."""
    with _FAULTS_LOCK:
        policy = _FAULTS.get(name)
    if policy is not None:
        try:
            policy.apply(name)
        except BaseException as e:
            obs.emit("fault", algorithm=name,
                     policy=type(policy).__name__, error=repr(e))
            raise
        else:
            obs.emit("fault", algorithm=name,
                     policy=type(policy).__name__, error=None)


# ---------------------------------------------------------------------------
# Cache keys
# ---------------------------------------------------------------------------

def freeze(value: Any) -> Any:
    """Recursively convert a parameter value into a hashable key
    component (dicts to sorted item tuples, arrays to bytes)."""
    if isinstance(value, Mapping):
        return tuple(sorted((k, freeze(v)) for k, v in value.items()))
    if isinstance(value, (list, tuple)):
        return tuple(freeze(v) for v in value)
    if isinstance(value, (set, frozenset)):
        return tuple(sorted(freeze(v) for v in value))
    if isinstance(value, np.ndarray):
        return (value.dtype.str, value.shape, value.tobytes())
    if isinstance(value, (int, float, bool, str, bytes, type(None))):
        return value
    if hasattr(value, "__array__"):          # jax arrays and friends
        arr = np.asarray(value)
        return (arr.dtype.str, arr.shape, arr.tobytes())
    return value                              # trust it to be hashable


# ---------------------------------------------------------------------------
# Superstep execution variants
# ---------------------------------------------------------------------------

def superstep_variants(spec) -> dict:
    """The standard ``variants`` mapping for a PregelSpec runner.

    ``dense`` is the spec itself (the gather/segment-combine oracle),
    ``fused`` the ELL-blocked fused-kernel strategy, and — when the spec
    declares a ``frontier_mode`` — ``frontier`` the packed active-list
    strategy.  All three produce bit-identical results (the engine falls
    back to dense whenever a strategy's preconditions fail), so the
    planner is free to pick per (graph, engine) from the cost hook's
    per-variant QuerySpecs.
    """
    from repro.core.pregel import SuperstepVariant

    out = {"dense": spec, "fused": SuperstepVariant(spec, "fused")}
    if spec.frontier_mode is not None:
        out["frontier"] = SuperstepVariant(spec, "frontier")
    return out
