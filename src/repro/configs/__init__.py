from repro.configs.base import (
    ModelConfig, ShapeSpec, SHAPES, get_config, list_archs, reduced_config,
)
