"""GraphQuery — the unified interface layer (paper Section III-A).

The paper's stack puts "a unified user interface ... and code templates"
above the engines so users never pick Spark-vs-Neo4j by hand.  This is
that layer: a small declarative query object + ``GraphPlatform`` which
owns both engines and routes through the cost-based planner.

    platform = GraphPlatform(coo, mesh=mesh)
    r = platform.query(GraphQuery.connected_components(count_only=True))
    r.value, r.engine, r.meta['plan']

Queries target any algorithm in the registry: the named classmethods are
thin wrappers over the generic, schema-validated constructor

    GraphQuery.of("hits", max_iters=50)

so a newly registered algorithm is queryable with zero edits here.

``GraphPlatform`` keeps two LRU caches for the paper's interactive query
class ("<2 s count vs ~10 min table"): a *plan* cache (cost model +
routing per distinct query shape) and a *result* cache keyed on
``(graph content digest, algorithm, frozen params, count_only,
engine)`` — a repeated identical query on a resident graph returns the
cached result without re-tracing or re-running anything.  Keying on the
content digest (not ``id()``, which CPython recycles the moment a graph
is garbage-collected) makes the cache sound across graph lifetimes and
lets byte-identical reloaded snapshots share entries: pass one mapping
as ``result_cache`` to several platforms and a query answered for a
graph is a hit for every later platform built over the same bytes.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.engines import LocalEngine, DistributedEngine, QueryResult


@dataclasses.dataclass(frozen=True)
class GraphQuery:
    """One declarative query; ``algorithm`` is any registered name
    (``repro.core.registry.names()``).

    ``count_only=True`` selects the algorithm's count-only fast path
    (the paper's '<2 s count vs ~10 min table' query class) where one
    exists; it is a no-op for algorithms whose result is already a
    scalar summary.
    """

    algorithm: str
    count_only: bool = False
    params: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, algorithm: str, count_only: bool = False,
           **params) -> "GraphQuery":
        """Generic constructor: validates ``params`` against the
        algorithm's registered schema (unknown names, missing required
        parameters and out-of-range values all raise here, not at
        execution time) and fills in schema defaults."""
        defn = R.get(algorithm)
        return cls(algorithm, count_only, defn.validate(params))

    def key(self):
        """Hashable identity of this query (cache key component)."""
        return (self.algorithm, R.freeze(self.params), self.count_only)

    # -- named constructors (thin wrappers over ``of``) ---------------------
    @classmethod
    def pagerank(cls, alpha=0.85, tol=1e-8, max_iters=100):
        return cls.of("pagerank", alpha=alpha, tol=tol, max_iters=max_iters)

    @classmethod
    def connected_components(cls, count_only=False, max_iters=200):
        return cls.of("connected_components", count_only,
                      max_iters=max_iters)

    @classmethod
    def two_hop(cls, n_users: int, count_only=False, dedup=True):
        return cls.of("two_hop", count_only, n_users=n_users, dedup=dedup)

    @classmethod
    def degree_stats(cls):
        return cls.of("degree_stats", True)

    @classmethod
    def bfs(cls, sources, count_only=False, max_iters=None):
        """Hop distances from a source set; ``count_only`` returns the
        size of the reachable set instead of the distance table.
        ``max_iters=None`` guarantees convergence."""
        return cls.of("bfs", count_only, sources=tuple(sources),
                      max_iters=max_iters)

    @classmethod
    def sssp(cls, source: int, max_iters=None):
        """Single-source weighted shortest paths (non-negative weights)."""
        return cls.of("sssp", source=source, max_iters=max_iters)

    @classmethod
    def label_propagation(cls, count_only=False, max_iters=30,
                          n_channels=64):
        """Community detection; ``count_only`` returns ``num_communities``."""
        return cls.of("label_propagation", count_only, max_iters=max_iters,
                      n_channels=n_channels)

    @classmethod
    def triangle_count(cls):
        """Global triangle count (inherently count-only)."""
        return cls.of("triangle_count", True)

    @classmethod
    def k_core(cls, k: int, count_only=False, max_iters=None):
        """k-core membership; ``count_only`` returns the core size."""
        return cls.of("k_core", count_only, k=k, max_iters=max_iters)


class GraphPlatform:
    """Owns both engines; routes each query through the planner and
    serves repeats from the result cache."""

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None, cache_size: int = 128,
                 result_cache: Optional[OrderedDict] = None):
        self.coo = coo
        self.mesh = mesh
        self.stats = P.GraphStats.of(coo)
        self.force_engine = force_engine
        self._local: Optional[LocalEngine] = None
        self._dist: Optional[DistributedEngine] = None
        self._local_max_degree = local_max_degree
        self._n_data, self._n_model = n_data, n_model
        if mesh is not None:
            self.n_chips = 1
            for s in mesh.devices.shape:
                self.n_chips *= s
        else:
            self.n_chips = max(n_data * n_model, 1)
        self.cache_size = cache_size
        self._plan_cache: OrderedDict = OrderedDict()
        # result entries are keyed on the graph's *content digest*, so a
        # caller-supplied mapping may be shared across platforms (the
        # reloaded-snapshot case) without ever serving a stale result
        self._result_cache: OrderedDict = (
            OrderedDict() if result_cache is None else result_cache)
        self.cache_stats = {"hits": 0, "misses": 0}

    # lazy engine construction: building ELL/partitions is ETL work we
    # only pay when the planner actually routes there.
    @property
    def local(self) -> LocalEngine:
        if self._local is None:
            self._local = LocalEngine(self.coo, self._local_max_degree)
        return self._local

    @property
    def distributed(self) -> DistributedEngine:
        if self._dist is None:
            self._dist = DistributedEngine(self.coo, mesh=self.mesh,
                                           n_data=self._n_data,
                                           n_model=self._n_model)
        return self._dist

    @staticmethod
    def _lru_get(cache: OrderedDict, key):
        if key is None or key not in cache:
            return None
        cache.move_to_end(key)
        return cache[key]

    def _lru_put(self, cache: OrderedDict, key, value) -> None:
        if key is None or not self.cache_size:
            return
        cache[key] = value
        while len(cache) > self.cache_size:
            cache.popitem(last=False)

    @staticmethod
    def _query_key(q: GraphQuery):
        try:
            key = q.key()
            hash(key)           # force the check: freeze() may pass
            return key          # exotic values through unhashed
        except TypeError:       # unhashable parameter value: skip caching
            return None

    def plan(self, q: GraphQuery) -> P.Plan:
        """Cost every (engine, variant) pair and pick one (cached per
        query shape)."""
        key = self._query_key(q)
        cached = self._lru_get(self._plan_cache, key)
        if cached is not None:
            return cached
        defn = R.get(q.algorithm)
        specs = P.specs_for(q.algorithm, self.stats, count_only=q.count_only,
                            **q.params)
        plan = P.choose_plan(self.stats, specs, self.n_chips)
        chosen_engine = plan.engine
        if self.force_engine:
            plan = dataclasses.replace(plan, engine=self.force_engine,
                                       reason=f"forced: {self.force_engine}")
        if plan.engine not in defn.engines:
            # capability clamp wins over both the cost model and forcing
            plan = dataclasses.replace(
                plan, engine=defn.engines[0],
                reason=f"{q.algorithm} runs on {'/'.join(defn.engines)} "
                       f"only")
        if len(specs) > 1 and plan.engine != chosen_engine:
            # engine was overridden: re-pick the cheapest variant for it
            best = P.best_spec_for_engine(self.stats, specs, plan.engine,
                                          self.n_chips)
            plan = dataclasses.replace(plan, variant=best.variant)
        self._lru_put(self._plan_cache, key, plan)
        return plan

    def query(self, q: GraphQuery) -> QueryResult:
        plan = self.plan(q)
        qkey = self._query_key(q)
        # content digest, not id(): a recycled address must never alias
        # a dead graph's results, and byte-identical reloads must share.
        # The variant is deliberately absent — variants are contractually
        # interchangeable, so either one's result answers the query.
        key = None if qkey is None else \
            (self.coo.content_digest(), plan.engine) + qkey
        hit = self._lru_get(self._result_cache, key)
        if hit is not None:
            self.cache_stats["hits"] += 1
            return dataclasses.replace(hit, meta={**hit.meta, "cache": "hit"})
        self.cache_stats["misses"] += 1
        eng = self.local if plan.engine == "local" else self.distributed
        r = eng.run(q.algorithm, q.params, count_only=q.count_only,
                    variant=plan.variant)
        r.meta["plan"] = plan
        self._lru_put(self._result_cache, key, r)
        return r
