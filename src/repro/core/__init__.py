# The paper's primary contribution: the hybrid dual-engine graph
# analytics platform (engines + cost-based planner + algorithm library).
from repro.core import graph
from repro.core import partition
from repro.core import pregel
from repro.core import planner
from repro.core.engines import LocalEngine, DistributedEngine
from repro.core.service import (AdmissionRejected, GraphAnalyticsService,
                                GraphContext, QueryTicket)
from repro.core.query import GraphQuery, GraphPlatform
