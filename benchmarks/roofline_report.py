"""Render the roofline table from the dry-run JSON records + the
analytic cost model (EXPERIMENTS.md §Roofline reads from this)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_row
from repro.configs.base import SHAPES, get_config
from repro.utils.analytic import cost_cell
from repro.utils import roofline as RL

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

MESH_SIZES = {"single": {"data": 16, "model": 16},
              "multi": {"pod": 2, "data": 16, "model": 16}}


def load_records(results_dir=RESULTS):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def analytic_row(arch: str, shape_name: str, mesh_kind: str,
                 microbatches: int = 8):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    sizes = MESH_SIZES[mesh_kind]
    dp = tuple(ax for ax in ("pod", "data")
               if ax in sizes and shape.global_batch % sizes[ax] == 0)
    # mirror usable_dp's sequential divisibility
    dp_used, rem = [], shape.global_batch
    for ax in ("pod", "data"):
        if ax in sizes and rem % sizes[ax] == 0:
            dp_used.append(ax)
            rem //= sizes[ax]
    cost = cost_cell(cfg, shape, sizes, dp_used=tuple(dp_used),
                     microbatches=microbatches if shape.kind == "train" else 1)
    terms = cost.terms()
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    return cost, terms, dominant


def render(out=print, results_dir=RESULTS):
    recs = {(r["arch"], r["shape"],
             "multi" if r.get("mesh", "").count("x") == 2 else "single"): r
            for r in load_records(results_dir) if r.get("status") == "ok"}
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':6s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dom':>6s} {'RF':>6s} {'mem/dev':>8s}")
    out(hdr)
    rows = []
    for (arch, shape, mesh_kind), r in sorted(recs.items()):
        try:
            cost, terms, dom = analytic_row(arch, shape, mesh_kind)
        except Exception as e:  # noqa: BLE001
            out(f"{arch} {shape} {mesh_kind}: analytic error {e}")
            continue
        bound = max(terms["compute_s"], terms["memory_s"],
                    terms["collective_s"])
        rf = terms["compute_ideal_s"] / bound if bound > 0 else 0.0
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh_kind,
            **{k: terms[k] for k in
               ("compute_s", "memory_s", "collective_s")},
            "dominant": dom.replace("_s", ""),
            "roofline_fraction": rf,
            "mem_gb": r.get("memory_per_device_gb", 0.0),
            "hlo_coll_counts": r.get("coll_counts", {}),
        })
        out(f"{arch:22s} {shape:12s} {mesh_kind:6s} "
            f"{RL.fmt_seconds(terms['compute_s']):>10s} "
            f"{RL.fmt_seconds(terms['memory_s']):>10s} "
            f"{RL.fmt_seconds(terms['collective_s']):>10s} "
            f"{rows[-1]['dominant']:>6s} {rf:6.2f} "
            f"{rows[-1]['mem_gb']:7.1f}G")
    return rows


def run(out=print):
    rows = render(out=lambda *_: None)
    for r in rows[:8]:
        out(csv_row(
            f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]),
            f"dom={r['dominant']};rf={r['roofline_fraction']:.2f}"))
    out(csv_row("roofline/n_cells", 0.0, f"count={len(rows)}"))
    return rows


if __name__ == "__main__":
    render()
