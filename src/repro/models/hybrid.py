"""Hymba hybrid-head LM: parallel attention + Mamba heads per layer.

Each layer runs GQA attention (sliding window everywhere except three
full-attention layers) and a selective-SSM mixer *in parallel* on the
same normalized input; the two branch outputs are per-branch normalized
and averaged (the Hymba fusion), then an MLP follows.  Sub-quadratic:
the SSM branch carries unbounded context in O(1) state, attention is
windowed except at the three global layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.transformer import DenseLM, dp_axes


class HybridLM(DenseLM):
    family = "hybrid"

    def _init_layers(self, key) -> dict:
        cfg = self.cfg
        ka, km, ks = jax.random.split(key, 3)
        lcount, d = cfg.n_layers, cfg.d_model
        p = {
            "ln1": jnp.zeros((lcount, d), jnp.float32),
            "ln2": jnp.zeros((lcount, d), jnp.float32),
            "norm_attn": jnp.zeros((lcount, d), jnp.float32),
            "norm_ssm": jnp.zeros((lcount, d), jnp.float32),
            "attn": L.init_attn(ka, cfg, layers=lcount),
            "ssm": M.mamba_init(ks, cfg, layers=lcount),
            "mlp": L.init_mlp(km, cfg, layers=lcount),
        }
        return p

    def _mixer_train(self, p_l, window, h, qpos):
        cfg = self.cfg
        q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
        q = L.rope(q, qpos, cfg.rope_theta)
        k = L.rope(k, qpos, cfg.rope_theta)
        o = L.attention_output(q, k, v, qpos, qpos, cfg.attn_impl,
                               causal=True, window=window,
                               softcap=cfg.attn_logit_softcap,
                               chunk=cfg.attn_chunk)
        attn_out = L.out_proj(p_l["attn"], o, h.dtype)
        ssm_out, _, _ = M.mamba_mixer(p_l["ssm"], h, cfg)
        fused = 0.5 * (L.rms_norm(attn_out, p_l["norm_attn"])
                       + L.rms_norm(ssm_out, p_l["norm_ssm"]))
        return fused, (k, v)

    def _block_decode(self, p_l, window, x, k_cache, v_cache, index,
                      ssm_state=None, conv_state=None):
        cfg = self.cfg
        h = L.rms_norm(x, p_l["ln1"])
        q, k1, v1 = L.qkv_proj(p_l["attn"], h, cfg)
        pos = jnp.full((1,), index, jnp.int32)
        q = L.rope(q, pos, cfg.rope_theta)
        k1 = L.rope(k1, pos, cfg.rope_theta)
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k1.astype(k_cache.dtype), index, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v1.astype(v_cache.dtype), index, axis=1)
        o = L.attn_decode(q, k_cache, v_cache, index, causal=True,
                          window=window, softcap=cfg.attn_logit_softcap)
        attn_out = L.out_proj(p_l["attn"], o, x.dtype)
        ssm_out, ssm_state, conv_state = M.mamba_decode(
            p_l["ssm"], h, cfg, ssm_state, conv_state)
        fused = 0.5 * (L.rms_norm(attn_out, p_l["norm_attn"])
                       + L.rms_norm(ssm_out, p_l["norm_ssm"]))
        x = x + fused
        h2 = L.rms_norm(x, p_l["ln2"])
        x = x + self._ffn(p_l, h2, pos)
        return x, k_cache, v_cache, ssm_state, conv_state

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, cache_len: int) -> dict:
        cfg = self.cfg
        di = cfg.ssm_expand * cfg.d_model
        base = super().init_cache(batch_size, cache_len)
        base["ssm"] = jnp.zeros(
            (cfg.n_layers, batch_size, di, cfg.ssm_state), jnp.float32)
        base["conv"] = jnp.zeros(
            (cfg.n_layers, batch_size, cfg.ssm_conv - 1, di), self.dtype)
        return base

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        x, qpos = self._embed_inputs(params, batch)

        def body(carry, xs):
            p_l, w_l = xs
            carry = self._constrain_act(carry)
            h = L.rms_norm(carry, p_l["ln1"])
            q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
            q = L.rope(q, qpos, cfg.rope_theta)
            k = L.rope(k, qpos, cfg.rope_theta)
            o = L.attention_output(q, k, v, qpos, qpos, cfg.attn_impl,
                                   causal=True, window=w_l,
                                   softcap=cfg.attn_logit_softcap,
                                   chunk=cfg.attn_chunk)
            attn_out = L.out_proj(p_l["attn"], o, carry.dtype)
            ssm_out, hT, conv_st = M.mamba_mixer(p_l["ssm"], h, cfg)
            fused = 0.5 * (L.rms_norm(attn_out, p_l["norm_attn"])
                           + L.rms_norm(ssm_out, p_l["norm_ssm"]))
            out = carry + fused
            h2 = L.rms_norm(out, p_l["ln2"])
            out = out + self._ffn(p_l, h2, qpos)
            return out, (k, v, hT, conv_st)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (ks, vs, hTs, convs) = lax.scan(
            body, x, (params["layers"], self.windows))
        logits = L.unembed(params, x[:, -1:, :], cfg)
        pad = cache_len - s
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, {"k": ks.astype(self.dtype),
                        "v": vs.astype(self.dtype),
                        "ssm": hTs, "conv": convs}

    def decode_step(self, params, tokens, cache, index):
        x = L.embed_tokens(params, tokens, self.cfg, self.dtype)

        def body(carry, xs):
            p_l, w_l, k_c, v_c, s_c, c_c = xs
            out, k_c, v_c, s_c, c_c = self._block_decode(
                p_l, w_l, carry, k_c, v_c, index, s_c, c_c)
            return out, (k_c, v_c, s_c, c_c)

        x, (k, v, s, c) = lax.scan(
            body, x, (params["layers"], self.windows,
                      cache["k"], cache["v"], cache["ssm"], cache["conv"]))
        logits = L.unembed(params, x, self.cfg)
        return logits, {"k": k, "v": v, "ssm": s, "conv": c}

    # ------------------------------------------------------- shardings
    def _layer_spec(self, fs) -> dict:
        s = super()._layer_spec(fs)
        s["norm_attn"] = P(None, None)
        s["norm_ssm"] = P(None, None)
        s["ssm"] = {
            "w_in": P(None, fs, "model"),
            "conv_w": P(None, None, "model"),
            "w_b": P(None, "model", None),
            "w_c": P(None, "model", None),
            "w_dt1": P(None, "model", None),
            "w_dt2": P(None, None, "model"),
            "dt_bias": P(None, "model"),
            "a_log": P(None, "model", None),
            "d_skip": P(None, "model"),
            "w_out": P(None, "model", fs),
        }
        s.pop("ln1_post", None)
        s.pop("ln2_post", None)
        return s

    def cache_spec(self, multi_pod: bool = True) -> dict:
        dp = dp_axes(multi_pod)
        base = super().cache_spec(multi_pod)
        base["ssm"] = P(None, dp, "model", None)
        base["conv"] = P(None, dp, None, "model")
        return base
