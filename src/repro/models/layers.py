"""Shared transformer building blocks (pure functions over param dicts).

Conventions
-----------
* Params are nested dicts of ``jnp`` arrays; per-layer params are stacked
  on a leading ``L`` axis and consumed by ``lax.scan`` (one compiled layer
  body regardless of depth — the compile-time and HBM win every
  production JAX trainer uses).
* Activations flow as ``[B, S, D]`` in ``cfg.dtype``; attention logits
  and softmax always f32.
* Three attention implementations:
    - 'ref'     : materializes [B,H,S,S] logits (oracle; smoke tests)
    - 'chunked' : pure-JAX online softmax over (q-chunk, kv-chunk) tiles —
                  flash-attention memory behaviour, lowers on any backend
                  (what the dry-run compiles)
    - 'flash'   : the Pallas kernel (TPU runtime path)
  All three are numerically cross-checked in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -2.3819763e38  # large negative for masking in f32


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, Dh]; positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [...,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Attention implementations
# ---------------------------------------------------------------------------

def _mask(qpos, kpos, causal: bool, window, prefix: int = 0):
    """qpos [*,Sq], kpos [*,Sk] -> bool [*,Sq,Sk]. window may be traced
    (0 = unlimited) so gemma2/hymba local-global alternation survives
    lax.scan over layers.  prefix > 0 opens a bidirectional zone over the
    first ``prefix`` positions (prefix-LM, paligemma-style)."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        c = k <= q
        if prefix:
            c |= (q < prefix) & (k < prefix)
        m &= c
    w = jnp.asarray(window)
    m &= (w <= 0) | (k > q - w)
    return m


def attn_ref(q, k, v, qpos, kpos, causal=True, window=0, softcap=0.0,
             prefix: int = 0):
    """q [B,Sq,Hq,Dh]; k/v [B,Sk,Hkv,Dh] -> [B,Sq,Hq,Dh]. Oracle."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    qf = qf.reshape(b, sq, hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    m = _mask(qpos, kpos, causal, window, prefix)    # [B?,Sq,Sk] or [Sq,Sk]
    while m.ndim < logits.ndim:
        m = m[..., None, :, :] if m.ndim >= 3 else m[None]
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def _pick_chunk(s: int, c: int) -> int:
    """Largest divisor of s that is <= c (whisper's 1500-frame encoder
    and other non-power-of-two sequences need a non-1024 tile)."""
    c = min(c, s)
    while s % c:
        c -= 1
    return c


def attn_chunked(q, k, v, qpos, kpos, causal=True, window=0, softcap=0.0,
                 chunk_q: int = 1024, chunk_k: int = 1024, prefix: int = 0):
    """Flash-style online softmax in pure JAX (scan over kv chunks inside
    scan over q chunks).  Peak live logits: [B,Hkv,G,cq,ck]."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    cq = _pick_chunk(sq, chunk_q)
    ck = _pick_chunk(k.shape[1], chunk_k)
    nq, nk = sq // cq, k.shape[1] // ck

    # keep q/k/v in compute dtype (bf16 on TPU); logits/softmax accumulate
    # in f32 via preferred_element_type — the MXU-native mixed precision
    qf = (q * jnp.asarray(dh ** -0.5, q.dtype)).reshape(b, nq, cq, hkv, g, dh)
    qf = qf.transpose(1, 0, 3, 4, 2, 5)              # [nq,B,Hkv,G,cq,dh]
    kf = k.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)
    vf = v.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)
    qp = qpos.reshape(nq, cq) if qpos.ndim == 1 else qpos.reshape(b, nq, cq)
    kp = kpos.reshape(nk, ck) if kpos.ndim == 1 else kpos.reshape(b, nk, ck)

    def q_step(_, qblk):
        qi, qc = qblk                                 # [B,Hkv,G,cq,dh]
        qpb = qp[qi] if qp.ndim == 2 else qp[:, qi]   # [cq] or [B,cq]

        @jax.checkpoint
        def kv_step(carry, kblk):
            m_p, l_p, acc = carry
            ki, kc, vc = kblk
            kpb = kp[ki] if kp.ndim == 2 else kp[:, ki]
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc,
                                preferred_element_type=jnp.float32)
            logits = _softcap(logits, softcap)
            msk = _mask(qpb, kpb, causal, window, prefix)
            while msk.ndim < logits.ndim:
                msk = msk[..., None, :, :] if msk.ndim >= 3 else msk[None]
            logits = jnp.where(msk, logits, NEG_INF)
            m_c = jnp.max(logits, axis=-1, keepdims=True)
            m_n = jnp.maximum(m_p, m_c)
            p = jnp.exp(logits - m_n)
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_n, l_n, acc), None

        m0 = jnp.full((b, hkv, g, cq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        (m_f, l_f, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kf, vf))
        out = acc / jnp.where(l_f > 0, l_f, 1.0)
        return None, out

    _, outs = lax.scan(jax.checkpoint(q_step), None, (jnp.arange(nq), qf))
    # outs [nq,B,Hkv,G,cq,dh] -> [B,S,Hq,dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def _online_block(q, k, v, qpos, kpos, state, causal, window, softcap,
                  prefix=0, chunk_k: int = 512):
    """Online-softmax update of (m, l, acc) against one kv block.
    q [B,Hkv,G,Sq,Dh]; k/v [B,Sk,Hkv,Dh]; state tensors [B,Hkv,G,Sq,*]."""
    b, sk, hkv, dh = k.shape
    ck = _pick_chunk(sk, chunk_k)
    nk = sk // ck
    kc = k.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)
    kpc = kpos.reshape(nk, ck)

    @jax.checkpoint
    def kv_step(carry, xs):
        m_p, l_p, acc = carry
        kb, vb, kpb = xs
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", q, kb,
                            preferred_element_type=jnp.float32)
        logits = _softcap(logits, softcap)
        msk = _mask(qpos, kpb, causal, window, prefix)
        while msk.ndim < logits.ndim:
            msk = msk[None]
        logits = jnp.where(msk, logits, NEG_INF)
        m_c = jnp.max(logits, axis=-1, keepdims=True)
        m_n = jnp.maximum(m_p, m_c)
        p = jnp.exp(logits - m_n)
        alpha = jnp.exp(m_p - m_n)
        l_n = alpha * l_p + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_n, l_n, acc), None

    state, _ = lax.scan(kv_step, state, (kc, vc, kpc))
    return state


def attn_ring(q, k, v, *, mesh, axis: str = "model", batch_axes=("data",),
              causal=True, window=0, softcap=0.0, chunk_k: int = 512):
    """Ring attention (context parallelism): the sequence dim of q/k/v is
    sharded over ``axis``; kv blocks circulate the ring via ppermute while
    each chip online-softmaxes its local queries against every block.

    Per-chip collective volume: (M-1)/M of the LOCAL kv (B_loc * S *
    Hkv * Dh * 2 * 2 bytes) per layer — orders less than gathering
    activations when d_model >> Hkv*Dh (GQA), which is what makes it the
    prefill hillclimb for the big dense archs.  q/k/v: [B, S, H*, Dh]
    logically global.
    """
    from jax.sharding import PartitionSpec as P
    from repro.utils.compat import shard_map, axis_size

    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bspec = tuple(batch_axes) if batch_axes else None

    # With no causal/window masking the positions are dead code, but old
    # jax lowers the leftover axis_index to a PartitionId the SPMD
    # partitioner rejects — skip computing them entirely.
    needs_pos = causal or not (isinstance(window, int) and window == 0)

    def body(q_l, k_l, v_l):
        M = axis_size(axis)
        bl, s_loc = q_l.shape[0], q_l.shape[1]
        if needs_pos:
            m_idx = lax.axis_index(axis)
            qpos = m_idx * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
        else:
            m_idx = 0
            qpos = jnp.zeros(s_loc, dtype=jnp.int32)
        qf = (q_l * jnp.asarray(dh ** -0.5, q_l.dtype))             .reshape(bl, s_loc, hkv, g, dh).transpose(0, 2, 3, 1, 4)
        m0 = jnp.full((bl, hkv, g, s_loc, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bl, hkv, g, s_loc, 1), jnp.float32)
        a0 = jnp.zeros((bl, hkv, g, s_loc, dh), jnp.float32)

        def stage(carry, j):
            (k_c, v_c), st = carry
            src_shard = (m_idx - j) % M
            kpos = src_shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
            st = _online_block(qf, k_c, v_c, qpos, kpos, st, causal,
                               window, softcap, chunk_k=chunk_k)
            perm = [(i, (i + 1) % M) for i in range(M)]
            k_c = lax.ppermute(k_c, axis, perm)
            v_c = lax.ppermute(v_c, axis, perm)
            return ((k_c, v_c), st), None

        ((_, _), (m_f, l_f, acc)), _ = lax.scan(
            stage, ((k_l, v_l), (m0, l0, a0)),
            jnp.arange(axis_size(axis)))
        out = acc / jnp.where(l_f > 0, l_f, 1.0)
        # [B,Hkv,G,Sq,Dh] -> [B,Sq,Hq,Dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(bl, s_loc, hq, dh)
        return out.astype(q_l.dtype)

    spec = P(bspec, axis, None, None)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=spec, check_vma=False)
    return fn(q, k, v)


def attn_decode(q, k_cache, v_cache, q_index, causal=True, window=0,
                softcap=0.0):
    """Single-token decode: q [B,1,Hq,Dh], caches [B,C,Hkv,Dh].
    q_index: current position (scalar or [B])."""
    b, _, hq, dh = q.shape
    c = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = hq // hkv
    qf = (q.astype(jnp.float32) * (dh ** -0.5)).reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    kpos = jnp.arange(c)
    qi = jnp.atleast_1d(jnp.asarray(q_index))[:, None]   # [B or 1, 1]
    valid = kpos[None, :] <= qi if causal else jnp.ones((1, c), bool)
    w = jnp.asarray(window)
    valid &= (w <= 0) | (kpos[None, :] > qi - w)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def attention_output(q, k, v, qpos, kpos, impl: str, causal=True, window=0,
                     softcap=0.0, chunk: int = 1024, prefix: int = 0):
    if impl == "ref":
        return attn_ref(q, k, v, qpos, kpos, causal, window, softcap, prefix)
    if impl == "chunked":
        return attn_chunked(q, k, v, qpos, kpos, causal, window, softcap,
                            chunk_q=chunk, chunk_k=chunk, prefix=prefix)
    if impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention
        # flash kernel wants [B,H,S,D] and static window/softcap
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            window=int(window), softcap=float(softcap))
        return o.transpose(0, 2, 1, 3)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# Parameterized sublayers
# ---------------------------------------------------------------------------

def init_attn(key, cfg, layers: Optional[int] = None):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    shp = (lambda *s: ((layers,) + s) if layers else s)
    scale = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], shp(d, qd), jnp.float32) * scale,
        "wk": jax.random.normal(ks[1], shp(d, kvd), jnp.float32) * scale,
        "wv": jax.random.normal(ks[2], shp(d, kvd), jnp.float32) * scale,
        "wo": jax.random.normal(ks[3], shp(qd, d), jnp.float32)
              * (qd ** -0.5) / max(cfg.n_layers, 1) ** 0.5,
    }


def init_mlp(key, cfg, layers: Optional[int] = None, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    shp = (lambda *s: ((layers,) + s) if layers else s)
    return {
        "w_gate": jax.random.normal(ks[0], shp(d, f), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[1], shp(d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], shp(f, d), jnp.float32)
                  * (f ** -0.5) / max(cfg.n_layers, 1) ** 0.5,
    }


def mlp_apply(p, x, act: str = "silu"):
    dt = x.dtype
    gate = x @ p["w_gate"].astype(dt)
    up = x @ p["w_up"].astype(dt)
    actv = jax.nn.silu if act == "silu" else jax.nn.gelu
    return (actv(gate) * up) @ p["w_down"].astype(dt)


def qkv_proj(p, x, cfg):
    """x [B,S,D] -> q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh]."""
    b, s, _ = x.shape
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def out_proj(p, o, x_dtype):
    b, s, hq, dh = o.shape
    return o.reshape(b, s, hq * dh) @ p["wo"].astype(x_dtype)


def init_embed(key, cfg):
    ks = jax.random.split(key, 3)
    vp = cfg.padded_vocab
    p = {
        "embedding": jax.random.normal(
            ks[0], (vp, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            ks[1], (cfg.d_model, vp), jnp.float32) \
            * cfg.d_model ** -0.5
    return p


def embed_tokens(p, tokens, cfg, dtype):
    x = jnp.take(p["embedding"], tokens, axis=0).astype(dtype)
    if cfg.family in ("vlm",):          # gemma-style embedding scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return x


def unembed(p, x, cfg):
    x = rms_norm(x, p["final_norm"])
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ p["embedding"].T.astype(jnp.float32)
    else:
        logits = x.astype(jnp.float32) @ p["lm_head"].astype(jnp.float32)
    logits = _softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, NEG_INF)
    return logits


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer sliding-window sizes [L] (0 = global/full attention)."""
    L = cfg.n_layers
    w = jnp.zeros((L,), jnp.int32)
    if cfg.window and cfg.local_global_period:
        # gemma2: even layers local, every `period`-th global
        ids = jnp.arange(L)
        w = jnp.where(ids % cfg.local_global_period == 0, cfg.window, 0)
    elif cfg.window:
        w = jnp.full((L,), cfg.window, jnp.int32)
        if cfg.global_layers:
            ids = jnp.arange(L)
            for gl in cfg.global_layers:
                w = jnp.where(ids == gl, 0, w)
    return w
