"""Multi-account detection: the two-hop motif
``(user1)-[e1]->(identifier)-[e2]->(user2)``.

The paper runs this on a 14.89B-vertex heterogeneous graph of users and
identifiers (emails, phones): two users are "the same" when one identifier
connects them directly.  GraphFrames solves it with Motif Finding; the
legacy Scalding job did a 3-step join with a MaxAdjacentNodes=100 cap
(losing 27.8% of edges, Table I).

TPU-native formulation: pack the identifier->users adjacency in ELL
(``[I, K]``); every unordered pair of valid slots in a row is a match.
The pair expansion is a statically-shaped ``[I, K*(K-1)/2, 2]`` tensor —
degree skew became padding at ETL time, so there is no shuffle and no
stragglers.  Deduplication across identifiers is one sort over packed
64-bit keys.  The count-only fast path never materializes pairs at all —
the workload class where the paper's local engine (Neo4j) dominates.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R

Array = jax.Array


def _pair_slots(k: int) -> tuple[np.ndarray, np.ndarray]:
    """Static upper-triangle slot indices for one ELL row of width K."""
    a, b = np.triu_indices(k, k=1)
    return a.astype(np.int32), b.astype(np.int32)


_SENT = np.int32(np.iinfo(np.int32).max)


@partial(jax.jit, static_argnames=())
def _expand_pairs(nbr: Array, mask: Array, slot_a: Array, slot_b: Array):
    """[I, K] rows -> canonical (lo, hi) pair columns [I*P] (int32).

    Invalid slots become (SENT, SENT) so they sort last.  (Pure int32 —
    the container runs without x64; a packed 64-bit key would be the TPU
    layout but lexsort on two int32 columns is numerically identical.)
    """
    u1 = nbr[:, slot_a]                      # [I, P]
    u2 = nbr[:, slot_b]
    valid = mask[:, slot_a] & mask[:, slot_b] & (u1 != u2)  # no self-pairs
    lo = jnp.where(valid, jnp.minimum(u1, u2), _SENT)
    hi = jnp.where(valid, jnp.maximum(u1, u2), _SENT)
    return lo.reshape(-1), hi.reshape(-1), valid.reshape(-1)


@partial(jax.jit, static_argnames=())
def _dedup_sorted(lo: Array, hi: Array):
    """Lexsort (lo, hi); unique = first occurrence of each pair."""
    order = jnp.lexsort((hi, lo))
    lo_s, hi_s = lo[order], hi[order]
    uniq = jnp.concatenate(
        [jnp.array([True]),
         (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1])])
    valid = lo_s != _SENT
    return lo_s, hi_s, uniq & valid


def two_hop_pairs(ell: G.GraphELL, n_users: int, dedup: bool = True):
    """All (user, user) matches.

    Returns ``(pairs [N_pad, 2] int32, valid [N_pad] bool, count)`` where
    ``count`` is the number of *distinct* pairs when ``dedup`` else total
    (with per-identifier multiplicity).
    """
    k = ell.max_degree
    sa, sb = _pair_slots(k)
    lo, hi, valid = _expand_pairs(ell.nbr, ell.mask, jnp.asarray(sa),
                                  jnp.asarray(sb))
    if not dedup:
        pairs = jnp.stack([lo, hi], axis=-1)
        return pairs, valid, jnp.sum(valid)
    lo_s, hi_s, uniq = _dedup_sorted(lo, hi)
    pairs = jnp.stack([lo_s, hi_s], axis=-1)
    return pairs, uniq, jnp.sum(uniq)


def two_hop_count_upper_bound(identifier_degrees: Array):
    """Count-only fast path: sum_i d_i*(d_i-1)/2 — no pair materialization.

    Upper bound on distinct matches (exact when no user pair shares two
    identifiers).  This is the 'return only a count' query class from the
    paper's Fig. 5 discussion.
    """
    d = identifier_degrees.astype(jnp.int32)
    return jnp.sum(d * (d - 1) // 2)


def multi_account_pairs(
    user_ids: np.ndarray,
    identifier_ids: np.ndarray,
    n_users: int,
    n_identifiers: int,
    max_adjacent_nodes: int = 100,
    dedup: bool = True,
):
    """End-to-end: (user, identifier) edge snapshot -> matched user pairs.

    Mirrors the production job: builds the identifier->users ELL adjacency
    (with the paper's MaxAdjacentNodes cap) and expands the motif.
    Returns ``(pairs, valid, count, ell)``.
    """
    ell = G.build_ell(
        src=np.asarray(user_ids), dst=np.asarray(identifier_ids),
        n_vertices=n_identifiers, max_degree=max_adjacent_nodes,
        direction="in",
    )
    # rows index identifiers; entries are user ids (sentinel n_users safe
    # because build_ell used n_identifiers as sentinel — remap it)
    nbr = jnp.where(ell.mask, ell.nbr, n_users)
    ell = G.GraphELL(nbr, ell.mask, ell.w, ell.n_vertices,
                     ell.n_edges, ell.n_edges_total)
    pairs, valid, count = two_hop_pairs(ell, n_users, dedup=dedup)
    return pairs, valid, count, ell


# ------------------------------------------------------------ registration

def _engine_run(eng, n_users=None, dedup=True, expected_pairs=None):
    """Motif expansion over the engine's cached ELL adjacency — both
    engines share the one built-once layout (padding slots are gated by
    the mask, so no sentinel remap is needed)."""
    pairs, valid, count = two_hop_pairs(
        eng.ell, n_users or eng.coo.n_vertices, dedup=dedup)
    return (pairs, valid, int(count)), None


def _engine_count(eng, **_):
    """Count-only fast path on *exact* COO in-degrees — identical on
    both engines (the capped ELL degrees the local engine previously
    used undercounted wherever the cap truncated a row)."""
    return int(two_hop_count_upper_bound(G.in_degrees(eng.coo))), None


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    rows = 1 if count_only else (params.get("expected_pairs")
                                 or max(g.n_edges * 4, g.n_vertices))
    return P.QuerySpec("two_hop", rows, iterations=1)


R.register(R.AlgorithmDef(
    name="two_hop",
    run=_engine_run,
    params=(
        R.Param("n_users", None, check=lambda n: n >= 1, normalize=int,
                doc="user-id space size for bipartite graphs "
                    "(defaults to n_vertices)"),
        R.Param("dedup", True, normalize=bool),
        R.Param("expected_pairs", None, check=lambda n: n >= 1,
                normalize=int, doc="planner hint: estimated output rows"),
    ),
    count_run=_engine_count,
    cost=_cost,
    method="two_hop_pairs",
    count_method="two_hop_count",
    example_params=None,    # output is O(V * K^2): fig6 benchmarks it
    doc="Multi-account two-hop motif over the ELL layout.",
))


def two_hop_reference(user_ids, identifier_ids, n_users):
    """Pure-python oracle: distinct user pairs sharing >=1 identifier."""
    from collections import defaultdict
    by_id = defaultdict(list)
    for u, i in zip(np.asarray(user_ids), np.asarray(identifier_ids)):
        by_id[int(i)].append(int(u))
    pairs = set()
    for users in by_id.values():
        us = sorted(set(users))
        for a in range(len(us)):
            for b in range(a + 1, len(us)):
                pairs.add((us[a], us[b]))
    return pairs
