"""Fig. 7 reproduction: combined connected users running time —
unified-graph CC in one XLA program (ours/GraphFrames-equivalent) vs the
legacy per-edge-set CC + merge pipeline.  Paper reports ~37x."""
from __future__ import annotations

import numpy as np

from benchmarks.common import time_fn, time_host, csv_row
from repro.core import graph as G
from repro.core.algorithms.connected_components import connected_components
from repro.core.algorithms.legacy import legacy_connected_users
from repro.data import synthetic as S


def run(out=print):
    rows = []
    for n_users in [2_000, 20_000, 100_000]:
        sets = S.identifier_edge_sets(n_users, n_sets=4, seed=3)
        allsrc = np.concatenate([s for s, _ in sets])
        alldst = np.concatenate([d for _, d in sets])
        g = G.build_coo(allsrc, alldst, n_users, symmetrize=True)

        t_ours, (labels, iters) = time_fn(
            lambda: connected_components(g))
        t_legacy, legacy_labels = time_host(
            legacy_connected_users, sets, n_users, iters=1)
        assert (np.asarray(labels) == legacy_labels).all()

        ratio = t_legacy / t_ours
        rows.append((n_users, t_ours, t_legacy, ratio))
        out(csv_row(f"fig7/unified_cc_u{n_users}", t_ours,
                    f"iters={int(iters)}"))
        out(csv_row(f"fig7/legacy_perset_u{n_users}", t_legacy,
                    f"speedup={ratio:.1f}x(paper:37x)"))

    # ablation (beyond-paper): pointer jumping turns O(diameter) label
    # propagation into O(log d) — decisive on long-chain components
    chain = np.arange(20_000 - 1)
    gch = G.build_coo(chain, chain + 1, 20_000, symmetrize=True)
    t_plain, (_, it_plain) = time_fn(
        lambda: connected_components(gch, accelerated=False,
                                     max_iters=30_000))
    t_jump, (_, it_jump) = time_fn(
        lambda: connected_components(gch, accelerated=True,
                                     max_iters=30_000))
    out(csv_row("fig7/ablation_cc_plain_chain20k", t_plain,
                f"iters={int(it_plain)}"))
    out(csv_row("fig7/ablation_cc_pointer_jump", t_jump,
                f"iters={int(it_jump)};speedup={t_plain/t_jump:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
