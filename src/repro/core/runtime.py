"""Service-runtime primitives: backpressure, retry schedules, latency
histograms.

The paper's platform fields interactive and batch analytics *side by
side* — a 10-minute batch table job must not freeze the "<2 s count"
traffic.  ``GraphAnalyticsService`` gets there with a small concurrent
runtime (``service.py``); this module holds the runtime's pure, testable
pieces:

* :class:`Backpressure` — the typed ``submit``-time rejection raised
  when a tier's queue is at its depth budget.  Like
  ``AdmissionRejected`` it carries enough context (tier, depth, budget)
  for the caller to decide between shedding and waiting.
* :class:`RetryPolicy` — jittered-exponential-backoff schedule for
  failed executions, fully deterministic given a seed: the k-th retry
  sleeps somewhere in ``[base_s, min(cap_s, base_s * multiplier**k)]``,
  so the *bounds* are monotone non-decreasing and every sleep lies in
  ``[base_s, cap_s]`` (the properties the hypothesis suite pins).
  ``max_attempts`` counts executions, not retries: a ticket is tried at
  most ``max_attempts`` times and the schedule therefore has
  ``max_attempts - 1`` entries.
* :class:`LatencyHistogram` — per-tier submit-to-resolution latency:
  log-spaced bucket counts for the ``metrics()`` snapshot plus a
  bounded raw-sample window for exact small-N percentiles (the
  benchmark's p50/p99 and the "interactive beats batch" assertion).
* :class:`PoolGate` — the per-pool in-flight cap of the federation
  runtime: workers claiming a unit bound for pool P must acquire P's
  slot first, so a pool with ``max_inflight=1`` never runs two units
  at once even when several contexts could.
* :class:`TransferLedger` — per-pool transfer accounting: every
  non-resident execution records the snapshot bytes it had to move,
  the number behind ``metrics()['pools'][*]['transfer_bytes']``.
"""
from __future__ import annotations

import bisect
import dataclasses
import random
import threading
from collections import deque
from typing import Any, Mapping, Optional

from repro.core import obs


class Backpressure(Exception):
    """Raised by ``submit`` when the destination queue is at its tier's
    depth budget.  The query was *not* admitted; nothing is queued.
    Carries the tier and the depths so callers can tell load shedding
    ("batch is full, come back later") from a misconfigured budget."""

    def __init__(self, graph_name: str, query: Any, engine: str, tier: str,
                 depth: int, budget: int):
        self.graph_name = graph_name
        self.query = query
        self.engine = engine
        self.tier = tier
        self.depth = depth
        self.budget = budget
        super().__init__(
            f"query {query.algorithm!r} on {graph_name!r} rejected: "
            f"{tier} queue for engine {engine!r} is at its depth budget "
            f"({depth}/{budget})")


# ---------------------------------------------------------------------------
# Retry / backoff
# ---------------------------------------------------------------------------

#: Exception types that retrying can never fix: schema violations and
#: lookup errors are properties of the query, not of the attempt.
PERMANENT_ERRORS = (ValueError, TypeError, KeyError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff with a dead-letter bound.

    ``max_attempts`` is the total number of *executions* a ticket may
    consume (first try included); after the last one fails the ticket
    dead-letters.  The sleep before retry ``k`` (0-indexed) is drawn
    uniformly from ``[base_s, bound_k]`` with
    ``bound_k = min(cap_s, base_s * multiplier**k)`` — full jitter above
    a floor, so concurrent retries decorrelate while the schedule's
    upper envelope stays monotone and capped.
    """

    max_attempts: int = 3
    base_s: float = 0.002
    cap_s: float = 0.25
    multiplier: float = 2.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0 <= self.base_s <= self.cap_s:
            raise ValueError("need 0 <= base_s <= cap_s")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")

    def bounds(self) -> tuple[float, ...]:
        """Upper bound of each retry's sleep — monotone non-decreasing,
        clipped at ``cap_s``; one entry per retry (``max_attempts - 1``)."""
        return tuple(min(self.cap_s, self.base_s * self.multiplier ** k)
                     for k in range(self.max_attempts - 1))

    def schedule(self, seed: int) -> tuple[float, ...]:
        """The actual jittered sleeps for one ticket, deterministic in
        ``seed`` (the service derives it from its own seed and the
        ticket id, so a replayed drain sleeps identically)."""
        rng = random.Random(int(seed))
        return tuple(self.base_s + rng.random() * (b - self.base_s)
                     for b in self.bounds())

    @staticmethod
    def retryable(error: BaseException) -> bool:
        """Whether another attempt could plausibly succeed.  Schema and
        lookup errors (:data:`PERMANENT_ERRORS`) are deterministic
        functions of the query — they dead-letter immediately instead
        of burning ``max_attempts`` identical failures."""
        return not isinstance(error, PERMANENT_ERRORS)


# ---------------------------------------------------------------------------
# Latency histograms
# ---------------------------------------------------------------------------

def _log_bounds() -> tuple[float, ...]:
    # 10 us .. 100 s, half-decade steps — wide enough for both a cache
    # hit and a 10-minute batch job's neighbours.
    return tuple(10.0 ** (k / 2.0) for k in range(-10, 5))


class LatencyHistogram:
    """Latency recorder behind ``metrics()``: log-spaced bucket counts
    (cheap, unbounded history) plus a bounded window of raw samples for
    exact percentiles.  Not thread-safe on its own — the service
    observes under its runtime lock."""

    def __init__(self, max_samples: int = 4096):
        self.bounds = _log_bounds()
        self.counts = [0] * (len(self.bounds) + 1)   # last = overflow
        self.count = 0
        self.total_s = 0.0
        self._samples: deque = deque(maxlen=max_samples)

    def observe(self, seconds: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, seconds)] += 1
        self.count += 1
        self.total_s += seconds
        self._samples.append(seconds)

    def percentile(self, p: float) -> Optional[float]:
        """Exact percentile over the retained sample window (the whole
        history while fewer than ``max_samples`` observations)."""
        if not self._samples:
            return None
        xs = sorted(self._samples)
        rank = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[rank]

    def snapshot(self) -> dict:
        """The ``metrics()`` view: totals, p50/p99 over the retained
        window, and cumulative ``le``-style bucket counts.

        The quantiles are **exact only while every observation is still
        retained** (``count <= max_samples``); under longer drains the
        raw window is a bounded deque, the oldest samples age out, and
        p50/p99 silently become *window-local* quantiles over the most
        recent ``window_size`` observations.  ``window_exact`` makes
        that visible: ``True`` means whole-history quantiles,
        ``False`` means rolling-window.  The bucket counts are always
        whole-history (they never age out) — percentiles needing exact
        long-horizon answers should derive from ``buckets``."""
        cum, acc = {}, 0
        for b, c in zip(self.bounds, self.counts):
            acc += c
            cum[f"le_{b:.0e}"] = acc
        cum["le_inf"] = self.count
        return {
            "count": self.count,
            "mean_s": (self.total_s / self.count) if self.count else None,
            "p50_s": self.percentile(50),
            "p99_s": self.percentile(99),
            "window_exact": self.count <= self._samples.maxlen,
            "window_size": len(self._samples),
            "buckets": cum,
        }


# ---------------------------------------------------------------------------
# Federation runtime primitives
# ---------------------------------------------------------------------------

class PoolGate:
    """Per-pool in-flight caps for the worker pool.

    ``caps`` maps pool name to its ``max_inflight`` (``None`` or a
    missing name = unbounded — unknown pools, and the poolset-free
    legacy path, always pass).  ``try_acquire`` is non-blocking: a
    worker that cannot enter a pool parks the queue and scans on, the
    same protocol as a busy (context, engine) pair.
    """

    def __init__(self, caps: Optional[Mapping[str, Optional[int]]] = None):
        self._caps = dict(caps or {})
        self._inflight: dict[str, int] = {}
        self._lock = threading.Lock()

    def try_acquire(self, pool: Optional[str]) -> bool:
        if pool is None:
            return True
        with self._lock:
            cap = self._caps.get(pool)
            n = self._inflight.get(pool, 0)
            if cap is not None and n >= cap:
                return False
            self._inflight[pool] = n + 1
            return True

    def release(self, pool: Optional[str]) -> None:
        if pool is None:
            return
        with self._lock:
            n = self._inflight.get(pool, 0)
            if n <= 0:
                raise RuntimeError(f"release of idle pool {pool!r}")
            self._inflight[pool] = n - 1

    def inflight(self, pool: str) -> int:
        with self._lock:
            return self._inflight.get(pool, 0)


class TransferLedger:
    """Thread-safe per-pool transfer accounting: how many snapshot
    bytes each pool pulled across the link to serve non-resident work
    (and how many distinct transfers)."""

    def __init__(self):
        self._bytes: dict[str, int] = {}
        self._count: dict[str, int] = {}
        self._lock = threading.Lock()

    def record(self, pool: str, n_bytes: int) -> None:
        with self._lock:
            self._bytes[pool] = self._bytes.get(pool, 0) + int(n_bytes)
            self._count[pool] = self._count.get(pool, 0) + 1
        obs.emit("transfer", pool=pool, bytes=int(n_bytes))

    def bytes_for(self, pool: str) -> int:
        with self._lock:
            return self._bytes.get(pool, 0)

    def transfers_for(self, pool: str) -> int:
        with self._lock:
            return self._count.get(pool, 0)

    def snapshot(self) -> dict:
        with self._lock:
            return {p: {"transfer_bytes": self._bytes.get(p, 0),
                        "transfers": self._count.get(p, 0)}
                    for p in sorted(set(self._bytes) | set(self._count))}


class IncrementalMeter:
    """Thread-safe counters for the incremental/warm-start execution
    paths: how often the catalog's lineage actually paid off.

    ``warm_hits`` counts executions seeded from an ancestor's converged
    vector; ``incremental_runs`` counts localized repairs seeded from
    the direct parent's result plus the recorded delta;
    ``iterations_saved`` accumulates the per-run iteration reduction
    (the seed's converged iteration count minus the seeded run's — the
    ancestor's cold cost standing in for this snapshot's, since the
    whole point is never paying the cold run); ``delta_bytes_applied``
    accumulates the delta payloads consumed by incremental repairs.
    """

    def __init__(self):
        self._warm = 0
        self._incremental = 0
        self._iters_saved = 0
        self._delta_bytes = 0
        self._lock = threading.Lock()

    def record(self, mode: str, iterations_saved: int = 0,
               delta_bytes: int = 0) -> None:
        with self._lock:
            if mode == "warm":
                self._warm += 1
            elif mode == "incremental":
                self._incremental += 1
            self._iters_saved += max(int(iterations_saved), 0)
            self._delta_bytes += max(int(delta_bytes), 0)

    def snapshot(self) -> dict:
        with self._lock:
            return {"warm_hits": self._warm,
                    "incremental_runs": self._incremental,
                    "iterations_saved": self._iters_saved,
                    "delta_bytes_applied": self._delta_bytes}
