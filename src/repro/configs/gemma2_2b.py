"""Gemma-2 2B [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local(4096-window)/global alternating attention, attn logit softcap 50,
final logit softcap 30, post-norms (RMSNorm after attn and mlp outputs),
GeGLU MLP, head_dim 256, tied embeddings (vocab 256k dominates params).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab_size=256000,
    window=4096,
    local_global_period=2,      # layers 0,2,4,... local; 1,3,5,... global
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    mlp_act="gelu",
    tie_embeddings=True,
)
