"""AdamW + schedules, implemented directly (no optax on the box).

Optimizer state is a pytree congruent with params, so FSDP sharding
rules apply to ``m``/``v`` verbatim — sharding the optimizer over the
``data`` axis is what makes the 100B+ archs fit (ZeRO-style).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils.tree import global_norm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio*peak."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.peak_lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, master_copy: bool = False) -> dict:
    """master_copy=True keeps an f32 master alongside bf16 params (the
    mixed-precision layout: bf16 wire/compute copy is what FSDP gathers,
    halving gather traffic and the gathered footprint)."""
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros,
             "v": jax.tree_util.tree_map(jnp.zeros_like, zeros),
             "step": jnp.zeros((), jnp.int32)}
    if master_copy:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v, master):
        ref = master if master is not None else p.astype(jnp.float32)
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        new_ref = ref - lr * (step_ + cfg.weight_decay * ref)
        return new_ref.astype(p.dtype), m, v, new_ref

    has_master = "master" in state
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_ref = (tdef.flatten_up_to(state["master"]) if has_master
                else [None] * len(flat_p))
    out = [upd(p, g, m, v, r) for p, g, m, v, r in
           zip(flat_p, flat_g, flat_m, flat_v, flat_ref)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    if has_master:
        new_state["master"] = jax.tree_util.tree_unflatten(
            tdef, [o[3] for o in out])
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
