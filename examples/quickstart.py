"""Quickstart: the unified graph-analytics platform in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import graph as G
from repro.core.query import GraphQuery, GraphPlatform
from repro.data import synthetic as S

# 1. A user-follow-style graph (power-law, directed).
src, dst = S.user_follow_graph(n_users=10_000, mean_degree=6.0, seed=0)
coo = G.build_coo(src, dst, 10_000, symmetrize=False)

# 2. The platform owns both engines; the planner routes each query.
platform = GraphPlatform(coo, n_data=4)

# 3. PageRank (the paper's recommendation-team workload).
r = platform.query(GraphQuery.pagerank(max_iters=50))
top = np.argsort(np.asarray(r.value))[-5:][::-1]
print(f"pagerank via {r.engine} in {r.iterations} iters; top users: {top}")
print("  plan:", r.meta["plan"].reason)

# 4. Connected components on the symmetrized graph — count-only fast path
#    (the query class where the paper's local engine wins by 300x).
sym = G.build_coo(src, dst, 10_000, symmetrize=True)
platform2 = GraphPlatform(sym, n_data=4)
r = platform2.query(GraphQuery.connected_components(count_only=True))
print(f"connected components: {r.value} via {r.engine}")

# 5. Multi-account detection: two-hop motif on a user<->identifier graph.
users, ids = S.safety_bipartite_graph(2_000, 800, seed=1)
bip = G.build_coo(users, ids, int(max(users.max(), ids.max())) + 1)
plat3 = GraphPlatform(bip)
r = plat3.query(GraphQuery.two_hop(n_users=2_000, count_only=True))
print(f"candidate same-user pairs (upper bound): {r.value} via {r.engine}")

# 6. The broader suite, all through the same platform: traversal,
#    communities, cohesion — each with its count-only fast path.
r = platform2.query(GraphQuery.bfs([0], count_only=True))
print(f"reachable from user 0: {r.value} via {r.engine}")
r = platform2.query(GraphQuery.label_propagation(count_only=True))
print(f"communities (label propagation): {r.value} via {r.engine}")
r = platform2.query(GraphQuery.k_core(5, count_only=True))
print(f"5-core size: {r.value} via {r.engine}")
dist = platform.query(GraphQuery.sssp(0)).value
print(f"sssp from user 0: {np.isfinite(np.asarray(dist)).sum()} reachable")
