"""GraphQuery — the unified interface layer (paper Section III-A).

The paper's stack puts "a unified user interface ... and code templates"
above the engines so users never pick Spark-vs-Neo4j by hand.  This is
that layer: a small declarative query object + ``GraphPlatform`` which
owns both engines and routes through the cost-based planner.

    platform = GraphPlatform(coo, mesh=mesh)
    r = platform.query(GraphQuery.connected_components(count_only=True))
    r.value, r.engine, r.meta['plan']
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import graph as G
from repro.core import planner as P
from repro.core.engines import LocalEngine, DistributedEngine, QueryResult


@dataclasses.dataclass(frozen=True)
class GraphQuery:
    algorithm: str                      # pagerank | connected_components | two_hop | degree_stats
    count_only: bool = False
    params: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def pagerank(cls, alpha=0.85, tol=1e-8, max_iters=100):
        return cls("pagerank", False,
                   {"alpha": alpha, "tol": tol, "max_iters": max_iters})

    @classmethod
    def connected_components(cls, count_only=False, max_iters=200):
        return cls("connected_components", count_only, {"max_iters": max_iters})

    @classmethod
    def two_hop(cls, n_users: int, count_only=False, dedup=True):
        return cls("two_hop", count_only, {"n_users": n_users, "dedup": dedup})

    @classmethod
    def degree_stats(cls):
        return cls("degree_stats", True, {})


class GraphPlatform:
    """Owns both engines; routes each query through the planner."""

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None):
        self.coo = coo
        self.mesh = mesh
        self.stats = P.GraphStats.of(coo)
        self.force_engine = force_engine
        self._local: Optional[LocalEngine] = None
        self._dist: Optional[DistributedEngine] = None
        self._local_max_degree = local_max_degree
        self._n_data, self._n_model = n_data, n_model
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self.n_chips = 1
            for s in mesh.devices.shape:
                self.n_chips *= s
        else:
            self.n_chips = max(n_data * n_model, 1)

    # lazy engine construction: building ELL/partitions is ETL work we
    # only pay when the planner actually routes there.
    @property
    def local(self) -> LocalEngine:
        if self._local is None:
            self._local = LocalEngine(self.coo, self._local_max_degree)
        return self._local

    @property
    def distributed(self) -> DistributedEngine:
        if self._dist is None:
            self._dist = DistributedEngine(self.coo, mesh=self.mesh,
                                           n_data=self._n_data,
                                           n_model=self._n_model)
        return self._dist

    def plan(self, q: GraphQuery) -> P.Plan:
        spec = P.spec_for(q.algorithm, self.stats, count_only=q.count_only)
        plan = P.choose_engine(self.stats, spec, self.n_chips)
        if self.force_engine:
            plan = dataclasses.replace(plan, engine=self.force_engine,
                                       reason=f"forced: {self.force_engine}")
        return plan

    def query(self, q: GraphQuery) -> QueryResult:
        plan = self.plan(q)
        eng = self.local if plan.engine == "local" else self.distributed
        if q.algorithm == "pagerank":
            r = eng.pagerank(**q.params)
        elif q.algorithm == "connected_components":
            r = (eng.num_components(**q.params) if q.count_only
                 else eng.connected_components(**q.params))
        elif q.algorithm == "two_hop":
            if q.count_only:
                r = eng.two_hop_count()
            else:
                r = eng.two_hop_pairs(q.params["n_users"],
                                      dedup=q.params.get("dedup", True))
        elif q.algorithm == "degree_stats":
            r = eng.degree_stats()
        else:
            raise ValueError(q.algorithm)
        r.meta["plan"] = plan
        return r
