"""Traversal workloads: BFS hop distance and weighted SSSP.

Both are one ``PregelSpec`` over the min-monoid — the relaxation

    dist[v] <- min(dist[v], min_{(u,v) in E} dist[u] + cost(u, v))

with ``cost = 1`` (BFS) or ``cost = w`` (SSSP, Bellman-Ford).  The whole
frontier expansion runs as one XLA while-loop on either engine; the
count-only fast path (``reachable_count``) returns the size of the
reachable set without materializing the distance table — the query class
where the paper's local engine wins by orders of magnitude (Fig. 5).

Distances are float32 with ``inf`` for unreachable vertices.  Edge
weights must be non-negative for SSSP (Bellman-Ford converges in at most
V-1 supersteps; the ``halt`` fixpoint check stops far earlier on
small-diameter social graphs).
"""
from __future__ import annotations

import heapq
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import (PregelSpec, batched_spec, converged_halt,
                               run_pregel)


def _relax_apply(dist, agg, ids, gval):
    return jnp.minimum(dist, agg)


def _finite_frontier(dist):
    """Activity predicate: only a vertex with a finite distance can
    improve a neighbor (inf + cost == inf is a no-op under min)."""
    return jnp.isfinite(dist)


# Both relaxations declare the full superstep-variant contract: the
# message is elementwise in (src_state, w); the min fold makes frontier
# compression *exact* ('monotone' — an unchanged source already
# delivered its message, and apply folded it into state permanently);
# and min tolerates reduced-precision message channels by construction.
_BFS_SPEC = PregelSpec(
    message=lambda d, w: d + 1.0,
    combine="min", apply=_relax_apply, identity=float("inf"),
    halt=converged_halt, elementwise_message=True,
    frontier_mode="monotone", frontier_init=_finite_frontier)

_SSSP_SPEC = PregelSpec(
    message=lambda d, w: d + w,
    combine="min", apply=_relax_apply, identity=float("inf"),
    halt=converged_halt, elementwise_message=True,
    frontier_mode="monotone", frontier_init=_finite_frontier)


def _init_distances(sources, V: int, n_pad: int) -> jnp.ndarray:
    init = np.full(n_pad, np.inf, dtype=np.float32)
    init[np.asarray(sources, dtype=np.int64)] = 0.0
    return jnp.asarray(init)


def _run_relaxation(spec, g: G.GraphCOO, sources, max_iters, mesh,
                    n_data, n_model, sharded: Optional[ShardedCOO]):
    if max_iters is None:
        # worst case (path graph) needs V-1 relaxation rounds; the halt
        # check exits the while-loop at the fixpoint, so the generous
        # bound costs nothing on small-diameter graphs
        max_iters = g.n_vertices
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    init = _init_distances(sources, g.n_vertices, sharded.n_pad)
    dist, iters = run_pregel(spec, sharded, init, max_iters, mesh=mesh)
    return dist[: g.n_vertices], iters


def bfs_distances(
    g: G.GraphCOO,
    sources: Sequence[int],
    max_iters: Optional[int] = None,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Hop distance from the source set along directed edges.

    Returns ``(dist [V] float32 with inf = unreachable, iters)``.
    ``max_iters=None`` (default) guarantees convergence; an explicit
    smaller bound truncates distances beyond that many hops to inf.
    """
    return _run_relaxation(_BFS_SPEC, g, sources, max_iters, mesh,
                           n_data, n_model, sharded)


def sssp(
    g: G.GraphCOO,
    source: int,
    max_iters: Optional[int] = None,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Single-source weighted shortest paths (non-negative weights).
    ``max_iters=None`` (default) guarantees Bellman-Ford convergence."""
    return _run_relaxation(_SSSP_SPEC, g, [source], max_iters, mesh,
                           n_data, n_model, sharded)


def reachable_count(dist) -> int:
    """Count-only fast path: |{v : dist[v] < inf}| — never materializes
    the distance table on the host."""
    return int(jnp.sum(jnp.isfinite(dist)))


# ------------------------------------------------------------ registration
#
# BFS and SSSP register their PregelSpec *as* the runner: the generic
# engine drives run_pregel, and the definition only supplies the initial
# state.  This is the purest "algorithm as data" form the registry
# supports.

def _bfs_init(eng, params):
    mi = params["max_iters"]
    if mi is None:
        mi = eng.coo.n_vertices
    return _init_distances(params["sources"], eng.coo.n_vertices,
                           eng.sharded.n_pad), mi


def _sssp_init(eng, params):
    mi = params["max_iters"]
    if mi is None:
        mi = eng.coo.n_vertices
    return _init_distances([params["source"]], eng.coo.n_vertices,
                           eng.sharded.n_pad), mi


def _sources_tuple(s):
    return tuple(int(x) for x in np.atleast_1d(np.asarray(s)))


# Fused batch runners: K relaxations with different sources are one
# pregel program over [V, K] state (batched_spec lifts the scalar spec
# onto a trailing batch axis).  The min monoid is exact per column, so
# column k is bit-identical to running query k alone — the service's
# fusion contract.  Queries fuse only within an equal max_iters group
# (the fuse key), so the shared loop bound is every ticket's own.

def _relax_batch(spec, eng, source_sets, max_iters):
    V = eng.coo.n_vertices
    mi = max_iters if max_iters is not None else V
    init = np.full((eng.sharded.n_pad, len(source_sets)), np.inf,
                   dtype=np.float32)
    for b, sources in enumerate(source_sets):
        init[np.asarray(sources, dtype=np.int64), b] = 0.0
    # batched_spec propagates the superstep-variant declarations, so the
    # fused batch rides the frontier/fused path where supported — still
    # bit-identical per column (min is exact under any strategy).
    dist, iters = eng.run_superstep(batched_spec(spec), jnp.asarray(init),
                                    mi, variant="auto")
    values = [dist[:V, b] for b in range(len(source_sets))]
    return values, int(iters), {"pregel_calls": 1}


def _bfs_batch(eng, params_list):
    return _relax_batch(_BFS_SPEC, eng,
                        [p["sources"] for p in params_list],
                        params_list[0]["max_iters"])


def _sssp_batch(eng, params_list):
    return _relax_batch(_SSSP_SPEC, eng,
                        [(p["source"],) for p in params_list],
                        params_list[0]["max_iters"])


def _relax_fuse_key(params):
    return ("max_iters", params["max_iters"])


def _relax_incremental(spec, eng, sources, seed, delta):
    """Localized repair for *add-only* deltas: seed distances from the
    ancestor's converged table (old distances are path lengths still
    achievable in the new graph, hence elementwise upper bounds) and the
    frontier from the delta's touched endpoints.  The min relaxation
    from that state reaches exactly the cold fixpoint — and since every
    distance is a deterministic along-path float sum, byte-identical to
    a cold run.  Removals can lengthen distances (values would need to
    rise), so those decline; so does a run that exhausts its iteration
    budget before the halt fires (parity is only proven at the
    fixpoint)."""
    if delta is None or delta.n_removed:
        return None
    prev = np.asarray(getattr(seed, "value", seed))
    V = eng.coo.n_vertices
    if prev.ndim != 1 or prev.shape[0] > V or prev.dtype.kind != "f":
        return None
    mi = V
    init = np.full(eng.sharded.n_pad, np.inf, dtype=np.float32)
    init[: prev.shape[0]] = prev
    init[np.asarray(sources, dtype=np.int64)] = 0.0
    act = np.zeros(V, dtype=bool)
    touched = np.asarray(delta.touched)
    act[touched[touched < V]] = True
    dist, iters = eng.run_superstep(spec, jnp.asarray(init), mi,
                                    variant="auto",
                                    init_active=jnp.asarray(act))
    if int(iters) >= mi:
        return None
    return dist[:V], int(iters)


def _bfs_incremental(eng, params, seed, delta):
    # an explicit max_iters truncates distances beyond that many hops —
    # trajectory-dependent semantics a warm seed cannot reproduce
    if params["max_iters"] is not None:
        return None
    return _relax_incremental(_BFS_SPEC, eng, params["sources"], seed,
                              delta)


def _sssp_incremental(eng, params, seed, delta):
    if params["max_iters"] is not None:
        return None
    return _relax_incremental(_SSSP_SPEC, eng, (params["source"],), seed,
                              delta)


def _bfs_cost(g: P.GraphStats, params: dict, count_only: bool):
    # small-world graphs: effective diameter ~ a dozen supersteps
    iters = min(12, params.get("max_iters") or 12)
    return P.superstep_specs("bfs",
                             output_rows=1 if count_only else g.n_vertices,
                             iterations=iters, state_bytes_per_vertex=4.0)


def _sssp_cost(g: P.GraphStats, params: dict, count_only: bool):
    # weighted relaxation settles slower than hop distance
    iters = min(24, params.get("max_iters") or 24)
    return P.superstep_specs("sssp",
                             output_rows=1 if count_only else g.n_vertices,
                             iterations=iters, state_bytes_per_vertex=4.0)


R.register(R.AlgorithmDef(
    name="bfs",
    run=_BFS_SPEC,
    init=_bfs_init,
    params=(
        R.Param("sources", R.REQUIRED, normalize=_sources_tuple),
        R.Param("max_iters", None, check=lambda n: n >= 1, normalize=int),
    ),
    count=reachable_count,
    count_method="reachable_count",
    cost=_bfs_cost,
    variants=R.superstep_variants(_BFS_SPEC),
    batch_runner=_bfs_batch,
    fuse=_relax_fuse_key,
    incremental=_bfs_incremental,
    example_params={"sources": (0,)},
    doc="Hop distances from a source set along directed edges.",
))

R.register(R.AlgorithmDef(
    name="sssp",
    run=_SSSP_SPEC,
    init=_sssp_init,
    params=(
        R.Param("source", R.REQUIRED, normalize=int),
        R.Param("max_iters", None, check=lambda n: n >= 1, normalize=int),
    ),
    cost=_sssp_cost,
    variants=R.superstep_variants(_SSSP_SPEC),
    batch_runner=_sssp_batch,
    fuse=_relax_fuse_key,
    incremental=_sssp_incremental,
    example_params={"source": 0},
    doc="Single-source weighted shortest paths (non-negative weights).",
))


# ---------------------------------------------------------------- oracles

def bfs_reference(src, dst, n_vertices: int, sources) -> np.ndarray:
    """Queue BFS oracle (host) for tests."""
    adj = [[] for _ in range(n_vertices)]
    for s, d in zip(np.asarray(src), np.asarray(dst)):
        adj[int(s)].append(int(d))
    dist = np.full(n_vertices, np.inf, dtype=np.float32)
    from collections import deque
    q = deque()
    for s in sources:
        dist[int(s)] = 0.0
        q.append(int(s))
    while q:
        u = q.popleft()
        for v in adj[u]:
            if not np.isfinite(dist[v]):
                dist[v] = dist[u] + 1.0
                q.append(v)
    return dist


def sssp_reference(src, dst, w, n_vertices: int, source: int) -> np.ndarray:
    """Dijkstra oracle (host) for tests — non-negative weights."""
    adj = [[] for _ in range(n_vertices)]
    for s, d, ww in zip(np.asarray(src), np.asarray(dst), np.asarray(w)):
        adj[int(s)].append((int(d), float(ww)))
    dist = np.full(n_vertices, np.inf, dtype=np.float64)
    dist[source] = 0.0
    heap = [(0.0, source)]
    while heap:
        du, u = heapq.heappop(heap)
        if du > dist[u]:
            continue
        for v, ww in adj[u]:
            nd = du + ww
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist.astype(np.float32)
