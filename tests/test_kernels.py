"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes.  Hypothesis property tests live in
``test_kernels_properties.py`` (skipped when ``hypothesis`` is absent).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ell_combine.ops import ell_spmv, ell_spmv_ref
from repro.kernels.ell_intersect.ops import (
    ell_intersect, ell_intersect_rows_ref)
from repro.kernels.pregel_superstep import fused_superstep, fused_superstep_ref
from repro.kernels.pregel_superstep import ops as superstep_ops
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_reference


# ---------------------------------------------------------------- ell_combine

@pytest.mark.parametrize("v,k,vx", [(64, 16, 80), (300, 37, 400),
                                    (1024, 128, 1024), (17, 200, 33)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_ell_combine_shapes(v, k, vx, op):
    rng = np.random.default_rng(v + k)
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < 0.7)
    w = jnp.asarray(rng.standard_normal((v, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(vx), jnp.float32)
    got = ell_spmv(nbr, mask, w, x, op=op)
    want = ell_spmv_ref(nbr, mask, w, x, op=op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ell_combine_empty_rows():
    """Vertices without neighbors get the monoid identity."""
    nbr = jnp.zeros((8, 4), jnp.int32)
    mask = jnp.zeros((8, 4), bool)
    w = jnp.ones((8, 4), jnp.float32)
    x = jnp.ones((16,), jnp.float32)
    assert (np.asarray(ell_spmv(nbr, mask, w, x, op="sum")) == 0).all()
    assert np.isinf(np.asarray(ell_spmv(nbr, mask, w, x, op="min"))).all()


def test_ell_spmv_matches_dense_matmul():
    """ELL SpMV == dense A @ x for a random sparse matrix."""
    rng = np.random.default_rng(3)
    v, k, vx = 50, 12, 50
    nbr = rng.integers(0, vx, (v, k)).astype(np.int32)
    mask = rng.random((v, k)) < 0.5
    w = rng.standard_normal((v, k)).astype(np.float32)
    dense = np.zeros((v, vx), np.float32)
    for i in range(v):
        for j in range(k):
            if mask[i, j]:
                dense[i, nbr[i, j]] += w[i, j]
    x = rng.standard_normal(vx).astype(np.float32)
    got = np.asarray(ell_spmv(jnp.asarray(nbr), jnp.asarray(mask),
                              jnp.asarray(w), jnp.asarray(x), op="sum"))
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ pregel_superstep

def _relax(s, w):
    return s + w


@pytest.mark.parametrize("v,k,vx", [(64, 16, 80), (300, 37, 400),
                                    (1024, 128, 1024), (17, 200, 33)])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_superstep_shapes(v, k, vx, op):
    """Pallas (interpret on CPU) vs fused jnp reference over ragged
    shapes that exercise row-block and 128-lane padding."""
    rng = np.random.default_rng(v + k)
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < 0.7)
    w = jnp.asarray(rng.standard_normal((v, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(vx), jnp.float32)
    identity = 0.0 if op == "sum" else float("inf") * (1 if op == "min"
                                                       else -1)
    got = fused_superstep(nbr, mask, w, x, message=_relax, op=op,
                          identity=identity)
    want = fused_superstep_ref(nbr, mask, w, x, message=_relax, op=op,
                               identity=identity)
    assert got.shape == (v,)
    if op == "sum":
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    else:
        # min/max select, they never round: bit-identical
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_superstep_empty_rows_get_fill():
    """Vertices with no active in-edges get the dense-path fill: the
    monoid identity for min/max, 0 for sum (segment-sum semantics)."""
    nbr = jnp.zeros((8, 4), jnp.int32)
    mask = jnp.zeros((8, 4), bool)
    w = jnp.ones((8, 4), jnp.float32)
    x = jnp.ones((16,), jnp.float32)
    for fn in (fused_superstep, fused_superstep_ref):
        s = np.asarray(fn(nbr, mask, w, x, message=_relax, op="sum",
                          identity=0.0))
        assert (s == 0).all()
        m = np.asarray(fn(nbr, mask, w, x, message=_relax, op="min",
                          identity=float("inf")))
        assert np.isinf(m).all() and (m > 0).all()


def test_superstep_sentinel_neighbors_masked_out():
    """Padding slots point at the sentinel row (index >= V); masked off,
    they must contribute nothing even though the gather clips them."""
    vx = 12
    nbr = jnp.full((4, 8), vx, jnp.int32)
    nbr = nbr.at[0, 0].set(3)
    mask = jnp.zeros((4, 8), bool).at[0, 0].set(True)
    w = jnp.full((4, 8), 100.0, jnp.float32)
    x = jnp.arange(vx, dtype=jnp.float32)
    for fn in (fused_superstep, fused_superstep_ref):
        got = np.asarray(fn(nbr, mask, w, x, message=_relax, op="min",
                            identity=float("inf")))
        assert got[0] == 103.0
        assert np.isinf(got[1:]).all()


def test_superstep_vmem_budget_falls_back_exact(monkeypatch):
    """Over-budget gather source silently routes to the reference — same
    bits out."""
    rng = np.random.default_rng(7)
    v, k, vx = 128, 9, 200
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < 0.6)
    w = jnp.asarray(rng.standard_normal((v, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(vx), jnp.float32)
    want = fused_superstep(nbr, mask, w, x, message=_relax, op="min",
                           identity=float("inf"))
    monkeypatch.setattr(superstep_ops, "VMEM_X_BUDGET_BYTES", 64)
    got = fused_superstep(nbr, mask, w, x, message=_relax, op="min",
                          identity=float("inf"))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_superstep_trailing_state_dims_use_reference():
    """[V, C] state (fused-batch programs) is out of the Pallas contract;
    the wrapper must fall back and still reduce per-channel."""
    rng = np.random.default_rng(11)
    v, k, vx, c = 32, 5, 40, 3
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < 0.7)
    w = jnp.asarray(rng.standard_normal((v, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((vx, c)), jnp.float32)
    msg = lambda s, w_: s + w_[..., None]
    got = fused_superstep(nbr, mask, w, x, message=msg, op="min",
                          identity=float("inf"))
    assert got.shape == (v, c)
    want = fused_superstep_ref(nbr, mask, w, x, message=msg, op="min",
                               identity=float("inf"))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_superstep_message_dtype_rounds_before_combine():
    """A bf16 channel rounds each message identically on both paths, so
    min stays bit-identical — the mixed-precision contract."""
    rng = np.random.default_rng(13)
    v, k, vx = 96, 7, 96
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < 0.7)
    w = jnp.asarray(rng.random((v, k)), jnp.float32)
    x = jnp.asarray(rng.random(vx), jnp.float32)
    got = fused_superstep(nbr, mask, w, x, message=_relax, op="min",
                          identity=float("inf"), message_dtype="bfloat16")
    want = fused_superstep_ref(nbr, mask, w, x, message=_relax, op="min",
                               identity=float("inf"),
                               message_dtype="bfloat16")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


# --------------------------------------------------------------- ell_intersect

def _sorted_rows(rng, e, k, vx, fill=0.6):
    """Random sorted, deduped, sentinel-padded rows (the OrientedELL
    row invariant); sentinel == vx."""
    rows = np.full((e, k), vx, dtype=np.int32)
    for i in range(e):
        n = rng.integers(0, int(k * fill) + 1)
        vals = rng.choice(vx, size=min(n, vx), replace=False)
        vals.sort()
        rows[i, : len(vals)] = vals
    return rows


@pytest.mark.parametrize("e,k,vx", [(16, 8, 40), (100, 37, 64),
                                    (256, 128, 500), (7, 200, 300)])
def test_ell_intersect_shapes(e, k, vx):
    """Pallas (interpret on CPU) vs searchsorted reference vs python
    sets, over ragged shapes that exercise lane/sublane padding."""
    rng = np.random.default_rng(e * k)
    a = _sorted_rows(rng, e, k, vx)
    b = _sorted_rows(rng, e, k, vx)
    got = np.asarray(ell_intersect(jnp.asarray(a), jnp.asarray(b), vx))
    ref = np.asarray(ell_intersect_rows_ref(jnp.asarray(a),
                                            jnp.asarray(b), vx))
    want = np.array([len(set(ra[ra < vx]) & set(rb[rb < vx]))
                     for ra, rb in zip(a, b)])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ref, want)


def test_ell_intersect_sentinel_rows_count_zero():
    """All-sentinel rows (padding edges gathering the padding row) must
    contribute nothing — sentinel never matches sentinel."""
    vx = 32
    a = np.full((8, 16), vx, dtype=np.int32)
    b = np.full((8, 16), vx, dtype=np.int32)
    b[0, :3] = [1, 5, 9]
    for fn in (ell_intersect, ell_intersect_rows_ref):
        got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), vx))
        assert (got == 0).all()


def test_ell_intersect_identical_rows():
    vx = 100
    row = np.array([2, 3, 5, 7, 11, vx, vx, vx], dtype=np.int32)
    a = np.tile(row, (8, 1))
    for fn in (ell_intersect, ell_intersect_rows_ref):
        got = np.asarray(fn(jnp.asarray(a), jnp.asarray(a), vx))
        assert (got == 5).all()


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 128, 32),     # MHA
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 128, 64),     # MQA
])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
])
def test_flash_attention_variants(b, hq, hkv, s, d, kwargs):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    got = flash_attention(q, k, v, block_q=64, block_k=64, **kwargs)
    want = mha_reference(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_blocks_divide_requirement():
    """Non-dividing blocks shrink to fit via the wrapper."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 96, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 96, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 96, 32)), jnp.float32)
    got = flash_attention(q, k, v, block_q=96, block_k=96)
    want = mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------- chunked attention (pure-JAX flash)

def test_chunked_attention_vs_ref():
    from repro.models.layers import attn_chunked, attn_ref
    rng = np.random.default_rng(5)
    b, s, hq, hkv, dh = 2, 96, 6, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    pos = jnp.arange(s)
    for kwargs in [dict(causal=True), dict(causal=True, window=17),
                   dict(causal=True, softcap=20.0),
                   dict(causal=True, prefix=8)]:
        got = attn_chunked(q, k, v, pos, pos, chunk_q=32, chunk_k=16,
                           **kwargs)
        want = attn_ref(q, k, v, pos, pos, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=str(kwargs))


# ------------------------------------------------------- ring attention

def test_ring_attention_vs_ref():
    """Context-parallel ring attention == reference, on 8 virtual devices
    (subprocess: device count must be set before jax init)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.layers import attn_ring, attn_ref
        mesh = make_mesh((4, 2), ('data', 'model'))
        rng = np.random.default_rng(0)
        B, S, Hq, Hkv, Dh = 4, 64, 6, 2, 32
        q = jnp.asarray(rng.standard_normal((B, S, Hq, Dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
        pos = jnp.arange(S)
        with mesh:
            for kwargs in [dict(causal=True), dict(causal=True, window=17),
                           dict(causal=True, softcap=20.0),
                           dict(causal=False)]:
                got = jax.jit(lambda q, k, v: attn_ring(
                    q, k, v, mesh=mesh, chunk_k=16, **kwargs))(q, k, v)
                want = attn_ref(q, k, v, pos, pos, **kwargs)
                assert float(jnp.max(jnp.abs(got - want))) < 2e-5, kwargs
        print('RING_OK')
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "RING_OK" in r.stdout, r.stderr[-2000:]
