"""Production training driver: supervised, checkpointed, restartable.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --reduced --steps 200 --batch 8 --seq 64 \
        --ckpt-dir /tmp/run1 [--simulate-failure-at 120] \
        [--compression int8] [--microbatches 4]

Control flow mirrors a real multi-pod job:
  supervisor -> (restore latest checkpoint) -> step loop with heartbeat,
  straggler watchdog and async checkpointing -> on failure (injected here,
  preemption in production) the supervisor restarts and the loop resumes
  from the last committed step — the test suite asserts bit-exactness of
  this path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step, init_train_state
from repro.train.compression import CompressionConfig
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint)
from repro.train.fault_tolerance import (FailureInjector, Heartbeat,
                                         StragglerWatchdog, run_supervised)
from repro.data.tokens import SyntheticTokens


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=min(50, args.steps // 5),
                      total_steps=args.steps)
    comp = (CompressionConfig(kind=args.compression)
            if args.compression != "none" else None)
    step_fn = jax.jit(make_train_step(model, opt,
                                      microbatches=args.microbatches,
                                      compression=comp))
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch, seed=0)
    return model, step_fn, data, comp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    args = ap.parse_args()

    model, step_fn, data, comp = build(args)
    injector = FailureInjector(
        [args.simulate_failure_at] if args.simulate_failure_at >= 0 else [])
    watchdog = StragglerWatchdog()
    heartbeat = Heartbeat(args.ckpt_dir + ".heartbeat", interval_s=5.0)
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    def train_loop(_resume):
        state = init_train_state(model, jax.random.PRNGKey(0),
                                 compression=comp)
        start = 0
        if latest_step(args.ckpt_dir) is not None:
            state, start = restore_checkpoint(args.ckpt_dir, state)
            print(f"[restore] resumed from step {start}")
        losses = []
        for i in range(start, args.steps):
            injector.check(i)
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.record(i, dt):
                print(f"[straggler] step {i} took {dt:.2f}s "
                      f"(ewma {watchdog.ewma:.2f}s)")
            heartbeat.beat(i)
            if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
                ckpt.submit(i + 1, state)
            if (i + 1) % args.log_every == 0:
                print(f"step {i+1:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"{dt*1e3:.0f}ms")
        ckpt.wait()
        return {"steps": args.steps, "final_loss": losses[-1],
                "straggler_events": len(watchdog.events)}

    report = run_supervised(train_loop, max_restarts=3)
    print(f"[done] steps={report.completed_steps} "
          f"restarts={report.restarts} "
          f"final_loss={report.final_metrics['final_loss']:.4f}")


if __name__ == "__main__":
    main()
