"""Cost-based router tests: the Fig. 5 crossover must emerge from the
model, and the paper-scale workloads must route to the right engine.
"""
import pytest

from repro.core import planner as P


def _stats(v, e):
    return P.GraphStats(n_vertices=v, n_edges=e, bytes_coo=e * 12)


def test_small_graph_small_output_routes_local():
    g = _stats(400_000, 2_000_000)
    q = P.spec_for("connected_components", g, count_only=True)
    assert P.choose_engine(g, q, 256).engine == "local"


def test_huge_graph_routes_distributed():
    # paper scale: combined connected users, 2.41B vertices 1.5B edges
    g = _stats(2_410_000_000, 1_500_000_000)
    q = P.spec_for("connected_components", g)
    plan = P.choose_engine(g, q, 256)
    assert plan.engine == "distributed"
    assert plan.est_local_s == float("inf")     # exceeds local memory


def test_multi_account_scale_routes_distributed():
    # paper scale: 14.89B vertices, 30.86B edges heterogeneous graph
    g = _stats(14_890_000_000, 30_860_000_000)
    q = P.spec_for("two_hop", g)
    assert P.choose_engine(g, q, 256).engine == "distributed"


def test_output_cardinality_flips_engine():
    """Fig. 5's second finding: same graph, count vs table changes the
    winner (Neo4j count in 2s vs Spark 10min)."""
    g = _stats(10_000_000, 50_000_000)
    q_count = P.spec_for("connected_components", g, count_only=True)
    q_pairs = P.spec_for("two_hop", g,
                         expected_pairs=2_000_000_000)
    plan_count = P.choose_engine(g, q_count, 256)
    plan_pairs = P.choose_engine(g, q_pairs, 256)
    assert plan_count.engine == "local"
    assert plan_pairs.engine == "distributed"


def test_crossover_exists():
    """Sweeping graph size, the winner must flip exactly once from local
    to distributed (the Fig. 5 shape)."""
    q_engine = []
    for v in [10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
        g = _stats(v, v * 5)
        q = P.spec_for("pagerank", g)
        q_engine.append(P.choose_engine(g, q, 256).engine)
    assert q_engine[0] == "local"
    assert q_engine[-1] == "distributed"
    flips = sum(a != b for a, b in zip(q_engine, q_engine[1:]))
    assert flips == 1


def test_cost_estimates_positive_and_ordered():
    g = _stats(1_000_000, 8_000_000)
    q = P.spec_for("pagerank", g)
    tl = P.estimate_local_cost(g, q)
    td = P.estimate_dist_cost(g, q, 256)
    assert tl > 0 and td > 0


ALL_ALGORITHMS = ["pagerank", "connected_components", "two_hop",
                  "degree_stats", "bfs", "sssp", "label_propagation",
                  "triangle_count", "k_core"]


@pytest.mark.parametrize("algorithm", ALL_ALGORITHMS)
def test_spec_and_plan_exist_for_every_algorithm(algorithm):
    """Every workload behind the unified layer has a cost spec and
    produces a Plan with finite distributed cost."""
    g = _stats(1_000_000, 5_000_000)
    for count_only in (False, True):
        q = P.spec_for(algorithm, g, count_only=count_only)
        assert q.iterations >= 1 and q.output_rows >= 1
        plan = P.choose_engine(g, q, 256)
        assert plan.engine in ("local", "distributed")
        assert plan.est_dist_s > 0 and plan.est_dist_s != float("inf")
        assert plan.reason


@pytest.mark.parametrize("algorithm", ALL_ALGORITHMS)
def test_every_algorithm_crosses_over_once(algorithm):
    """The Fig. 5 shape holds per algorithm: local wins small, the
    distributed engine wins at scale, with a single flip between."""
    engines = []
    for v in [10**3, 10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
        g = _stats(v, v * 5)
        engines.append(P.choose_engine(g, P.spec_for(algorithm, g), 256).engine)
    assert engines[0] == "local"
    assert engines[-1] == "distributed"
    assert sum(a != b for a, b in zip(engines, engines[1:])) == 1


def test_triangle_bitset_state_crosses_before_scalar_programs():
    """Triangle counting's O(V/32)-word state makes it leave the local
    engine at smaller V than scalar-state programs on the same graph."""
    def crossover(algorithm):
        for v in [10**3, 10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
            g = _stats(v, v * 5)
            if P.choose_engine(g, P.spec_for(algorithm, g), 256).engine \
                    == "distributed":
                return v
        return None
    assert crossover("triangle_count") < crossover("connected_components")


def test_user_max_iters_flows_into_cost():
    """Satellite fix: a user-supplied ``max_iters`` cap reaches the cost
    hook — the planner must not cost a 4-superstep CC at the analytic
    16 (nor a 3-hop BFS at 12)."""
    g = _stats(1_000_000, 5_000_000)
    assert P.spec_for("connected_components", g).iterations == 16
    assert P.spec_for("connected_components", g, max_iters=4).iterations == 4
    assert P.spec_for("bfs", g).iterations == 12
    assert P.spec_for("bfs", g, max_iters=3).iterations == 3
    assert P.spec_for("pagerank", g, max_iters=10).iterations == 10
    # caps looser than the analytic estimate keep the estimate
    assert P.spec_for("pagerank", g, max_iters=500).iterations == 40
    # and a tighter cap lowers the estimated cost monotonically
    tight = P.estimate_local_cost(g, P.spec_for("pagerank", g, max_iters=5))
    loose = P.estimate_local_cost(g, P.spec_for("pagerank", g))
    assert tight < loose


def test_spec_for_rejects_unknown_params():
    g = _stats(1_000, 5_000)
    with pytest.raises(ValueError, match="unknown parameter"):
        P.spec_for("pagerank", g, iters=10)


def test_platform_plan_for_new_queries():
    """GraphQuery -> Plan through the platform without running engines."""
    from repro.core import graph as G
    from repro.core.query import GraphPlatform, GraphQuery
    import numpy as np
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 0])
    plat = GraphPlatform(G.build_coo(src, dst, 3, symmetrize=True))
    for q in [GraphQuery.bfs([0]), GraphQuery.sssp(0),
              GraphQuery.label_propagation(), GraphQuery.triangle_count(),
              GraphQuery.k_core(2)]:
        plan = plat.plan(q)
        assert plan.engine == "local"   # tiny graph
