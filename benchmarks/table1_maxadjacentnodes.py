"""Table I reproduction: MaxAdjacentNodes cap vs edge-loss percentage on
a heavy-tailed user<->identifier graph.  The paper's production numbers
(cap=100 -> 27.8% lost) depend on Twitter's exact degree distribution;
the reproduction asserts the same *structure*: monotone decreasing loss,
zero loss above the max degree, double-digit loss at tight caps."""
from __future__ import annotations


from benchmarks.common import csv_row
from repro.data.etl import max_adjacent_nodes_sweep
from repro.data import synthetic as S


def run(out=print):
    u, i = S.safety_bipartite_graph(100_000, 30_000, seed=4,
                                    hub_degree=2_000, hub_fraction=0.002)
    caps = [10, 100, 1_000, 10_000, 100_000]
    rows = max_adjacent_nodes_sweep(u, i, 30_000, caps)
    for r in rows:
        out(csv_row(f"table1/cap_{r['max_adjacent_nodes']}", 0.0,
                    f"edges={r['edge_count']}"
                    f";lost_pct={r['lost_percentage']:.1f}"))
    losses = [r["lost_percentage"] for r in rows]
    assert all(a >= b for a, b in zip(losses, losses[1:]))
    assert losses[-1] == 0.0
    return rows


if __name__ == "__main__":
    run()
