"""The configurable ETL pipeline (paper Section III-C-2).

The paper's pipeline: FlockDB association dumps -> HDFS snapshots ->
(replicate to GCS) -> graph generation -> algorithm execution -> results
to BigQuery/GCS for downstream ML.  Here:

    snapshot files (npz on disk == HDFS/GCS stand-in)
      -> SnapshotStore (daily partitions, multi-snapshot union)
      -> GraphETL: dedup | remap ids | symmetrize | degree-cap | pack
      -> GraphCOO / GraphELL on device
      -> results persisted back via ResultSink (npz + manifest)

Every stage is pure and restartable; the pipeline writes a manifest with
content hashes so a restarted job skips completed stages (the same
mechanism the trainer's checkpointer uses).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core import graph as G


def _hash_arrays(*arrays: np.ndarray) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class Snapshot:
    """One daily snapshot of (src, dst) associations."""
    name: str
    src: np.ndarray
    dst: np.ndarray

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


@dataclasses.dataclass
class SnapshotDelta:
    """Edge edit from ``base`` to the (virtual) snapshot ``name`` — the
    daily-cadence partition format: today's landing job ships only the
    changed associations, not the full graph."""
    name: str
    base: str
    added_src: np.ndarray
    added_dst: np.ndarray
    removed_src: np.ndarray
    removed_dst: np.ndarray

    @property
    def n_added(self) -> int:
        return int(self.added_src.shape[0])

    @property
    def n_removed(self) -> int:
        return int(self.removed_src.shape[0])


class SnapshotStore:
    """Directory of npz snapshot partitions — the HDFS/GCS stand-in.

    Two partition kinds: full snapshots (``{name}.npz``) and delta
    partitions (``{name}.delta.npz``) that reference a base by name.
    ``manifest``/``resolve`` walk a delta chain back to its full base,
    so a snapshot landed as deltas costs only the changed edges on disk.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, snap: Snapshot) -> str:
        path = os.path.join(self.root, f"{snap.name}.npz")
        tmp = path + ".tmp.npz"   # savez appends .npz if missing
        np.savez_compressed(tmp, src=snap.src, dst=snap.dst)
        os.replace(tmp, path)     # atomic commit
        return path

    def read(self, name: str) -> Snapshot:
        path = os.path.join(self.root, f"{name}.npz")
        if not os.path.exists(path):
            raise KeyError(
                f"snapshot {name!r} not in store {self.root!r}; "
                f"available: {self.list()} (deltas: {self.list_deltas()})")
        data = np.load(path)
        return Snapshot(name, data["src"], data["dst"])

    def write_delta(self, delta: SnapshotDelta) -> str:
        path = os.path.join(self.root, f"{delta.name}.delta.npz")
        tmp = path + ".tmp.npz"
        np.savez_compressed(
            tmp, base=np.array(delta.base),
            added_src=delta.added_src, added_dst=delta.added_dst,
            removed_src=delta.removed_src, removed_dst=delta.removed_dst)
        os.replace(tmp, path)
        return path

    def read_delta(self, name: str) -> SnapshotDelta:
        path = os.path.join(self.root, f"{name}.delta.npz")
        if not os.path.exists(path):
            raise KeyError(
                f"delta partition {name!r} not in store {self.root!r}; "
                f"available deltas: {self.list_deltas()}")
        data = np.load(path)
        return SnapshotDelta(
            name, str(data["base"]),
            data["added_src"], data["added_dst"],
            data["removed_src"], data["removed_dst"])

    def manifest(self, name: str) -> dict:
        """Lineage of ``name``: its full base partition plus the delta
        names to apply, oldest first."""
        deltas, seen = [], set()
        cur = name
        while not os.path.exists(os.path.join(self.root, f"{cur}.npz")):
            if cur in seen:
                raise KeyError(f"delta chain for {name!r} has a cycle "
                               f"at {cur!r}")
            seen.add(cur)
            deltas.append(self.read_delta(cur))   # KeyError if missing
            cur = deltas[-1].base
        return {"name": name, "base": cur,
                "deltas": [d.name for d in reversed(deltas)]}

    def resolve(self, name: str) -> Snapshot:
        """Materialize ``name`` as a full edge list: read its base and
        apply the delta chain (removals before additions, per delta)."""
        man = self.manifest(name)
        base = self.read(man["base"])
        src = np.asarray(base.src, dtype=np.int64)
        dst = np.asarray(base.dst, dtype=np.int64)
        for dname in man["deltas"]:
            d = self.read_delta(dname)
            if d.n_removed:
                stride = np.int64(
                    max(src.max(initial=0), dst.max(initial=0),
                        np.asarray(d.removed_src).max(initial=0),
                        np.asarray(d.removed_dst).max(initial=0)) + 1)
                rem = (np.asarray(d.removed_src, dtype=np.int64) * stride
                       + np.asarray(d.removed_dst, dtype=np.int64))
                keep = ~np.isin(src * stride + dst, rem)
                src, dst = src[keep], dst[keep]
            src = np.concatenate([src, np.asarray(d.added_src,
                                                  dtype=np.int64)])
            dst = np.concatenate([dst, np.asarray(d.added_dst,
                                                  dtype=np.int64)])
        return Snapshot(name, src, dst)

    def list(self) -> list[str]:
        """Full snapshot partitions only — stray ``.tmp.npz`` files from
        a crashed ``write`` and delta partitions are excluded."""
        return sorted(f[:-4] for f in os.listdir(self.root)
                      if f.endswith(".npz")
                      and not f.endswith(".tmp.npz")
                      and not f.endswith(".delta.npz"))

    def list_deltas(self) -> list[str]:
        return sorted(f[: -len(".delta.npz")] for f in os.listdir(self.root)
                      if f.endswith(".delta.npz")
                      and not f.endswith(".tmp.npz"))


@dataclasses.dataclass
class ETLReport:
    n_vertices: int
    n_edges_in: int
    n_edges_deduped: int
    n_edges_after_cap: int
    lost_fraction: float      # Table I quantity
    wall_seconds: float
    content_hash: str


class GraphETL:
    """Snapshot union -> device graph, with the paper's knobs."""

    def __init__(self, max_adjacent_nodes: Optional[int] = None,
                 symmetrize: bool = False, dedup: bool = True):
        self.cap = max_adjacent_nodes
        self.symmetrize = symmetrize
        self.dedup = dedup

    def union_snapshots(self, snaps: Iterable[Snapshot]):
        srcs, dsts = [], []
        for s in snaps:
            srcs.append(s.src)
            dsts.append(s.dst)
        return np.concatenate(srcs), np.concatenate(dsts)

    def build(self, snaps: Sequence[Snapshot],
              n_vertices: Optional[int] = None):
        """Returns (GraphCOO, GraphELL|None, ETLReport)."""
        t0 = time.time()
        src, dst = self.union_snapshots(snaps)
        n_in = src.shape[0]
        if n_vertices is None:
            n_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
        coo = G.build_coo(src, dst, n_vertices, symmetrize=self.symmetrize,
                          dedup=self.dedup)
        ell = None
        lost = 0.0
        if self.cap is not None:
            ell = G.build_ell(np.asarray(coo.src)[: coo.n_edges],
                              np.asarray(coo.dst)[: coo.n_edges],
                              n_vertices, self.cap, direction="in")
            lost = ell.lost_fraction
        report = ETLReport(
            n_vertices=n_vertices, n_edges_in=n_in,
            n_edges_deduped=coo.n_edges,
            n_edges_after_cap=ell.n_edges if ell else coo.n_edges,
            lost_fraction=lost, wall_seconds=time.time() - t0,
            content_hash=_hash_arrays(src, dst),
        )
        return coo, ell, report


class ResultSink:
    """Persist algorithm outputs + manifest (the BigQuery/GCS stand-in)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, name: str, arrays: dict, meta: dict) -> str:
        path = os.path.join(self.root, f"{name}.npz")
        np.savez_compressed(path, **{k: np.asarray(v)
                                     for k, v in arrays.items()})
        manifest = {
            "name": name, "time": time.time(),
            "meta": {k: str(v) for k, v in meta.items()},
            "arrays": {k: list(np.asarray(v).shape)
                       for k, v in arrays.items()},
        }
        with open(os.path.join(self.root, f"{name}.manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        return path

    def read(self, name: str):
        data = np.load(os.path.join(self.root, f"{name}.npz"))
        with open(os.path.join(self.root, f"{name}.manifest.json")) as f:
            manifest = json.load(f)
        return dict(data), manifest


def max_adjacent_nodes_sweep(src: np.ndarray, dst: np.ndarray,
                             n_vertices: int,
                             caps: Sequence[int]) -> list[dict]:
    """Reproduce Table I: edge retention vs MaxAdjacentNodes."""
    rows = []
    total = src.shape[0]
    for cap in caps:
        ell = G.build_ell(src, dst, n_vertices, cap, direction="in")
        rows.append({
            "max_adjacent_nodes": cap,
            "edge_count": ell.n_edges,
            "lost_percentage": 100.0 * ell.lost_fraction,
        })
        assert ell.n_edges_total == total
    return rows
