"""Shape-faithful synthetic graph generators.

The paper's production graphs cannot leave Twitter; we generate graphs
with the same *structure* at configurable scale:

* ``user_follow_graph``      — directed power-law (small-world) graph,
  the PageRank workload (paper: millions of vertices, billions of edges).
* ``safety_bipartite_graph`` — heterogeneous user<->identifier graph for
  multi-account detection (paper: 14.89B vertices / 30.86B edges across 4
  daily snapshots; identifier degrees heavy-tailed, which is exactly why
  the legacy job needed MaxAdjacentNodes).
* ``identifier_edge_sets``   — the combined-connected-users inputs: one
  edge set per identifier type (paper: 2 daily snapshots, 2.41B vertices
  / 1.50B edges).

All generators are numpy + seeded (deterministic tests/benchmarks).
"""
from __future__ import annotations

import numpy as np


def _power_law_degrees(n: int, rng, alpha: float = 2.1, d_min: int = 1,
                       d_max: int | None = None) -> np.ndarray:
    """Zipf-ish degree sequence (discrete Pareto), clipped."""
    d_max = d_max or max(4, n // 4)
    u = rng.random(n)
    deg = np.floor(d_min * (1 - u) ** (-1.0 / (alpha - 1.0))).astype(np.int64)
    return np.clip(deg, d_min, d_max)


def user_follow_graph(n_users: int, mean_degree: float = 8.0,
                      seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Directed power-law graph via a Chung-Lu style sampler.

    Returns (src, dst) int64 arrays; may contain a few duplicate edges
    (dedup'd at build_coo, as the ETL does).
    """
    rng = np.random.default_rng(seed)
    n_edges = int(n_users * mean_degree)
    out_w = _power_law_degrees(n_users, rng).astype(np.float64)
    in_w = _power_law_degrees(n_users, rng).astype(np.float64)
    src = rng.choice(n_users, size=n_edges, p=out_w / out_w.sum())
    dst = rng.choice(n_users, size=n_edges, p=in_w / in_w.sum())
    keep = src != dst
    return src[keep].astype(np.int64), dst[keep].astype(np.int64)


def safety_bipartite_graph(n_users: int, n_identifiers: int,
                           mean_ids_per_user: float = 2.0,
                           hub_fraction: float = 0.001,
                           hub_degree: int = 500,
                           seed: int = 0):
    """(user, identifier) edges with heavy-tailed identifier degrees.

    ``hub_fraction`` of identifiers are shared by ~``hub_degree`` users
    (the paper's motivation for the MaxAdjacentNodes cap: a few emails /
    phones connect huge numbers of accounts).
    Returns (user_ids, identifier_ids).
    """
    rng = np.random.default_rng(seed)
    n_edges = int(n_users * mean_ids_per_user)
    users = rng.integers(0, n_users, size=n_edges)
    id_w = _power_law_degrees(n_identifiers, rng, alpha=2.0).astype(np.float64)
    n_hubs = max(1, int(n_identifiers * hub_fraction))
    id_w[:n_hubs] = hub_degree
    ids = rng.choice(n_identifiers, size=n_edges, p=id_w / id_w.sum())
    # dedup (user, id) pairs — a user registers an identifier once
    key = users * np.int64(n_identifiers) + ids
    _, keep = np.unique(key, return_index=True)
    return users[keep].astype(np.int64), ids[keep].astype(np.int64)


def identifier_edge_sets(n_users: int, n_sets: int = 4,
                         mean_degree: float = 1.5, seed: int = 0):
    """One (src,dst) user-user edge set per identifier type — the
    combined-connected-users input.  Edges inside a set link users that
    share an identifier of that type."""
    rng = np.random.default_rng(seed)
    sets = []
    for t in range(n_sets):
        n_edges = int(n_users * mean_degree)
        src = rng.integers(0, n_users, size=n_edges)
        # preferential attachment to small offsets -> chains + clusters
        off = rng.geometric(p=0.3, size=n_edges)
        dst = (src + off) % n_users
        sets.append((src.astype(np.int64), dst.astype(np.int64)))
    return sets


def rmat_graph(scale: int, edge_factor: int = 8, seed: int = 0,
               a=0.57, b=0.19, c=0.19):
    """Graph500-style R-MAT: 2^scale vertices, edge_factor*2^scale edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        s_bit = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        d_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= s_bit.astype(np.int64) << bit
        dst |= d_bit.astype(np.int64) << bit
    keep = src != dst
    return src[keep], dst[keep], n
