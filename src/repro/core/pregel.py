"""BSP vertex-centric superstep engine — the Spark/GraphFrames analogue.

One Pregel superstep (Malewicz et al., the model GraphFrames ultimately
lowers to) maps onto a TPU mesh as::

    gather   : read source-vertex state along edges        (local gather /
               all_gather over the ``model`` axis when vertex-sharded)
    message  : per-edge compute                            (VPU)
    combine  : segment-reduce messages to destinations     (local)
    shuffle  : merge partial aggregates across edge shards (psum/pmin/pmax
               over the ``data`` axis — Spark's shuffle becomes one ring
               collective)
    apply    : per-vertex state update                     (VPU)

Everything is statically shaped: padded edges carry the sentinel vertex id
and are dropped at the segment-combine.  Convergence is decided *inside*
the jitted loop with a global ``psum`` of per-shard change counts, so a
whole multi-superstep algorithm (PageRank, hash-to-min CC) is a single
XLA program — the property that makes the distributed engine orders of
magnitude faster than a dataflow engine that materializes every round.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from collections import OrderedDict
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.partition import ShardedCOO
from repro.utils.compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PregelSpec:
    """One vertex program.

    message : (src_state[E], w[E]) -> msg[E] or msg[E, M]; with
              ``needs_dst_state`` the signature is
              (src_state, w, dst_state) — an *edge* program that can read
              both endpoints (triangle counting intersects neighborhoods
              this way).
    combine : the message monoid.  Either a single op ('sum'|'min'|'max')
              applied to the whole message, or a tuple of ``(op, width)``
              column groups for *structured* messages: the message's last
              axis is split into contiguous groups, each combined with its
              own monoid (label propagation sends C sum-combined weight
              channels next to C min-combined label channels in one
              superstep).
    apply   : (old_state[Vl], agg, vertex_ids[Vl], gval) -> new_state
    identity: identity element of the monoid — a scalar, or a tuple of
              per-group identities matching a grouped ``combine`` (fills
              vertices with no incoming message)
    halt    : optional (old, new, valid[Vl]) -> bool array (per-shard
              "locally converged"); None runs exactly ``max_iters``.
    global_value : optional (state[Vl], ids, valid) -> scalar (or small
              array) partial; summed across vertex shards and fed to
              ``apply`` as ``gval`` (PageRank uses this for the
              dangling-mass redistribution — the one pattern a pure
              message-passing model can't express).
    global_over_agg : compute ``global_value`` over the *new* combined
              aggregate instead of the pre-superstep state — the hook a
              same-superstep normalization needs (HITS divides the fresh
              hub/authority sums by their own L2 norms inside the loop,
              making the whole algorithm one XLA program).

    Vertex state may be 1-D ``[Vl]`` or N-D ``[Vl, ...]`` (triangle
    counting keeps a packed neighborhood bitset per vertex); padding-slot
    freezing broadcasts over the trailing axes.
    """

    message: Callable[..., Array]
    combine: object
    apply: Callable[[Array, Array, Array, Array], Array]
    identity: object
    halt: Optional[Callable[[Array, Array, Array], Array]] = None
    global_value: Optional[Callable[[Array, Array, Array], Array]] = None
    needs_dst_state: bool = False
    global_over_agg: bool = False


def converged_halt(old, new, valid):
    """The standard fixpoint predicate: no valid vertex changed state.
    Shared by every to-convergence vertex program (CC, traversal, LPA,
    k-core peeling)."""
    return jnp.logical_not(jnp.any(jnp.logical_and(valid, new != old)))


@functools.lru_cache(maxsize=64)
def batched_spec(spec: PregelSpec) -> PregelSpec:
    """Lift a scalar vertex program onto a trailing batch axis.

    The returned spec runs K independent instances of ``spec`` as *one*
    program over state ``[Vl, K]`` — the fused-batch substrate of the
    service layer (K BFS frontiers with different sources share every
    gather, segment-combine and collective of every superstep).  Each
    column's arithmetic is the unbatched program's, element for element
    (vmap only widens the ops), and the monoid combines are exact
    per-column, so column ``k`` of the fused result is bit-identical to
    running instance ``k`` alone.  The fused ``halt`` is the AND over
    columns; converged columns sit at their fixpoint (apply is a no-op
    there) while stragglers finish.

    Memoized (bounded) so repeated fusions of the same program hit the
    jit cache.  Structured (grouped-monoid) messages split columns
    positionally and cannot carry a trailing batch axis — rejected up
    front.
    """
    if isinstance(spec.combine, tuple):
        raise ValueError(
            "batched_spec: structured (grouped-monoid) messages cannot "
            "be lifted onto a batch axis")
    msg_axes = (-1, None, -1) if spec.needs_dst_state else (-1, None)
    message = jax.vmap(spec.message, in_axes=msg_axes, out_axes=-1)
    # with a global_value the per-column scalars arrive as a trailing-K
    # vector and each column's apply reads its own entry
    gval_axis = None if spec.global_value is None else -1
    apply_ = jax.vmap(spec.apply, in_axes=(-1, -1, None, gval_axis),
                      out_axes=-1)

    halt = None
    if spec.halt is not None:
        per_col = jax.vmap(spec.halt, in_axes=(-1, -1, None))

        def halt(old, new, valid):
            return jnp.all(per_col(old, new, valid))

    gval = None
    if spec.global_value is not None:
        per_col_g = jax.vmap(spec.global_value, in_axes=(-1, None, None),
                             out_axes=-1)

        def gval(state, ids, valid):
            return per_col_g(state, ids, valid)

    return PregelSpec(
        message=message, combine=spec.combine, apply=apply_,
        identity=spec.identity, halt=halt, global_value=gval,
        needs_dst_state=spec.needs_dst_state,
        global_over_agg=spec.global_over_agg)


_SEG = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def _psum_like(x: Array, op: str, axis) -> Array:
    if op == "sum":
        return lax.psum(x, axis)
    if op == "min":
        return lax.pmin(x, axis)
    if op == "max":
        return lax.pmax(x, axis)
    raise ValueError(op)


def _local_combine(msgs, dst, n_vertices, v_local, start, op, identity):
    """Segment-combine messages into the locally-owned vertex range.

    Grouped ``op`` splits the message's last axis into ``(op, width)``
    column groups, each combined under its own monoid.
    """
    if isinstance(op, tuple):
        parts, c0 = [], 0
        for (o, width), ident in zip(op, identity):
            parts.append(_local_combine(msgs[..., c0:c0 + width], dst,
                                        n_vertices, v_local, start, o, ident))
            c0 += width
        return jnp.concatenate(parts, axis=-1)
    local_dst = jnp.where(dst >= n_vertices, v_local, dst - start)
    local_dst = jnp.clip(local_dst, 0, v_local)
    agg = _SEG[op](msgs, local_dst, num_segments=v_local + 1)[:v_local]
    if op in ("min", "max"):
        # segment_min/max give +/-inf (or int extremes) for empty segments;
        # normalize to the declared identity.
        no_msg = _SEG["sum"](jnp.ones_like(msgs, dtype=jnp.int32),
                             local_dst, num_segments=v_local + 1)[:v_local] == 0
        agg = jnp.where(no_msg, jnp.asarray(identity, agg.dtype), agg)
    return agg


def _shard_combine(agg, op, axis):
    """Cross-shard merge of partial aggregates (grouped ops column-wise)."""
    if isinstance(op, tuple):
        parts, c0 = [], 0
        for o, width in op:
            parts.append(_psum_like(agg[..., c0:c0 + width], o, axis))
            c0 += width
        return jnp.concatenate(parts, axis=-1)
    return _psum_like(agg, op, axis)


# Bounded LRU of jitted superstep programs.  Keys are *structural*:
# meshes enter as (axis names/types, shape, device ids), never as the
# Mesh object — unbounded Mesh-keyed entries used to pin device state
# for the life of the process.  A cached *mesh-path* program still
# closes over the mesh it was built with (shard_map needs one), so a
# dead Mesh can linger until its entry ages out of the LRU; the bound
# is what turns that from a leak into a window.
_JIT_CACHE: OrderedDict = OrderedDict()
JIT_CACHE_MAX = 64
# The service runtime executes on worker threads (one per engine); the
# LRU's get/move_to_end/popitem sequences are not atomic under free
# threading, so guard them.  Building a missed program happens outside
# the lock — two threads may race to compile the same key and the loser
# simply overwrites with an equivalent entry.
_JIT_CACHE_LOCK = threading.Lock()


def _mesh_cache_key(mesh):
    if mesh is None:
        return None
    # axis_types distinguishes semantically different meshes over the
    # same devices (Auto vs Explicit axes) on jax versions that have it
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat),
            str(getattr(mesh, "axis_types", None)))


def _jit_cache_get(key):
    """Returns (cached fn or None, hashable key or None)."""
    with _JIT_CACHE_LOCK:
        try:
            fn = _JIT_CACHE.get(key)
        except TypeError:          # unhashable spec (closure consts)
            return None, None
        if fn is not None:
            _JIT_CACHE.move_to_end(key)
        return fn, key


def _jit_cache_put(key, fn) -> None:
    if key is None:
        return
    with _JIT_CACHE_LOCK:
        _JIT_CACHE[key] = fn
        while len(_JIT_CACHE) > JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)


def run_pregel(
    spec: PregelSpec,
    sg: ShardedCOO,
    init_state: Array,
    max_iters: int,
    mesh: Optional[Mesh] = None,
    axis_data: str = "data",
    axis_model: str = "model",
):
    """Run the vertex program to convergence (or ``max_iters``).

    Returns ``(final_state [V or n_model*v_local], iterations_run)``.
    With ``mesh=None`` runs the same program on one device (the engine the
    planner picks for medium graphs still shares this code path).
    """
    V = sg.n_vertices
    v_local = sg.v_local
    sharded = sg.vertex_layout == "sharded"

    def body(src, dst, w, state):
        """Executes per-device under shard_map (or directly, single device)."""
        dist = mesh is not None
        if sharded:
            m_idx = lax.axis_index(axis_model) if dist else 0
            start = m_idx * v_local
        else:
            start = 0
        ids = start + jnp.arange(v_local, dtype=jnp.int32)
        valid = ids < V

        def one_iter(state):
            if sharded and dist:
                full = lax.all_gather(state, axis_model, tiled=True)
            else:
                full = state
            src_state = full[jnp.clip(src, 0, full.shape[0] - 1)]
            if spec.needs_dst_state:
                dst_state = full[jnp.clip(dst, 0, full.shape[0] - 1)]
                msgs = spec.message(src_state, w, dst_state)
            else:
                msgs = spec.message(src_state, w)
            agg = _local_combine(msgs, dst, V, v_local, start,
                                 spec.combine, spec.identity)
            if dist:
                agg = _shard_combine(agg, spec.combine, axis_data)
            if spec.global_value is not None:
                g_src = agg if spec.global_over_agg else state
                gval = spec.global_value(g_src, ids, valid)
                if sharded and dist:
                    gval = lax.psum(gval, axis_model)
            else:
                gval = jnp.float32(0.0)
            new = spec.apply(state, agg, ids, gval)
            vmask = valid.reshape(valid.shape + (1,) * (new.ndim - 1))
            new = jnp.where(vmask, new, state)  # freeze padding slots
            return new

        if spec.halt is None:
            def fori(_, s):
                return one_iter(s)
            final = lax.fori_loop(0, max_iters, fori, state)
            return final, jnp.int32(max_iters)

        def cond(carry):
            _, i, done = carry
            return jnp.logical_and(i < max_iters, jnp.logical_not(done))

        def step(carry):
            s, i, _ = carry
            new = one_iter(s)
            conv_local = spec.halt(s, new, valid)
            not_conv = jnp.logical_not(conv_local).astype(jnp.int32)
            if dist:
                axes = (axis_data, axis_model) if sharded else (axis_data,)
                not_conv = lax.psum(not_conv, axes)
            return new, i + 1, not_conv == 0

        final, iters, _ = lax.while_loop(
            cond, step, (state, jnp.int32(0), jnp.array(False)))
        return final, iters

    # jit-cache: repeated queries on the same engine must not re-trace
    # (the 'consistent query performance' property of the local engine)
    key = (spec, max_iters, _mesh_cache_key(mesh), axis_data, axis_model,
           V, v_local, sg.n_data, sg.n_model, sg.e_shard,
           init_state.shape, str(init_state.dtype))
    fn, key = _jit_cache_get(key)
    if mesh is None:
        # Single-device: shards concatenated — treat as one big shard.
        # (2-D vertex-sharded layouts only make sense on a mesh.)
        assert not sharded, "vertex-sharded layout requires a mesh"
        if fn is None:
            fn = jax.jit(body)
            _jit_cache_put(key, fn)
        return fn(sg.src, sg.dst, sg.w, init_state)

    if fn is None:
        edge_spec = P((axis_data, axis_model)) if sharded else P(axis_data)
        state_spec = P(axis_model) if sharded else P()
        fn = jax.jit(shard_map(
            body,
            mesh=mesh,
            in_specs=(edge_spec, edge_spec, edge_spec, state_spec),
            out_specs=(state_spec, P()),
            check_vma=False,
        ))
        _jit_cache_put(key, fn)
    with mesh:
        return fn(sg.src, sg.dst, sg.w, init_state)
