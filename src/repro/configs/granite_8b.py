"""Granite-8B-Code [arXiv:2405.04324]: llama-arch, code model.

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=49152,
    mlp_act="silu",
    tie_embeddings=False,
    fsdp=True,
)
