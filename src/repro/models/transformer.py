"""Dense decoder-only LM (llama/mistral/gemma2 family) + the base Model
API every architecture implements:

    init(key) -> params                       (stacked-layer pytree)
    forward(params, batch) -> logits          (teacher-forced, training)
    loss(params, batch) -> (scalar, metrics)  (chunked-vocab CE)
    init_cache(batch, cache_len) -> cache
    prefill(params, batch) -> (last_logits, cache)
    decode_step(params, tokens, cache, index) -> (logits, cache)
    param_spec() / cache_spec() -> PartitionSpec pytrees (fsdp-aware)
    input_specs(shape) -> ShapeDtypeStructs for the dry-run

Layers are stacked on a leading L axis and executed with ``lax.scan``
(+ optional full remat): one compiled block regardless of depth — the
standard production-JAX pattern for compile time and activation memory.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import layers as L

DP = ("pod", "data")   # canonical data-parallel mesh axes (pod may be absent)


def dp_axes(multi_pod: bool = True):
    return DP if multi_pod else ("data",)


class DenseLM:
    family = "dense"

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.windows = L.layer_windows(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        # Megatron-style sequence parallelism for the residual stream:
        # when a launcher sets act_spec = P(dp, 'model', None), the
        # layer-scan carry (the tensor remat must save per layer) is
        # sharded over the model axis on the sequence dim.  XLA inserts
        # the all-gather before attention and the reduce-scatter after —
        # the same ring bytes as the TP all-reduce it subsumes, for a
        # TP-fold smaller activation footprint.
        self.act_spec = None
        # FSDP shard axes — the launcher widens this to ('data', 'pod')
        # on multi-pod meshes so optimizer state scales with the fleet
        self.fsdp_axes = ("data",)
        # strip_tp=True removes tensor parallelism from the param specs
        # (the mesh's model axis is then repurposed as extra FSDP/DP) —
        # the right production config for small models on a fixed mesh
        self.strip_tp = False
        # ring attention (context parallelism): set by the launcher with
        # the concrete mesh; requires static window (cfg.window == 0)
        self.ring_mesh = None
        self.ring_batch_axes = ("data",)

    def _constrain_act(self, x):
        if self.act_spec is not None and x.ndim >= 3:
            return jax.lax.with_sharding_constraint(x, self.act_spec)
        return x

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_layers = jax.random.split(key)
        params = L.init_embed(k_embed, cfg)
        params["layers"] = self._init_layers(k_layers)
        return params

    def _init_layers(self, key) -> dict:
        cfg = self.cfg
        ka, km = jax.random.split(key)
        p = {
            "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32),
            "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32),
            "attn": L.init_attn(ka, cfg, layers=cfg.n_layers),
            "mlp": L.init_mlp(km, cfg, layers=cfg.n_layers),
        }
        if cfg.post_norms:
            p["ln1_post"] = jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32)
            p["ln2_post"] = jnp.zeros((cfg.n_layers, cfg.d_model), jnp.float32)
        return p

    # ------------------------------------------------------------ block
    def _ffn(self, p_l, h, *_):
        return L.mlp_apply(p_l["mlp"], h, self.cfg.mlp_act)

    def _mixer_train(self, p_l, window, h, qpos):
        cfg = self.cfg
        q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
        q = L.rope(q, qpos, cfg.rope_theta)
        k = L.rope(k, qpos, cfg.rope_theta)
        if cfg.attn_impl == "ring" and self.ring_mesh is not None:
            assert cfg.window == 0, "ring path needs a static window"
            o = L.attn_ring(q, k, v, mesh=self.ring_mesh,
                            batch_axes=self.ring_batch_axes,
                            causal=True, softcap=cfg.attn_logit_softcap,
                            chunk_k=min(cfg.attn_chunk, 512))
        else:
            o = L.attention_output(q, k, v, qpos, qpos, cfg.attn_impl,
                                   causal=True, window=window,
                                   softcap=cfg.attn_logit_softcap,
                                   chunk=cfg.attn_chunk)
        return L.out_proj(p_l["attn"], o, h.dtype), (k, v)

    def _block_train(self, p_l, window, x, qpos, collect_kv=False):
        cfg = self.cfg
        h = L.rms_norm(x, p_l["ln1"])
        o, kv = self._mixer_train(p_l, window, h, qpos)
        if cfg.post_norms:
            o = L.rms_norm(o, p_l["ln1_post"])
        x = x + o
        h2 = L.rms_norm(x, p_l["ln2"])
        m = self._ffn(p_l, h2, qpos)
        if cfg.post_norms:
            m = L.rms_norm(m, p_l["ln2_post"])
        x = x + m
        return x, (kv if collect_kv else None)

    def _block_decode(self, p_l, window, x, k_cache, v_cache, index):
        cfg = self.cfg
        h = L.rms_norm(x, p_l["ln1"])
        q, k1, v1 = L.qkv_proj(p_l["attn"], h, cfg)
        pos = jnp.full((1,), index, jnp.int32)
        q = L.rope(q, pos, cfg.rope_theta)
        k1 = L.rope(k1, pos, cfg.rope_theta)
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k1.astype(k_cache.dtype), index, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v1.astype(v_cache.dtype), index, axis=1)
        o = L.attn_decode(q, k_cache, v_cache, index, causal=True,
                          window=window, softcap=cfg.attn_logit_softcap)
        o = L.out_proj(p_l["attn"], o, x.dtype)
        if cfg.post_norms:
            o = L.rms_norm(o, p_l["ln1_post"])
        x = x + o
        h2 = L.rms_norm(x, p_l["ln2"])
        m = self._ffn(p_l, h2, pos)
        if cfg.post_norms:
            m = L.rms_norm(m, p_l["ln2_post"])
        x = x + m
        return x, k_cache, v_cache

    # ---------------------------------------------------------- forward
    def _embed_inputs(self, params, batch):
        tokens = batch["tokens"]
        x = L.embed_tokens(params, tokens, self.cfg, self.dtype)
        qpos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        return x, qpos

    def _scan_layers(self, params, x, qpos, collect_kv=False):
        cfg = self.cfg

        def body(carry, xs):
            p_l, w_l = xs
            carry = self._constrain_act(carry)
            out, kv = self._block_train(p_l, w_l, carry, qpos,
                                        collect_kv=collect_kv)
            return self._constrain_act(out), kv

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            x, kvs = lax.scan(body, x, (params["layers"], self.windows))
        else:
            kvs = []
            for i in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                x, kv = body(x, (p_l, self.windows[i]))
                kvs.append(kv)
            kvs = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *kvs)
                   if collect_kv else None)
        return x, kvs

    def forward(self, params, batch):
        x, qpos = self._embed_inputs(params, batch)
        x, _ = self._scan_layers(params, x, qpos)
        return L.unembed(params, x, self.cfg)

    # ------------------------------------------------------------- loss
    def loss(self, params, batch, vocab_chunk: int = 8):
        """Next-token CE.  The vocab projection is the memory hot spot at
        train time (B*S*V logits); chunk over the sequence so only
        S/vocab_chunk of the logits are ever live (remat recomputes)."""
        cfg = self.cfg
        x, qpos = self._embed_inputs(params, batch)
        x, _ = self._scan_layers(params, x, qpos)
        targets = batch["labels"]            # [B,S] (-1 = masked)
        b, s = targets.shape
        nc = vocab_chunk if s % vocab_chunk == 0 else 1
        xc = x.reshape(b, nc, s // nc, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, s // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_loss(carry, xs):
            xx, tt = xs
            logits = L.unembed(params, xx, cfg)          # [b, s/nc, V] f32
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tt, 0)[..., None], axis=-1)[..., 0]
            valid = (tt >= 0)
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = lax.scan(chunk_loss, (jnp.float32(0), jnp.int32(0)),
                                 (xc, tc))
        loss = tot / jnp.maximum(cnt, 1)
        return loss, {"loss": loss, "tokens": cnt}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, cache_len: int) -> dict:
        cfg = self.cfg
        shp = (cfg.n_layers, batch_size, cache_len, cfg.n_kv_heads,
               cfg.d_head)
        return {"k": jnp.zeros(shp, self.dtype),
                "v": jnp.zeros(shp, self.dtype)}

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Teacher prefill: run the full prompt, return (last_logits, cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        x, qpos = self._embed_inputs(params, batch)
        x, kvs = self._scan_layers(params, x, qpos, collect_kv=True)
        logits = L.unembed(params, x[:, -1:, :], cfg)
        k, v = kvs
        pad = cache_len - s
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, {"k": k.astype(self.dtype), "v": v.astype(self.dtype)}

    def decode_step(self, params, tokens, cache, index):
        """tokens [B,1]; index: scalar position of the new token."""
        x = L.embed_tokens(params, tokens, self.cfg, self.dtype)

        def body(carry, xs):
            p_l, w_l, k_c, v_c = xs
            out, k_c, v_c = self._block_decode(p_l, w_l, carry, k_c, v_c,
                                               index)
            return out, (k_c, v_c)

        x, (k_new, v_new) = lax.scan(
            body, x, (params["layers"], self.windows, cache["k"], cache["v"]))
        logits = L.unembed(params, x, self.cfg)
        return logits, {"k": k_new, "v": v_new}

    # ------------------------------------------------------- shardings
    def _fsdp_ax(self):
        if not self.cfg.fsdp:
            return None
        axes = tuple(self.fsdp_axes)
        return axes if len(axes) > 1 else axes[0]

    def param_spec(self) -> dict:
        cfg = self.cfg
        fs = self._fsdp_ax()
        spec = {
            "embedding": P("model", fs),
            "final_norm": P(None),
            "layers": self._layer_spec(fs),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = P(fs, "model")
        if self.strip_tp:
            spec = jax.tree_util.tree_map(
                lambda sp: P(*[None if e == "model" else e for e in sp]),
                spec, is_leaf=lambda x: isinstance(x, P))
        return spec

    def _layer_spec(self, fs) -> dict:
        cfg = self.cfg
        s = {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "attn": {
                "wq": P(None, fs, "model"),
                "wk": P(None, fs, "model"),
                "wv": P(None, fs, "model"),
                "wo": P(None, "model", fs),
            },
            "mlp": {
                "w_gate": P(None, fs, "model"),
                "w_up": P(None, fs, "model"),
                "w_down": P(None, "model", fs),
            },
        }
        if cfg.post_norms:
            s["ln1_post"] = P(None, None)
            s["ln2_post"] = P(None, None)
        return s

    def cache_spec(self, multi_pod: bool = True) -> dict:
        dp = dp_axes(multi_pod)
        return {"k": P(None, dp, None, None, "model"),
                "v": P(None, dp, None, None, "model")}

    # ------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeSpec, multi_pod: bool = True) -> dict:
        """ShapeDtypeStructs (+ PartitionSpecs) for the dry-run."""
        b, s = shape.global_batch, shape.seq_len
        dp = dp_axes(multi_pod)
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            return {
                "arrays": {"tokens": tok,
                           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)},
                "specs": {"tokens": P(dp, None), "labels": P(dp, None)},
            }
        if shape.kind == "prefill":
            return {"arrays": {"tokens": tok},
                    "specs": {"tokens": P(dp, None)}}
        if shape.kind == "decode":
            return {
                "arrays": {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
                "specs": {"tokens": P(dp, None)},
            }
        raise ValueError(shape.kind)
