"""Faithful re-implementations of the legacy Scalding pipelines.

The paper's speedups (17x multi-account, 37x combined connected users) are
measured AGAINST these pipelines, so they are part of the reproduction.
They are deliberately implemented the way a MapReduce dataflow runs them:

* every step **fully materializes** its output (MapReduce writes each
  stage to HDFS; we materialize numpy arrays and round-trip them through
  a serialization buffer to model the disk barrier),
* every shuffle is a **global sort** (MapReduce's sort-merge shuffle),
* no cross-step fusion, no convergence short-circuiting.

This is an honest algorithmic baseline, not a parody: the asymptotics and
data movement match the legacy jobs the paper describes; only constants
shrink because both run on the same host here.  Benchmarks report the
*ratio*, as the paper does.
"""
from __future__ import annotations

import io
from typing import Sequence

import numpy as np


def _hdfs_barrier(*arrays: np.ndarray) -> tuple[np.ndarray, ...]:
    """Model a MapReduce stage boundary: serialize + deserialize outputs."""
    buf = io.BytesIO()
    np.savez(buf, *arrays)
    buf.seek(0)
    loaded = np.load(buf)
    return tuple(loaded[k] for k in loaded.files)


def _group_adjacency(keys: np.ndarray, vals: np.ndarray, cap: int):
    """Sort-merge groupby key -> capped neighbor lists (one MR stage)."""
    order = np.argsort(keys, kind="stable")          # the shuffle sort
    keys, vals = keys[order], vals[order]
    starts = np.flatnonzero(np.concatenate([[True], keys[1:] != keys[:-1]]))
    counts = np.diff(np.concatenate([starts, [keys.shape[0]]]))
    slot = np.arange(keys.shape[0]) - np.repeat(starts, counts)
    keep = slot < cap                                 # MaxAdjacentNodes
    return keys[keep], vals[keep], slot[keep]


def legacy_multi_account(
    user_ids: np.ndarray,
    identifier_ids: np.ndarray,
    max_adjacent_nodes: int = 100,
) -> set:
    """The 3-step Scalding job (Section IV-C-1 of the paper).

    1) user -> identifiers adjacency, 2) identifier -> users adjacency,
    3) join on identifier, group by user.  Returns distinct user pairs.
    """
    u = np.asarray(user_ids, dtype=np.int64)
    i = np.asarray(identifier_ids, dtype=np.int64)

    # Step 1: identifier neighbors per user (materialized).
    k1, v1, _ = _group_adjacency(u, i, max_adjacent_nodes)
    k1, v1 = _hdfs_barrier(k1, v1)

    # Step 2: user neighbors per identifier (materialized).
    k2, v2, _ = _group_adjacency(i, u, max_adjacent_nodes)
    k2, v2 = _hdfs_barrier(k2, v2)

    # Step 3: join step-1 output with step-2 output on identifier, then
    # group by user.  MapReduce realizes the join as another sort-merge.
    o1 = np.argsort(v1, kind="stable")     # step-1 rows keyed by identifier
    ju, jid = k1[o1], v1[o1]
    o2 = np.argsort(k2, kind="stable")
    jid2, jus = k2[o2], v2[o2]

    # merge-join jid (sorted) with jid2 (sorted)
    left_start = np.searchsorted(jid2, jid, side="left")
    left_end = np.searchsorted(jid2, jid, side="right")
    reps = (left_end - left_start).astype(np.int64)
    rows = np.repeat(np.arange(jid.shape[0]), reps)
    offs = np.arange(reps.sum()) - np.repeat(np.cumsum(reps) - reps, reps)
    idx2 = np.repeat(left_start, reps) + offs
    pa, pb = ju[rows], jus[idx2]
    (pa, pb) = _hdfs_barrier(pa, pb)

    keep = pa != pb
    lo = np.minimum(pa[keep], pb[keep])
    hi = np.maximum(pa[keep], pb[keep])
    key = lo * np.int64(1 << 32) + hi
    key = np.unique(key)                   # final group-by-user dedup
    return {(int(k >> 32), int(k & 0xFFFFFFFF)) for k in key}


def _cc_one_edge_set(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Per-edge-set CC, the way the legacy job did it: iterative min-label
    propagation where EVERY round is a materialized sort-merge stage."""
    labels = np.arange(n, dtype=np.int64)
    for _ in range(n):  # upper bound; breaks on fixpoint
        ls = labels[src]
        ld = labels[dst]
        new = labels.copy()
        np.minimum.at(new, dst, ls)
        np.minimum.at(new, src, ld)
        (new,) = _hdfs_barrier(new)        # stage boundary each round
        if np.array_equal(new, labels):
            break
        labels = new
    return labels


def legacy_connected_users(
    edge_sets: Sequence[tuple[np.ndarray, np.ndarray]],
    n_vertices: int,
) -> np.ndarray:
    """The 2-step Scalding job (Section IV-C-2): CC per identifier edge-set,
    then a merge job combining the per-set labelings."""
    per_set = []
    for src, dst in edge_sets:
        per_set.append(_cc_one_edge_set(np.asarray(src, np.int64),
                                        np.asarray(dst, np.int64),
                                        n_vertices))
    # Merge job: each per-set labeling induces (v, label) equivalences;
    # combine by iterating pairwise merges (as the legacy combine did).
    labels = np.arange(n_vertices, dtype=np.int64)
    changed = True
    while changed:
        changed = False
        for ls in per_set:
            # v ~ ls[v]: propagate the current min label through each
            # per-set group (one sort-merge stage per labeling)
            srt = np.argsort(ls, kind="stable")
            uniq_vals, uniq_idx = np.unique(ls[srt], return_index=True)
            grp_min = np.minimum.reduceat(labels[srt], uniq_idx)
            lookup = np.full(n_vertices, np.iinfo(np.int64).max)
            lookup[uniq_vals] = grp_min
            new = np.minimum(labels, lookup[ls])
            (new,) = _hdfs_barrier(new)
            if not np.array_equal(new, labels):
                labels = new
                changed = True
    return labels.astype(np.int32)
