"""Fault-tolerance: injected failures + supervisor restart must produce
bit-exact continuation; straggler watchdog flags outliers.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step, init_train_state
from repro.train.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step)
from repro.train.fault_tolerance import (
    FailureInjector, SimulatedFailure, StragglerWatchdog, Heartbeat,
    run_supervised)
from repro.data.tokens import SyntheticTokens


def _setup():
    cfg = reduced_config(get_config("smollm_360m"))
    model = build_model(cfg)
    data = SyntheticTokens(cfg.vocab_size, 16, 4, seed=0)
    step = jax.jit(make_train_step(model, AdamWConfig(peak_lr=1e-3)))
    return model, data, step


def _run(model, data, step, root, n_steps, injector=None, ckpt_every=3):
    """Checkpointed loop resuming from the last committed step."""
    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    if latest_step(root) is not None:
        state, start = restore_checkpoint(root, state)
    losses = {}
    for i in range(start, n_steps):
        if injector:
            injector.check(i)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses[i] = float(metrics["loss"])
        if (i + 1) % ckpt_every == 0:
            save_checkpoint(root, i + 1, state)
    return state, losses


def test_restart_is_bit_exact(tmp_path):
    model, data, step = _setup()
    # uninterrupted run
    s_ref, _ = _run(model, data, step, str(tmp_path / "a"), 9)
    # interrupted at step 5, supervisor restarts from ckpt at step 3
    inj = FailureInjector(fail_at_steps=[5])
    root = str(tmp_path / "b")

    def loop(_resume):
        _, losses = _run(model, data, step, root, 9, injector=inj)
        return {"steps": 9}

    report = run_supervised(loop, max_restarts=2)
    assert report.restarts == 1
    s_rec, _ = _run(model, data, step, root, 9)  # no-op rerun from ckpt
    # compare final params bit-exactly
    final_ref = jax.tree_util.tree_leaves(s_ref.params)
    final_rec = jax.tree_util.tree_leaves(s_rec.params)
    for a, b in zip(final_ref, final_rec):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    calls = []

    def loop(_):
        calls.append(1)
        raise SimulatedFailure("permanently broken")

    try:
        run_supervised(loop, max_restarts=2)
        raised = False
    except SimulatedFailure:
        raised = True
    assert raised
    assert len(calls) == 3            # initial + 2 restarts


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=2.0, warmup=2)
    for i in range(10):
        assert not wd.record(i, 1.0)
    assert wd.record(10, 5.0)           # 5x EWMA -> flagged
    assert not wd.record(11, 1.1)       # back to normal
    assert len(wd.events) == 1
    assert wd.events[0]["step"] == 10


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), interval_s=0.0)
    assert hb.age() is None
    hb.beat(5, force=True)
    age = hb.age()
    assert age is not None and age < 5.0
