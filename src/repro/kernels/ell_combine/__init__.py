from repro.kernels.ell_combine.ops import ell_spmv, ell_spmv_ref
