"""The two engines of the hybrid platform.

``LocalEngine``        — the Neo4j analogue: one device, graph resident
                         in HBM, every query jit-compiled, count-only
                         fast paths that never materialize results.
``DistributedEngine``  — the Spark/GraphFrames analogue: edge-partitioned
                         BSP supersteps over a device mesh (shard_map),
                         scales to graphs and outputs that cannot live on
                         one device.

Both are the *same* generic executor (``Engine``) configured differently:
all per-algorithm behaviour lives in the algorithm registry
(``repro.core.registry``), and the engine only owns graph state — the
exact COO, the cached ``ShardedCOO`` edge shards, the cached degree-capped
ELL adjacency, and a per-algorithm memo for runner-specific state (e.g.
PageRank's normalized partition).  ``Engine.run(defn, params)`` executes
any registered definition; adding an algorithm therefore never touches
this file — the paper's central architectural claim (Section III-A) that
a production platform grows by registration, not by re-plumbing.

Legacy per-algorithm methods (``eng.pagerank(...)``,
``eng.num_components()``) still work: they dispatch through the
registry's method table via ``__getattr__``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import (
    PregelSpec,
    SuperstepVariant,
    run_pregel,
    run_pregel_frontier,
    run_pregel_fused,
)
from repro.kernels.ell_combine import ops as ell_ops

# Byte budget for the *uncapped* ELL layouts the fused/frontier superstep
# variants execute over (every edge retained — no MaxAdjacentNodes cap,
# else results would diverge from the dense oracle).  A star graph makes
# the uncapped width V and the layout O(V^2); past this budget the
# variants silently fall back to the dense path.
SUPERSTEP_ELL_BUDGET = 512 * 1024 * 1024


@dataclasses.dataclass
class QueryResult:
    value: object                 # scalar, array, or (pairs, valid, count)
    engine: str                   # 'local' | 'distributed'
    iterations: Optional[int] = None
    meta: dict = dataclasses.field(default_factory=dict)


class Engine:
    """Generic registry-driven executor over cached graph state."""

    name = "engine"

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, max_degree: int = 128):
        self.coo = coo
        self.mesh = mesh
        self.n_data = n_data
        self.n_model = n_model
        self.max_degree = max_degree
        self._sharded: Optional[ShardedCOO] = None
        self._ell: Optional[G.GraphELL] = None
        self._oriented: Optional[G.OrientedELL] = None
        # Uncapped ELL layouts for the fused ('in') and frontier ('out')
        # superstep variants, built lazily per direction.
        self._superstep_ell: dict = {}
        # Per-algorithm memo: runners stash reusable derived state here
        # (PageRank's normalized partition, HITS' doubled-graph shards).
        self.cache: dict = {}
        self.n_runs = 0               # executed queries (cache-hit probe)
        # Measured structure observed while building derived state,
        # fed back into GraphStats by the service/platform layer.
        self._measured: dict = {}
        # Device pool binding (hybrid-cloud federation): ``pool`` is the
        # DevicePool this engine executes on (None = the process
        # default), and ``_pool_twins`` caches one pool-bound twin per
        # pool name — each twin owns its *own* ShardedCOO/ELL/
        # OrientedELL derived state, so per-pool sharded state is keyed
        # by pool behind the one ``for_pool`` seam.
        self.pool = None
        self._pool_twins: dict = {}
        # One execution at a time per engine instance: the service
        # runtime runs one worker per engine, and a direct caller racing
        # a worker must not observe a half-built ELL or two interleaved
        # writes to the per-algorithm memo.  RLock: runners re-enter the
        # lazy properties from inside run()/run_batch().
        self._exec_lock = threading.RLock()
        # Superstep profile sink: ``run(profile=True)`` installs a list
        # here (under _exec_lock) and ``run_superstep`` appends one
        # counter dict per pregel execution it performs.  ``None`` (the
        # default) means profiling is off and the hot path pays a single
        # attribute read.
        self._profile_sink: Optional[list] = None
        # Measurements are read by the *planner* path (submit-time
        # current_stats) while a worker may hold _exec_lock for a long
        # batch run — a separate lock keeps submit latency flat.
        self._meta_lock = threading.Lock()

    # -- cached graph state -------------------------------------------------
    @property
    def sharded(self) -> ShardedCOO:
        """Edge shards, packed once — repeated interactive queries must
        not repay the O(E) host-side partition."""
        with self._exec_lock:
            if self._sharded is None:
                self._sharded = partition(self.coo, self.n_data,
                                          self.n_model)
            return self._sharded

    @property
    def ell(self) -> G.GraphELL:
        """Degree-capped ELL adjacency (in-direction), built once."""
        with self._exec_lock:
            if self._ell is None:
                coo = self.coo
                src = np.asarray(coo.src)[: coo.n_edges]
                dst = np.asarray(coo.dst)[: coo.n_edges]
                w = np.asarray(coo.w)[: coo.n_edges]
                if coo.n_edges:
                    # the true (uncapped) max in-degree falls out of the
                    # ELL build for free — record it for planner stats
                    md = int(np.bincount(
                        dst, minlength=coo.n_vertices).max())
                    with self._meta_lock:
                        self._measured["max_degree"] = md
                self._ell = G.build_ell(src, dst, coo.n_vertices,
                                        self.max_degree, w=w,
                                        direction="in")
            return self._ell

    @property
    def oriented(self) -> G.OrientedELL:
        """Degree-ordered sorted-neighbor orientation, built once — the
        derived state of the ELL-intersect triangle path (exact, unlike
        the capped ``ell``; requires a symmetrized graph)."""
        with self._exec_lock:
            if self._oriented is None:
                coo = self.coo
                G.require_symmetric(coo, "oriented adjacency")
                src = np.asarray(coo.src)[: coo.n_edges]
                dst = np.asarray(coo.dst)[: coo.n_edges]
                self._oriented = G.build_oriented_ell(src, dst,
                                                      coo.n_vertices)
                with self._meta_lock:
                    self._measured["oriented_width"] = \
                        self._oriented.max_out_degree
            return self._oriented

    def _measured_degree(self, direction: str) -> int:
        """True (uncapped) max in- or out-degree, computed host-side
        once and cached — sizes the superstep ELL layouts and feeds the
        planner's measured stats."""
        key = "max_degree" if direction == "in" else "max_out_degree"
        with self._meta_lock:
            v = self._measured.get(key)
        if v is None:
            coo = self.coo
            col = coo.dst if direction == "in" else coo.src
            arr = np.asarray(col)[: coo.n_edges]
            v = int(np.bincount(arr, minlength=coo.n_vertices).max()) \
                if arr.size else 0
            with self._meta_lock:
                self._measured[key] = v
        return v

    def superstep_ell(self, direction: str) -> G.GraphELL:
        """Uncapped ELL layout for the superstep variants: ``'in'`` for
        the fused kernel (row v = sources into v), ``'out'`` for the
        frontier scan (row u = destinations of u).  Every edge retained
        — the variants must be bit-identical to the dense oracle, so
        the MaxAdjacentNodes cap of ``self.ell`` does not apply.
        ``superstep_supported`` gates on the byte budget before this is
        built."""
        with self._exec_lock:
            got = self._superstep_ell.get(direction)
            if got is None:
                coo = self.coo
                src = np.asarray(coo.src)[: coo.n_edges]
                dst = np.asarray(coo.dst)[: coo.n_edges]
                w = np.asarray(coo.w)[: coo.n_edges]
                kmax = max(self._measured_degree(direction), 1)
                got = G.build_ell(src, dst, coo.n_vertices, kmax, w=w,
                                  direction=direction)
                self._superstep_ell[direction] = got
            return got

    def superstep_supported(self, spec: PregelSpec, variant: str) -> bool:
        """Do this engine + spec satisfy the variant's preconditions?

        Dense always holds.  Fused/frontier need: single-device vertex
        state (no mesh, no model sharding), an elementwise single-monoid
        message, and an uncapped ELL within the byte budget; frontier
        additionally needs a declared (and matching) ``frontier_mode``.
        """
        if variant == "dense":
            return True
        if variant not in ("fused", "frontier"):
            raise ValueError(f"unknown superstep variant {variant!r}")
        if self.mesh is not None or self.n_model > 1:
            return False
        if (not spec.elementwise_message or spec.needs_dst_state
                or isinstance(spec.combine, tuple)):
            return False
        V = self.coo.n_vertices
        if V == 0:
            return False
        if variant == "frontier":
            if spec.frontier_mode == "monotone":
                if spec.combine not in ("min", "max"):
                    return False
            elif spec.frontier_mode == "delta":
                if spec.combine != "sum":
                    return False
            else:
                return False
        direction = "in" if variant == "fused" else "out"
        kmax = max(self._measured_degree(direction), 1)
        return V * kmax * 9 <= SUPERSTEP_ELL_BUDGET

    def run_superstep(self, spec: PregelSpec, init_state, max_iters: int,
                      variant: Optional[str] = None, init_active=None):
        """Single dispatch point for superstep execution strategies.

        ``'dense'``/``None`` is the existing gather/segment-combine path
        (``run_pregel`` — the correctness oracle).  ``'fused'`` runs the
        ELL-blocked fused kernel, ``'frontier'`` the packed active-list
        loop; both fall back to dense when ``superstep_supported`` says
        no, so a planner-forced variant never errors and the variants
        contract (identical results everywhere) holds unconditionally.
        ``'auto'`` prefers frontier, then fused, then dense.

        ``init_active`` (optional ``bool [V]``) seeds the frontier
        variant's first active set — the incremental-maintenance seam.
        The dense and fused paths recompute every vertex each round
        regardless, so the seed only narrows work where narrowing is
        exact; every variant still lands on the same fixpoint.

        With a profile sink installed (``run(profile=True)``), each
        execution appends a superstep counter dict — realized variant,
        iterations, halt step, message traffic, per-round frontier
        occupancy — computed from values the run produced anyway (plus,
        for frontier, the opt-in occupancy output).  Results are
        identical either way.
        """
        v = variant or "dense"
        if v == "auto":
            if self.superstep_supported(spec, "frontier"):
                v = "frontier"
            elif self.superstep_supported(spec, "fused"):
                v = "fused"
            else:
                v = "dense"
        sink = self._profile_sink
        if v == "fused" and self.superstep_supported(spec, "fused"):
            V = self.coo.n_vertices
            state, iters = run_pregel_fused(
                spec, self.superstep_ell("in"), init_state[:V], max_iters,
                use_pallas=getattr(self, "use_pallas", False))
            if sink is not None:
                sink.append(self._superstep_profile(
                    "fused", spec, init_state, iters, max_iters,
                    slots_per_iter=int(self.superstep_ell("in").nbr.size)))
            return state, iters
        if v == "frontier" and self.superstep_supported(spec, "frontier"):
            V = self.coo.n_vertices
            active = None if init_active is None else init_active[:V]
            if sink is None:
                return run_pregel_frontier(
                    spec, self.superstep_ell("out"), init_state[:V],
                    max_iters, init_active=active)
            ell = self.superstep_ell("out")
            state, iters, occ = run_pregel_frontier(
                spec, ell, init_state[:V], max_iters,
                init_active=active, profile=True)
            n = int(iters)
            occupancy = [int(c) for c in np.asarray(occ)[:n]]
            B = min(1024, max(V, 1))             # run_pregel_frontier's B
            K = int(ell.nbr.shape[1])
            slots = sum(-(-c // B) * B * K for c in occupancy)
            prof = self._superstep_profile(
                "frontier", spec, init_state, iters, max_iters,
                slots_total=slots)
            prof["frontier_occupancy"] = occupancy
            prof["block_rows"] = B
            sink.append(prof)
            return state, iters
        state, iters = run_pregel(spec, self.sharded, init_state,
                                  max_iters, mesh=self.mesh)
        if sink is not None:
            sink.append(self._superstep_profile(
                "dense", spec, init_state, iters, max_iters,
                slots_per_iter=int(self.coo.n_edges)))
        return state, iters

    def _superstep_profile(self, variant: str, spec: PregelSpec,
                           init_state, iters, max_iters: int,
                           slots_per_iter: Optional[int] = None,
                           slots_total: Optional[int] = None) -> dict:
        """One execution's superstep counters.  Message traffic is
        counted in *slots* (gather/scatter positions the variant
        scans per run: E per dense round, the full ELL per fused
        round, the active blocks per frontier round) times the message
        element size."""
        n = int(iters)
        if slots_total is None:
            slots_total = int(slots_per_iter or 0) * n
        itemsize = (np.dtype(spec.message_dtype).itemsize
                    if spec.message_dtype is not None
                    else np.dtype(init_state.dtype).itemsize)
        return {
            "variant": variant,
            "iterations": n,
            "max_iters": int(max_iters),
            "halted": n < int(max_iters),
            "halt_step": n,
            "message_slots": int(slots_total),
            "message_bytes": int(slots_total) * int(itemsize),
        }

    # -- device pools -------------------------------------------------------
    def for_pool(self, pool) -> "Engine":
        """The pool-bound twin of this engine (cached per pool name).

        The twin shares the exact COO but owns separate derived state —
        its ShardedCOO/ELL/OrientedELL builds land on (and stay
        resident on) the pool's devices, which is precisely the
        per-pool snapshot residency the federation planner prices.
        ``None`` (or this engine's own pool) returns ``self``; results
        are contractually identical wherever they run.
        """
        if pool is None:
            return self
        if self.pool is not None and self.pool.name == pool.name:
            return self
        with self._meta_lock:
            twin = self._pool_twins.get(pool.name)
            if twin is None:
                twin = self._clone()
                twin.pool = pool
                self._pool_twins[pool.name] = twin
            return twin

    def _clone(self) -> "Engine":
        """A fresh engine over the same COO and configuration, with no
        derived state — subclasses override to keep their extras."""
        return Engine(self.coo, mesh=self.mesh, n_data=self.n_data,
                      n_model=self.n_model, max_degree=self.max_degree)

    def pool_twins(self) -> dict:
        """Snapshot of the pool-bound twins built so far (the service
        merges their measured structure alongside this engine's)."""
        with self._meta_lock:
            return dict(self._pool_twins)

    def _device_scope(self):
        """Execution placement for a pool-bound engine: computations
        default onto the pool's first device.  A meshless engine on the
        default pool (or a pool with no devices) runs unscoped —
        exactly the pre-pool behaviour."""
        devs = getattr(self.pool, "devices", ()) if self.pool is not None \
            else ()
        if devs and self.mesh is None:
            return jax.default_device(devs[0])
        return contextlib.nullcontext()

    def measurements(self) -> dict:
        """Measured graph structure observed so far (only fields whose
        derived state this engine has actually built) — the feedback
        path that replaces the planner's analytic stand-ins, e.g. the
        triangle cost hook's d_max estimate, with ground truth.  Safe to
        call from the submit/plan path while a worker is executing."""
        with self._meta_lock:
            return dict(self._measured)

    # -- generic execution --------------------------------------------------
    def run(self, algorithm, params: Optional[dict] = None,
            count_only: bool = False,
            variant: Optional[str] = None,
            seed=None, delta=None, profile: bool = False) -> QueryResult:
        """Execute any registered algorithm on this engine's graph.

        ``variant`` selects one of the definition's registered execution
        strategies (the platform passes the planner's choice through).
        Left ``None`` on a multi-variant definition, the engine resolves
        the cheapest feasible variant for *its own* graph via the cost
        hook — so a direct ``eng.triangle_count()`` on a huge graph
        takes the linear-memory path without a planner in sight.

        ``seed`` is an ancestor snapshot's cached result for the same
        query (any object with ``.value``); ``delta`` the
        ``GraphDelta`` between that ancestor and this engine's graph.
        With both present and the definition declaring an
        ``incremental`` hook, the engine repairs the seed against the
        delta; with only a seed and a ``warm_start`` hook, it restarts
        the fixpoint from the seed.  Either hook may decline (return
        ``None``) — execution falls back to the cold runner, so seeds
        affect time, never correctness.  ``meta['mode']`` records the
        realized path ('incremental' | 'warm').

        ``profile=True`` collects superstep counters from any pregel
        loop the execution runs and attaches the last (outermost)
        one as ``meta['superstep']``.  Off (the default), no counter
        code runs at all — the traced and untraced result values are
        byte-identical either way.
        """
        defn = R.get(algorithm) if isinstance(algorithm, str) else algorithm
        if self.name not in defn.engines:
            raise ValueError(
                f"{defn.name!r} supports engine(s) {defn.engines}, "
                f"not {self.name!r}")
        p = defn.validate(params)
        if defn.requires_symmetric:
            G.require_symmetric(self.coo, defn.name)
        if variant is None and defn.variants:
            variant = self._select_variant(defn, p, count_only)
        mode = None
        count_fast = False
        sink = None
        with self._exec_lock, self._device_scope():
            self.n_runs += 1
            if profile:
                self._profile_sink = []
            try:
                # the fault-injection seam: per attempt, so the service's
                # retry loop re-triggers an installed policy on every try
                R.apply_fault(defn.name)
                count_fast = count_only and defn.count_run is not None
                if count_fast:
                    value, iters = self._invoke(defn.count_run, defn, p)
                else:
                    got = None
                    if seed is not None and delta is not None \
                            and defn.incremental is not None:
                        got = defn.incremental(self, p, seed, delta)
                        if got is not None:
                            mode = "incremental"
                    if got is None and seed is not None \
                            and defn.warm_start is not None:
                        got = defn.warm_start(self, p, seed)
                        if got is not None:
                            mode = "warm"
                    if got is not None:
                        value, iters = got
                        iters = int(iters) if iters is not None else None
                    else:
                        value, iters = self._invoke(
                            defn.runner_for(variant), defn, p)
            finally:
                if profile:
                    sink, self._profile_sink = self._profile_sink, None
        if not count_fast:
            if count_only and defn.count is not None:
                value = defn.count(value)
        meta = {}
        if not count_fast:
            if variant is not None:
                meta["variant"] = variant
            if mode is not None:
                meta["mode"] = mode
        if sink:
            meta["superstep"] = sink[-1]
        return QueryResult(value, self.name, iters, meta)

    def run_batch(self, algorithm, params_list,
                  count_only=None, profile: bool = False) -> list:
        """Execute K compatible queries of one algorithm as a single
        fused program (the service's batch-packing path, NScale-style).

        The caller guarantees compatibility — same algorithm, same graph
        (this engine's), equal ``fuse`` keys.  Returns one
        ``QueryResult`` per entry of ``params_list``, in order; each
        value is bit-identical to ``run`` on the same params alone.
        ``count_only`` is per-query: fused tickets that only want the
        count get the registered reducer applied to their slice.
        """
        defn = R.get(algorithm) if isinstance(algorithm, str) else algorithm
        if defn.batch_runner is None:
            raise ValueError(f"{defn.name!r} has no batch runner")
        if self.name not in defn.engines:
            raise ValueError(
                f"{defn.name!r} supports engine(s) {defn.engines}, "
                f"not {self.name!r}")
        co = list(count_only) if count_only is not None \
            else [False] * len(params_list)
        if len(co) != len(params_list):
            raise ValueError("count_only length mismatch")
        ps = [defn.validate(p) for p in params_list]
        if defn.requires_symmetric:
            G.require_symmetric(self.coo, defn.name)
        sink = None
        with self._exec_lock, self._device_scope():
            self.n_runs += 1
            if profile:
                self._profile_sink = []
            try:
                R.apply_fault(defn.name)  # one fused execution, one fault
                values, iters, fused_meta = defn.batch_runner(self, ps)
            finally:
                if profile:
                    sink, self._profile_sink = self._profile_sink, None
        if len(values) != len(ps):
            raise ValueError(
                f"{defn.name}: batch runner returned {len(values)} values "
                f"for {len(ps)} queries")
        iters = int(iters) if iters is not None else None
        out = []
        for i, (value, c) in enumerate(zip(values, co)):
            if c and defn.count is not None:
                value = defn.count(value)
            meta = {"fused": {"batch_size": len(ps), "index": i,
                              **(fused_meta or {})}}
            if sink:
                # one fused execution -> the same shared counters on
                # every member's result (stripped, like 'fused', from
                # cached re-serves)
                meta["superstep"] = sink[-1]
            out.append(QueryResult(value, self.name, iters, meta))
        return out

    def _select_variant(self, defn: R.AlgorithmDef, params: dict,
                        count_only: bool) -> Optional[str]:
        """Cheapest feasible variant for this engine's graph (the same
        cost hook the planner consults, restricted to this engine,
        including any structure this engine has already measured)."""
        if defn.cost is None:
            return None
        stats = P.GraphStats.of(self.coo).with_measurements(
            self.measurements())
        specs = defn.cost(stats, params, count_only)
        if isinstance(specs, P.QuerySpec):
            return specs.variant
        best = P.best_spec_for_engine(stats, specs, self.name,
                                      max(self.n_data * self.n_model, 1))
        return best.variant

    def _invoke(self, runner, defn: R.AlgorithmDef, params: dict):
        if isinstance(runner, SuperstepVariant):
            state, max_iters = defn.init(self, params)
            state, iters = self.run_superstep(runner.spec, state,
                                              max_iters,
                                              variant=runner.mode)
            return state[: self.coo.n_vertices], int(iters)
        if isinstance(runner, PregelSpec):
            state, max_iters = defn.init(self, params)
            state, iters = run_pregel(runner, self.sharded, state,
                                      max_iters, mesh=self.mesh)
            if self._profile_sink is not None:
                self._profile_sink.append(self._superstep_profile(
                    "dense", runner, state, iters, max_iters,
                    slots_per_iter=int(self.coo.n_edges)))
            return state[: self.coo.n_vertices], int(iters)
        value, iters = runner(self, **params)
        return value, (int(iters) if iters is not None else None)

    # -- registry-backed method dispatch ------------------------------------
    def __getattr__(self, name: str):
        # only reached when normal attribute lookup fails
        if name.startswith("_"):
            raise AttributeError(name)
        entry = R.method_table().get(name)
        if entry is None:
            raise AttributeError(
                f"{type(self).__name__!r} object has no attribute {name!r}")
        defn, count_only = entry
        order = [p.name for p in defn.params]

        def call(*args, variant=None, **kw):
            if len(args) > len(order):
                raise TypeError(
                    f"{name}() takes at most {len(order)} positional "
                    f"arguments ({len(args)} given)")
            merged = dict(zip(order, args))
            dup = set(merged) & set(kw)
            if dup:
                raise TypeError(
                    f"{name}() got multiple values for {sorted(dup)}")
            merged.update(kw)
            return self.run(defn, merged, count_only=count_only,
                            variant=variant)

        call.__name__ = name
        call.__doc__ = defn.doc
        return call


class LocalEngine(Engine):
    """Single-device in-memory engine (Neo4j analogue).

    Holds the graph in exact COO (+ the degree-capped ELL for motif/
    similarity queries).  Algorithm loops run through the Pallas
    ``ell_combine`` kernel path when shapes are TPU-tileable, else the
    jnp reference — same numerics.
    """

    name = "local"

    def __init__(self, coo: G.GraphCOO, max_degree: int = 128,
                 use_pallas: bool = False):
        super().__init__(coo, mesh=None, n_data=1, n_model=1,
                         max_degree=max_degree)
        self.use_pallas = use_pallas
        self._spmv = ell_ops.ell_spmv if use_pallas else ell_ops.ell_spmv_ref

    def _clone(self) -> "LocalEngine":
        return LocalEngine(self.coo, max_degree=self.max_degree,
                           use_pallas=self.use_pallas)


class DistributedEngine(Engine):
    """Edge-partitioned BSP engine over a device mesh (Spark analogue)."""

    name = "distributed"

    def __init__(self, coo: G.GraphCOO, mesh=None,
                 n_data: Optional[int] = None, n_model: int = 1,
                 max_degree: int = 128):
        if mesh is not None:
            axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            nd = axis_sizes.get("data", 1)
            nm = axis_sizes.get("model", 1) if n_model > 1 else 1
        else:
            nd = n_data or 1
            nm = n_model
        super().__init__(coo, mesh=mesh, n_data=nd, n_model=nm,
                         max_degree=max_degree)

    def _clone(self) -> "DistributedEngine":
        return DistributedEngine(self.coo, mesh=self.mesh,
                                 n_data=self.n_data, n_model=self.n_model,
                                 max_degree=self.max_degree)
