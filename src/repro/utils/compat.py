"""Version-compat shims over the moving parts of the jax API.

The repo targets the jax that ships in the container; the two APIs that
moved across releases are wrapped here so every call site stays on the
newest spelling:

* ``shard_map`` — top-level ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (<= 0.4.x), and the replication
  -check kwarg rename ``check_rep`` -> ``check_vma``.
* ``make_mesh`` — ``axis_types=`` only exists once ``jax.sharding.AxisType``
  does; older jax simply has no explicit/auto axis distinction.
"""
from __future__ import annotations

import inspect
from typing import Optional

import jax

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax <= 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` with the replication-check kwarg normalized.

    ``check_vma`` follows the current jax spelling; on older jax it is
    forwarded as ``check_rep`` (same semantics: disable the static
    replication checker, required for manual psum/all_gather bodies).
    """
    kw = {}
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kw["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


def axis_size(axis_name):
    """``lax.axis_size`` where it exists; ``psum(1, axis)`` (which
    constant-folds for literal ints) on older jax."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the CompilerParams /
    TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
