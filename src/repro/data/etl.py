"""The configurable ETL pipeline (paper Section III-C-2).

The paper's pipeline: FlockDB association dumps -> HDFS snapshots ->
(replicate to GCS) -> graph generation -> algorithm execution -> results
to BigQuery/GCS for downstream ML.  Here:

    snapshot files (npz on disk == HDFS/GCS stand-in)
      -> SnapshotStore (daily partitions, multi-snapshot union)
      -> GraphETL: dedup | remap ids | symmetrize | degree-cap | pack
      -> GraphCOO / GraphELL on device
      -> results persisted back via ResultSink (npz + manifest)

Every stage is pure and restartable; the pipeline writes a manifest with
content hashes so a restarted job skips completed stages (the same
mechanism the trainer's checkpointer uses).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core import graph as G


def _hash_arrays(*arrays: np.ndarray) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class Snapshot:
    """One daily snapshot of (src, dst) associations."""
    name: str
    src: np.ndarray
    dst: np.ndarray

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


class SnapshotStore:
    """Directory of npz snapshot partitions — the HDFS/GCS stand-in."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, snap: Snapshot) -> str:
        path = os.path.join(self.root, f"{snap.name}.npz")
        tmp = path + ".tmp.npz"   # savez appends .npz if missing
        np.savez_compressed(tmp, src=snap.src, dst=snap.dst)
        os.replace(tmp, path)     # atomic commit
        return path

    def read(self, name: str) -> Snapshot:
        data = np.load(os.path.join(self.root, f"{name}.npz"))
        return Snapshot(name, data["src"], data["dst"])

    def list(self) -> list[str]:
        return sorted(f[:-4] for f in os.listdir(self.root)
                      if f.endswith(".npz"))


@dataclasses.dataclass
class ETLReport:
    n_vertices: int
    n_edges_in: int
    n_edges_deduped: int
    n_edges_after_cap: int
    lost_fraction: float      # Table I quantity
    wall_seconds: float
    content_hash: str


class GraphETL:
    """Snapshot union -> device graph, with the paper's knobs."""

    def __init__(self, max_adjacent_nodes: Optional[int] = None,
                 symmetrize: bool = False, dedup: bool = True):
        self.cap = max_adjacent_nodes
        self.symmetrize = symmetrize
        self.dedup = dedup

    def union_snapshots(self, snaps: Iterable[Snapshot]):
        srcs, dsts = [], []
        for s in snaps:
            srcs.append(s.src)
            dsts.append(s.dst)
        return np.concatenate(srcs), np.concatenate(dsts)

    def build(self, snaps: Sequence[Snapshot],
              n_vertices: Optional[int] = None):
        """Returns (GraphCOO, GraphELL|None, ETLReport)."""
        t0 = time.time()
        src, dst = self.union_snapshots(snaps)
        n_in = src.shape[0]
        if n_vertices is None:
            n_vertices = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
        coo = G.build_coo(src, dst, n_vertices, symmetrize=self.symmetrize,
                          dedup=self.dedup)
        ell = None
        lost = 0.0
        if self.cap is not None:
            ell = G.build_ell(np.asarray(coo.src)[: coo.n_edges],
                              np.asarray(coo.dst)[: coo.n_edges],
                              n_vertices, self.cap, direction="in")
            lost = ell.lost_fraction
        report = ETLReport(
            n_vertices=n_vertices, n_edges_in=n_in,
            n_edges_deduped=coo.n_edges,
            n_edges_after_cap=ell.n_edges if ell else coo.n_edges,
            lost_fraction=lost, wall_seconds=time.time() - t0,
            content_hash=_hash_arrays(src, dst),
        )
        return coo, ell, report


class ResultSink:
    """Persist algorithm outputs + manifest (the BigQuery/GCS stand-in)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def write(self, name: str, arrays: dict, meta: dict) -> str:
        path = os.path.join(self.root, f"{name}.npz")
        np.savez_compressed(path, **{k: np.asarray(v)
                                     for k, v in arrays.items()})
        manifest = {
            "name": name, "time": time.time(),
            "meta": {k: str(v) for k, v in meta.items()},
            "arrays": {k: list(np.asarray(v).shape)
                       for k, v in arrays.items()},
        }
        with open(os.path.join(self.root, f"{name}.manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        return path

    def read(self, name: str):
        data = np.load(os.path.join(self.root, f"{name}.npz"))
        with open(os.path.join(self.root, f"{name}.manifest.json")) as f:
            manifest = json.load(f)
        return dict(data), manifest


def max_adjacent_nodes_sweep(src: np.ndarray, dst: np.ndarray,
                             n_vertices: int,
                             caps: Sequence[int]) -> list[dict]:
    """Reproduce Table I: edge retention vs MaxAdjacentNodes."""
    rows = []
    total = src.shape[0]
    for cap in caps:
        ell = G.build_ell(src, dst, n_vertices, cap, direction="in")
        rows.append({
            "max_adjacent_nodes": cap,
            "edge_count": ell.n_edges,
            "lost_percentage": 100.0 * ell.lost_fraction,
        })
        assert ell.n_edges_total == total
    return rows
