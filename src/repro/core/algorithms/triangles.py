"""Cohesion workloads: triangle counting and k-core degree-peeling.

**Triangle counting** needs neighborhood *intersection*, which a scalar
message cannot carry.  Two registered execution variants produce the
same count; the planner picks the cheaper feasible one per
(graph, engine) from the cost hook's two QuerySpecs:

* ``bitset`` — the pregel formulation over N-D vertex state: each
  vertex carries a packed neighborhood bitset (``ceil(V/32)`` uint32
  words, plus one count word), built in one superstep (sum of deduped
  one-hot rows == bitwise OR) and intersected in a second where each
  edge reads *both* endpoint states:

      superstep 1:  state[v] <- OR_{(u,v) in E} onehot(u)     (adjacency)
      superstep 2:  count[v] <- sum_{(u,v) in E} popcount(N(u) & N(v))

  On the symmetrized graph every triangle is counted six times (three
  undirected edges, two directions each), so ``total // 6`` is exact.
  Memory is O(V^2/32) bits of state and O(E * V/32) gather traffic — the
  quadratic term that caps this variant at medium V (and makes it the
  planner's choice only for small interactive graphs, Fig. 5 style).

* ``intersect`` — the degree-ordered ELL-intersection formulation
  (NScale / GraphX style): orient every undirected edge from its
  lower-(degree, id) endpoint to the higher, keep each vertex's sorted
  oriented out-neighbor row (``OrientedELL``, cached on the engine next
  to the ShardedCOO/ELL derived state), and sum
  ``|nbr[u] ∩ nbr[v]|`` over the oriented edges — each triangle counted
  exactly once at its lowest-rank edge.  The intersection runs through
  the ``kernels/ell_intersect`` Pallas kernel (jnp ``searchsorted``
  reference on CPU / non-Pallas engines).  Memory is O(V * d_max) with
  the orientation's d_max = O(sqrt(E)) — *linear* in E·d̄, so large-V
  triangle queries stay on whichever engine the cost model prefers
  instead of being forced distributed by bitset memory.

**k-core** is the classic peeling fixpoint as a scalar vertex program:
vertices stay alive while their alive-degree is >= k; one XLA while-loop
runs peeling to convergence on either engine.

Both require a symmetrized graph (``build_coo(..., symmetrize=True)``,
enforced via the ``GraphCOO.symmetric`` flag) — on a directed edge list
they would run fine but return silently wrong answers.  Self-loops are
tolerated: triangle counting clears each vertex's own bit from its
neighborhood bitset, and k-core counts a self-loop once toward degree.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, converged_halt, run_pregel
from repro.kernels.ell_intersect import ops as intersect_ops


def _n_words(n_vertices: int) -> int:
    return -(-n_vertices // 32)


# agg = summed one-hot rows of in-neighbors == their OR (edges are
# deduped so no bit is added twice); count word arrives as 0.
_ADJACENCY_SPEC = PregelSpec(
    message=lambda s, w: s,
    combine="sum",
    apply=lambda old, agg, ids, gval: agg.astype(jnp.uint32),
    identity=0)


@lru_cache(maxsize=None)
def _intersect_spec(n_words: int) -> PregelSpec:
    W = n_words

    def message(src_state, w, dst_state):
        sb, db = src_state[:, :W], dst_state[:, :W]
        common = jnp.sum(jnp.bitwise_count(sb & db).astype(jnp.uint32),
                         axis=-1)
        # a self-loop edge intersects N(v) with itself (|N(v)|, not a
        # triangle count).  With own bits cleared, adjacent *distinct*
        # vertices always differ in their bitsets (v is in N(u) but not
        # in N(v)), so bitset equality identifies exactly the loops.
        is_loop = jnp.all(sb == db, axis=-1)
        return jnp.where(is_loop, jnp.uint32(0), common)

    def apply(old, agg, ids, gval):
        return jnp.concatenate(
            [old[:, :W], agg[:, None].astype(jnp.uint32)], axis=-1)

    return PregelSpec(
        message=message, combine="sum", apply=apply, identity=0,
        needs_dst_state=True)


def triangle_count(
    g: G.GraphCOO,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``(n_triangles, per_vertex_pair_counts [V] — popcount sums
    per destination, each triangle contributing 6 across the graph)``.
    """
    G.require_symmetric(g, "triangle_count")
    V = g.n_vertices
    W = _n_words(V)
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    # own-bit bitset rows; the trailing word accumulates the pair counts
    init = np.zeros((sharded.n_pad, W + 1), dtype=np.uint32)
    ids = np.arange(V, dtype=np.int64)
    own_bits = np.uint32(1) << (ids % 32).astype(np.uint32)
    init[ids, ids // 32] = own_bits

    bitsets, _ = run_pregel(_ADJACENCY_SPEC, sharded, jnp.asarray(init),
                            max_iters=1, mesh=mesh)
    # self-loops would put v's own bit in N(v) and inflate every
    # intersection along v's edges — clear it unconditionally
    bitsets = bitsets.at[jnp.asarray(ids), jnp.asarray(ids // 32)].set(
        bitsets[jnp.asarray(ids), jnp.asarray(ids // 32)]
        & ~jnp.asarray(own_bits))
    counted, _ = run_pregel(_intersect_spec(W), sharded, bitsets,
                            max_iters=1, mesh=mesh)
    per_vertex = np.asarray(counted[:V, W]).astype(np.int64)
    return int(per_vertex.sum()) // 6, per_vertex


def triangle_count_intersect(
    g: G.GraphCOO,
    oriented: Optional[G.OrientedELL] = None,
    use_pallas: bool = False,
):
    """The linear-memory variant: degree-ordered sorted-row intersection.

    Returns ``(n_triangles, per_oriented_edge_counts [n_edges] — the
    |nbr[u] ∩ nbr[v]| term per oriented edge, summing to the exact
    count)``.  Pass a cached ``oriented`` (the engine does) to skip the
    host-side orientation build.
    """
    G.require_symmetric(g, "triangle_count")
    if oriented is None:
        oriented = G.build_oriented_ell(
            np.asarray(g.src)[: g.n_edges], np.asarray(g.dst)[: g.n_edges],
            g.n_vertices)
    counts = intersect_ops.ell_intersect_counts(oriented,
                                                use_pallas=use_pallas)
    return int(counts.sum()), counts


# ------------------------------------------------------------------- k-core

@lru_cache(maxsize=None)
def _kcore_spec(k: int) -> PregelSpec:
    def apply(alive, deg, ids, gval):
        # peeling is monotone: once dropped, never resurrected
        return jnp.where(alive > 0.5, (deg >= k).astype(jnp.float32), 0.0)

    # The 0/1 aliveness sum is integer-valued in f32 (exact for degrees
    # < 2^24), so 'delta' frontier compression is exact: changed
    # vertices scatter msg(new) - msg(old) into a carried aggregate.
    # Reduced-precision channels stay *off* (no allow_inexact_sum):
    # bf16 cannot represent degrees above 256 exactly, which would break
    # the bit-parity contract between variants.
    return PregelSpec(
        message=lambda alive, w: alive,
        combine="sum", apply=apply, identity=0.0,
        halt=converged_halt, elementwise_message=True,
        frontier_mode="delta")


def k_core(
    g: G.GraphCOO,
    k: int,
    max_iters: Optional[int] = None,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``(in_core [V] bool, iters)`` — membership in the maximal
    subgraph where every vertex has degree >= k (a self-loop counts once
    toward its vertex's degree).  ``max_iters=None`` (default) guarantees
    the peeling reaches its fixpoint (at most V rounds; the halt check
    exits far earlier in practice)."""
    G.require_symmetric(g, "k_core")
    V = g.n_vertices
    if max_iters is None:
        max_iters = V
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    init = jnp.ones(sharded.n_pad, jnp.float32)
    alive, iters = run_pregel(_kcore_spec(int(k)), sharded, init,
                              max_iters, mesh=mesh)
    return alive[:V] > 0.5, iters


def core_size(in_core) -> int:
    """Count-only fast path: |k-core| without materializing membership."""
    return int(jnp.sum(in_core))


# ------------------------------------------------------------ registration

def _tri_run_bitset(eng):
    count, _per_vertex = triangle_count(eng.coo, mesh=eng.mesh,
                                        sharded=eng.sharded)
    return count, 2


def _tri_run_intersect(eng):
    count, _per_edge = triangle_count_intersect(
        eng.coo, oriented=eng.oriented,
        use_pallas=getattr(eng, "use_pallas", False))
    return count, 1


def oriented_degree_estimate(n_vertices: int, n_edges: int) -> float:
    """Analytic stand-in for the degree-ordered orientation's max
    out-degree, which the planner cannot know without building the
    adjacency: near the mean degree on heavy-tailed graphs (hubs rank
    last and mostly *receive*), never above the sqrt(2E) arboricity-style
    bound.  A calibration target like the other planner constants."""
    avg = n_edges / max(n_vertices, 1)
    return max(min((2.0 * max(n_edges, 1)) ** 0.5, 2.0 * avg + 16.0), 1.0)


def _tri_cost(g: P.GraphStats, params: dict, count_only: bool):
    # bitset: two supersteps over neighborhood bitsets of ceil(V/32)
    # words — sized with the runner's own _n_words (ceil), not floor
    word_bytes = 4.0 * max(_n_words(g.n_vertices), 1)
    bitset = P.QuerySpec("triangle_count", 1, iterations=2,
                         state_bytes_per_vertex=word_bytes,
                         edge_bytes_factor=max(2 * word_bytes / 12, 1.0),
                         variant="bitset")
    # intersect: one pass over the oriented edges; resident state is the
    # sorted out-neighbor rows (~4*d_max B/vertex), per-edge work is the
    # K x K lane-compare (charged as compute-equivalent bytes — the
    # merge is VPU-bound, not bandwidth-bound, once rows fit VMEM tiles).
    # Once an engine has built the OrientedELL its *measured* row width
    # flows back through GraphStats and replaces the analytic estimate.
    if g.oriented_width is not None:
        d_hat = max(float(g.oriented_width), 1.0)
    else:
        d_hat = oriented_degree_estimate(g.n_vertices, g.n_edges)
    intersect = P.QuerySpec("triangle_count", 1, iterations=1,
                            state_bytes_per_vertex=4.0 * d_hat,
                            edge_bytes_factor=max(d_hat * d_hat / 12.0, 1.0),
                            variant="intersect")
    return (bitset, intersect)


R.register(R.AlgorithmDef(
    name="triangle_count",
    run=_tri_run_bitset,
    variants={"bitset": _tri_run_bitset, "intersect": _tri_run_intersect},
    cost=_tri_cost,
    requires_symmetric=True,
    doc="Global triangle count; bitset intersection on small graphs, "
        "degree-ordered sorted-ELL intersection beyond the bitset wall.",
))


def _kcore_run(eng, k, max_iters):
    return k_core(eng.coo, k, max_iters=max_iters, mesh=eng.mesh,
                  sharded=eng.sharded)


def _kcore_variant(mode):
    """Superstep-variant runner: same init as ``k_core``, dispatched
    through the engine's superstep choke point."""
    def run(eng, k, max_iters):
        G.require_symmetric(eng.coo, "k_core")
        V = eng.coo.n_vertices
        mi = max_iters if max_iters is not None else V
        init = jnp.ones(eng.sharded.n_pad, jnp.float32)
        alive, iters = eng.run_superstep(_kcore_spec(int(k)), init, mi,
                                         variant=mode)
        return alive[:V] > 0.5, int(iters)
    return run


def _kcore_cost(g: P.GraphStats, params: dict, count_only: bool):
    iters = min(10, params.get("max_iters") or 10)
    return P.superstep_specs("k_core",
                             output_rows=1 if count_only else g.n_vertices,
                             iterations=iters, state_bytes_per_vertex=4.0)


def _kcore_incremental(eng, params, seed, delta):
    """Localized repair for *removal-only* deltas: removing edges can
    only shrink the core (any subgraph with min degree >= k in the new
    graph had it in the old one), so ``core_new ⊆ core_old`` and
    peeling the new graph *from the old membership* reaches the k-core
    of the old core's induced subgraph — which is exactly ``core_new``.
    Membership is a canonical bool vector, so the repaired result is
    byte-identical to a cold peel from all-alive.  Added edges can grow
    the core (dropped vertices would need to resurrect), so those
    decline, as does an explicit iteration cap (truncated-peeling
    semantics) or a budget-exhausted run."""
    if delta is None or delta.n_added or params["max_iters"] is not None:
        return None
    prev = np.asarray(getattr(seed, "value", seed))
    V = eng.coo.n_vertices
    if prev.ndim != 1 or prev.shape[0] != V or prev.dtype != np.bool_:
        return None
    mi = V
    init = np.zeros(eng.sharded.n_pad, dtype=np.float32)
    init[:V] = prev.astype(np.float32)
    alive, iters = eng.run_superstep(_kcore_spec(int(params["k"])),
                                     jnp.asarray(init), mi, variant="auto")
    if int(iters) >= mi:
        return None
    return alive[:V] > 0.5, int(iters)


R.register(R.AlgorithmDef(
    name="k_core",
    run=_kcore_run,
    params=(
        R.Param("k", R.REQUIRED, check=lambda k: k >= 1, normalize=int),
        R.Param("max_iters", None, check=lambda n: n >= 1, normalize=int),
    ),
    count=core_size,
    count_method="k_core_size",
    cost=_kcore_cost,
    variants={"dense": _kcore_variant("dense"),
              "fused": _kcore_variant("fused"),
              "frontier": _kcore_variant("frontier")},
    requires_symmetric=True,
    incremental=_kcore_incremental,
    example_params={"k": 3},
    doc="k-core membership via degree peeling to fixpoint.",
))


# ---------------------------------------------------------------- oracles

def triangle_count_reference(src, dst, n_vertices: int) -> int:
    """Dense-matmul oracle: trace(A^3) / 6 on the symmetrized 0/1
    adjacency (small graphs only)."""
    a = np.zeros((n_vertices, n_vertices), dtype=np.int64)
    s = np.asarray(src)
    d = np.asarray(dst)
    a[s, d] = 1
    a[d, s] = 1
    np.fill_diagonal(a, 0)
    return int(np.trace(a @ a @ a)) // 6


def k_core_reference(src, dst, n_vertices: int, k: int) -> np.ndarray:
    """Iterative peeling oracle on the symmetrized edge list."""
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    alive = np.ones(n_vertices, dtype=bool)
    while True:
        keep = alive[s] & alive[d]
        deg = np.bincount(d[keep], minlength=n_vertices)
        drop = alive & (deg < k)
        if not drop.any():
            return alive
        alive[drop] = False
