from repro.kernels.pregel_superstep.ops import (
    fused_superstep,
    fused_superstep_ref,
)

__all__ = ["fused_superstep", "fused_superstep_ref"]
