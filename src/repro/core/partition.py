"""Edge partitioning for the distributed engine.

The Spark analogue: GraphFrames hash-partitions edge DataFrames across
executors.  On a TPU mesh we pre-partition host-side into fixed-size edge
shards so one BSP superstep is a single statically-shaped `shard_map`:

* **1-D** (``vertex_layout='replicated'``): edges split evenly over the
  ``data`` axis, vertex state replicated.  Per-superstep communication is
  one ``psum``/``pmin`` of the vertex aggregate over ``data``.
* **2-D** (``vertex_layout='sharded'``): the vertex-cut.  The ``model``
  axis owns contiguous destination ranges; each (data, model) shard holds
  edges whose dst falls in its range.  Vertex state is sharded over
  ``model`` and materialized per-superstep with one ``all_gather`` —
  the TPU analogue of GraphX's 2-D vertex-cut shuffle.

Partitioning is host-side numpy (ETL territory), output arrays are laid
out shard-major so ``PartitionSpec`` along the leading dim places each
shard on its device without resharding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphCOO, round_up


@dataclasses.dataclass
class ShardedCOO:
    """Edge shards laid out shard-major along the leading axis.

    ``src/dst/w`` have shape ``[n_shards * e_shard]``; slice ``i`` is
    shard ``i``.  For 2-D partitioning ``n_shards == n_data * n_model``
    and shard ``(d, m)`` sits at index ``d * n_model + m`` (mesh-major
    order for ``PartitionSpec(('data', 'model'))``).
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    n_vertices: int
    n_edges: int
    n_data: int
    n_model: int          # 1 for 1-D partitioning
    e_shard: int
    v_local: int          # vertices owned per model shard (V for 1-D)

    @property
    def vertex_layout(self) -> str:
        return "replicated" if self.n_model == 1 else "sharded"

    @property
    def n_pad(self) -> int:
        """Length of a full vertex-state array (``n_model * v_local``;
        1-D layouts set ``v_local = n_vertices``, so this is V there)."""
        return self.n_model * self.v_local


def _pack_shards(groups, e_shard, sentinel):
    """Stack variable-size edge groups into a padded shard-major array."""
    n = len(groups)
    src = np.full((n, e_shard), sentinel, dtype=np.int32)
    dst = np.full((n, e_shard), sentinel, dtype=np.int32)
    w = np.zeros((n, e_shard), dtype=np.float32)
    for i, (s, d, ww) in enumerate(groups):
        k = s.shape[0]
        src[i, :k], dst[i, :k], w[i, :k] = s, d, ww
    return src.reshape(-1), dst.reshape(-1), w.reshape(-1)


def partition_1d(g: GraphCOO, n_data: int, pad_multiple: int = 256) -> ShardedCOO:
    """Round-robin edge split over the data axis (vertex state replicated)."""
    src = np.asarray(g.src)[: g.n_edges]
    dst = np.asarray(g.dst)[: g.n_edges]
    w = np.asarray(g.w)[: g.n_edges]
    e_shard = max(pad_multiple, round_up(-(-g.n_edges // n_data), pad_multiple))
    groups = []
    for d in range(n_data):
        sel = slice(d, None, n_data)  # strided → balanced across dst ranges
        groups.append((src[sel], dst[sel], w[sel]))
    s, dd, ww = _pack_shards(groups, e_shard, np.int32(g.n_vertices))
    return ShardedCOO(
        src=jnp.asarray(s), dst=jnp.asarray(dd), w=jnp.asarray(ww),
        n_vertices=g.n_vertices, n_edges=g.n_edges,
        n_data=n_data, n_model=1, e_shard=e_shard, v_local=g.n_vertices,
    )


def partition_2d(
    g: GraphCOO, n_data: int, n_model: int, pad_multiple: int = 256
) -> ShardedCOO:
    """Vertex-cut: model axis owns dst ranges, data axis splits within."""
    src = np.asarray(g.src)[: g.n_edges]
    dst = np.asarray(g.dst)[: g.n_edges]
    w = np.asarray(g.w)[: g.n_edges]
    v_local = -(-g.n_vertices // n_model)
    owner = np.minimum(dst // v_local, n_model - 1)
    groups = []
    max_block = 0
    for m in range(n_model):
        sel = owner == m
        sm, dm, wm = src[sel], dst[sel], w[sel]
        per_d = []
        for d in range(n_data):
            ss = slice(d, None, n_data)
            per_d.append((sm[ss], dm[ss], wm[ss]))
            max_block = max(max_block, per_d[-1][0].shape[0])
        groups.append(per_d)
    e_shard = max(pad_multiple, round_up(max_block, pad_multiple))
    flat = [groups[m][d] for d in range(n_data) for m in range(n_model)]
    s, dd, ww = _pack_shards(flat, e_shard, np.int32(g.n_vertices))
    return ShardedCOO(
        src=jnp.asarray(s), dst=jnp.asarray(dd), w=jnp.asarray(ww),
        n_vertices=g.n_vertices, n_edges=g.n_edges,
        n_data=n_data, n_model=n_model, e_shard=e_shard, v_local=v_local,
    )


def partition(g: GraphCOO, n_data: int, n_model: int = 1, **kw) -> ShardedCOO:
    if n_model <= 1:
        return partition_1d(g, n_data, **kw)
    return partition_2d(g, n_data, n_model, **kw)
