"""Cost-based router tests: the Fig. 5 crossover must emerge from the
model, and the paper-scale workloads must route to the right engine.
"""
import pytest

from repro.core import planner as P


def _stats(v, e):
    return P.GraphStats(n_vertices=v, n_edges=e, bytes_coo=e * 12)


def test_small_graph_small_output_routes_local():
    g = _stats(400_000, 2_000_000)
    q = P.spec_for("connected_components", g, count_only=True)
    assert P.choose_engine(g, q, 256).engine == "local"


def test_huge_graph_routes_distributed():
    # paper scale: combined connected users, 2.41B vertices 1.5B edges
    g = _stats(2_410_000_000, 1_500_000_000)
    q = P.spec_for("connected_components", g)
    plan = P.choose_engine(g, q, 256)
    assert plan.engine == "distributed"
    assert plan.est_local_s == float("inf")     # exceeds local memory


def test_multi_account_scale_routes_distributed():
    # paper scale: 14.89B vertices, 30.86B edges heterogeneous graph
    g = _stats(14_890_000_000, 30_860_000_000)
    q = P.spec_for("two_hop", g)
    assert P.choose_engine(g, q, 256).engine == "distributed"


def test_output_cardinality_flips_engine():
    """Fig. 5's second finding: same graph, count vs table changes the
    winner (Neo4j count in 2s vs Spark 10min)."""
    g = _stats(10_000_000, 50_000_000)
    q_count = P.spec_for("connected_components", g, count_only=True)
    q_pairs = P.spec_for("two_hop", g,
                         expected_pairs=2_000_000_000)
    plan_count = P.choose_engine(g, q_count, 256)
    plan_pairs = P.choose_engine(g, q_pairs, 256)
    assert plan_count.engine == "local"
    assert plan_pairs.engine == "distributed"


def test_crossover_exists():
    """Sweeping graph size, the winner must flip exactly once from local
    to distributed (the Fig. 5 shape)."""
    q_engine = []
    for v in [10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
        g = _stats(v, v * 5)
        q = P.spec_for("pagerank", g)
        q_engine.append(P.choose_engine(g, q, 256).engine)
    assert q_engine[0] == "local"
    assert q_engine[-1] == "distributed"
    flips = sum(a != b for a, b in zip(q_engine, q_engine[1:]))
    assert flips == 1


def test_cost_estimates_positive_and_ordered():
    g = _stats(1_000_000, 8_000_000)
    q = P.spec_for("pagerank", g)
    tl = P.estimate_local_cost(g, q)
    td = P.estimate_dist_cost(g, q, 256)
    assert tl > 0 and td > 0
