"""Pallas TPU kernel: fused Pregel superstep over ELL edge blocks.

The dense superstep lowers to three XLA ops — gather src state along
edges, the edge program over an [E] message tensor, segment-combine to
destinations — each a separate HBM round trip over O(E) data.  This
kernel fuses all three into one pass over the fixed-width in-neighbor
matrix:

    agg[v] = reduce_k( op, mask[v,k] ? message(x[nbr[v,k]], w[v,k])
                                     : fill )

The [E] message tensor is never materialized: messages live only in
VMEM registers between the gather and the row-reduction.

TPU mapping
-----------
* Grid over row tiles of ``R`` destination vertices.  Each step streams
  a ``(R, K)`` tile of ``nbr``/``mask``/``w`` from HBM and keeps the
  whole gather source ``x`` VMEM-resident (the ops wrapper enforces a
  byte budget and falls back to the jnp reference beyond it).
* ``message`` is inlined into the kernel body — it must be elementwise
  jnp code (the ``PregelSpec.elementwise_message`` contract), so it
  compiles to VPU ops over the gathered tile.
* The combine is a VPU row-reduction straight into the [R] output tile:
  no segment-sort, no scatter, no second kernel launch.
* With ``message_dtype`` set, messages are cast before the reduce — the
  mixed-precision channel.  The reduce and output then carry the
  reduced dtype, exactly as the dense path's combine does.

VMEM budget per step: R*K*(4+4+1) bytes for the tile + x bytes
(+ R*out_itemsize).  Default R=512, K<=1024, x<=16 MiB -> well under
the ~16 MB VMEM ceiling for typical K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _superstep_kernel(nbr_ref, mask_ref, w_ref, x_ref, y_ref, *,
                      message, op: str, fill, message_dtype):
    nbr = nbr_ref[...]                       # (R, K) int32
    msk = mask_ref[...]                      # (R, K) stored int8
    w = w_ref[...]                           # (R, K)
    x = x_ref[...]                           # (Vx,) — VMEM resident
    vals = jnp.take(x, jnp.clip(nbr, 0, x.shape[0] - 1), axis=0)
    msgs = message(vals, w)
    if message_dtype is not None:
        msgs = msgs.astype(message_dtype)
    contrib = jnp.where(msk != 0, msgs, jnp.asarray(fill, msgs.dtype))
    red = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[op]
    y_ref[...] = red(contrib, axis=1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "message", "op", "fill", "message_dtype", "out_dtype", "block_rows",
    "interpret"))
def superstep_pallas(nbr, mask, w, x, *, message, op: str, fill,
                     message_dtype=None, out_dtype=None,
                     block_rows: int = 512, interpret: bool = False):
    """Tiled pallas_call. Caller guarantees: V % block_rows == 0,
    K % 128 == 0 (ops.py pads), x is 1-D and fits VMEM, ``message`` is
    elementwise/shape-polymorphic with stable identity (module-level
    function — it keys this jit cache)."""
    V, K = nbr.shape
    grid = (V // block_rows,)
    out_dtype = x.dtype if out_dtype is None else out_dtype
    return pl.pallas_call(
        functools.partial(_superstep_kernel, message=message, op=op,
                          fill=fill, message_dtype=message_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # nbr tile
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # mask tile
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # w tile
            pl.BlockSpec(x.shape, lambda i: (0,)),             # x resident
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((V,), out_dtype),
        interpret=interpret,
    )(nbr, mask.astype(jnp.int8), w, x)
