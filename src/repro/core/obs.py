"""End-to-end query observability: span traces, superstep profiles, and
the planner's estimate-vs-actual feedback loop.

The service makes many invisible decisions per ticket — pool placement,
engine, variant, incremental-vs-full mode, fusion, spill, retries — and
until now exposed only aggregate counters.  This module is the answer to
"where did my query spend its time, and why did the planner put it
there?", the per-query monitoring the paper's companion SQL-serving
system runs its interactive tiers against:

* :class:`Tracer` — a thread-safe recorder producing one **span tree
  per ticket** (submit → admission → plan → queue-wait → attempt[n] →
  execute → resolve).  The plan span carries the *full* candidate table
  the planner considered (every (pool, engine, variant, mode) with its
  cost terms — :class:`repro.core.planner.PlanCandidate`), not just the
  winner; execute spans carry the superstep counters the engine
  collected (iterations, per-round frontier occupancy, message bytes,
  halt step).  Traces live in a ring buffer bounded by ``trace_depth``
  (the ``history_size`` idiom), so a long-lived service never accretes
  unbounded spans.  Tracing observes — it never changes scheduling,
  results, or the determinism digests.
* :class:`PlanAccuracyMeter` — records planner ``est_s`` against the
  measured execution wall per (algorithm, engine, variant, pool), the
  measured-vs-modeled residue the ROADMAP's calibration item needs.
  :meth:`PlanAccuracyMeter.calibration_samples` emits the
  ``{algorithm: [(measured, modeled), ...]}`` shape that
  ``benchmarks/algo_suite.emit_calibration`` fits, so refits can source
  from production traces instead of dedicated sweeps.  (The estimates
  already include the active profile's per-algorithm scale, so a refit
  from these pairs is a *relative* correction on top of it.)
* Surfaces — :func:`render_trace` (the human-readable tree behind
  ``service.explain``), :meth:`Tracer.export_chrome_trace`
  (Chrome/Perfetto trace-event JSON, validated by
  :func:`validate_chrome_trace`), and :func:`render_prometheus`
  (text exposition of the ``metrics()`` dict; :func:`parse_prometheus`
  is the round-trip check).
* A process-wide **observer seam** (:func:`install_observer` /
  :func:`emit`) for layers with no tracer in reach: the registry's
  fault-injection hook and the runtime's transfer ledger emit events
  through it.  With no observers installed, ``emit`` is one falsy check
  — the off path stays free.

This module is deliberately pure stdlib (no jax, no sibling imports),
so every core layer can import it without cycles.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
import threading
import time
import weakref
from collections import OrderedDict, deque
from typing import Iterable, Optional

__all__ = [
    "Span", "TicketTrace", "Tracer", "PlanAccuracyMeter",
    "render_trace", "render_prometheus", "parse_prometheus",
    "validate_chrome_trace", "install_observer", "uninstall_observer",
    "emit",
]


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Span:
    """One timed node of a ticket's trace tree.

    ``t0``/``t1`` are ``time.perf_counter`` seconds (``t1`` is ``None``
    while the span is open).  ``attrs`` hold structured payloads (the
    plan span's candidate table, the execute span's superstep
    counters); ``events`` are instantaneous ``(t, name, attrs)`` marks
    (cache hits, transfers, retries).  A span may be *shared* between
    tickets — a fused group's execute span appears in every member's
    attempt, carrying one per-ticket child span each (``span_id``
    identifies it across trees)."""

    span_id: int
    name: str
    t0: float
    t1: Optional[float] = None
    attrs: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    @property
    def duration_s(self) -> Optional[float]:
        if self.t1 is None:
            return None
        return self.t1 - self.t0

    def child(self, span_id: int, name: str, t0: float,
              **attrs) -> "Span":
        s = Span(span_id, name, t0, attrs=dict(attrs))
        self.children.append(s)
        return s

    def event(self, t: float, name: str, attrs: Optional[dict] = None) \
            -> None:
        self.events.append((t, name, dict(attrs or {})))

    def find(self, name: str) -> Optional["Span"]:
        """First span named ``name`` in this subtree (depth-first)."""
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def find_all(self, name: str) -> list:
        return [s for s in self.walk() if s.name == name]

    def walk(self) -> Iterable["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


@dataclasses.dataclass
class TicketTrace:
    """One ticket's span tree plus the identifying header fields."""

    ticket_id: int
    graph_name: str
    algorithm: str
    tier: str
    root: Span

    def find(self, name: str) -> Optional[Span]:
        return self.root.find(name)

    def find_all(self, name: str) -> list:
        return self.root.find_all(name)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class Tracer:
    """Bounded, thread-safe span recorder for the service runtime.

    ``trace_depth`` caps the number of *retained ticket traces* (ring
    buffer: finishing trace N+1 evicts the oldest, counted in
    ``counters['evicted']``) and the global event stream
    (fault/transfer events arriving through the observer seam).  All
    mutation happens under one lock; the service calls in from its own
    locked sections, and the tracer never calls back out, so the lock
    order is acyclic.

    Timing uses ``time.perf_counter`` — wall-clock content varies run
    to run, but the tree *structure* per ticket is a pure function of
    the schedule, and recording never perturbs the schedule or the
    results (the determinism digests hold bit-identical with tracing
    on).
    """

    def __init__(self, trace_depth: int = 256,
                 clock=time.perf_counter):
        if trace_depth < 1:
            raise ValueError("trace_depth must be >= 1")
        self.trace_depth = int(trace_depth)
        self.clock = clock
        self._lock = threading.RLock()
        self._traces: OrderedDict[int, TicketTrace] = OrderedDict()
        self._next_span = 0
        self.counters = {"tickets": 0, "spans": 0, "evicted": 0,
                         "events": 0}
        self.events: deque = deque(maxlen=self.trace_depth * 4)

    # -- internals ----------------------------------------------------------
    def _sid(self) -> int:
        self._next_span += 1
        self.counters["spans"] += 1
        return self._next_span

    def _span(self, name: str, t0: float, **attrs) -> Span:
        return Span(self._sid(), name, t0, attrs=dict(attrs))

    def trace(self, ticket_id: int) -> Optional[TicketTrace]:
        with self._lock:
            return self._traces.get(ticket_id)

    def traces(self) -> list:
        with self._lock:
            return list(self._traces.values())

    def counters_snapshot(self) -> dict:
        with self._lock:
            return {"enabled": 1, "depth": self.trace_depth,
                    "retained": len(self._traces), **self.counters}

    # -- lifecycle hooks (called by the service) ----------------------------
    def on_submit(self, ticket, t_submit: float, *,
                  admission: dict, plan_attrs: dict,
                  candidates: tuple = (),
                  original_placement: Optional[dict] = None) -> None:
        """Open a ticket's trace: root + submit(admission, plan) spans,
        then the queue-wait span.  ``original_placement`` records the
        pre-spill plan when the submit path re-placed the ticket."""
        now = self.clock()
        with self._lock:
            root = self._span("ticket", t_submit,
                              ticket_id=ticket.ticket_id,
                              graph=ticket.graph_name,
                              algorithm=ticket.query.algorithm,
                              tier=ticket.tier, est_s=ticket.est_s)
            submit = root.child(self._sid(), "submit", t_submit)
            submit.t1 = now
            adm = submit.child(self._sid(), "admission", t_submit,
                               **admission)
            adm.t1 = now
            plan = submit.child(self._sid(), "plan", t_submit,
                                **plan_attrs)
            plan.t1 = now
            plan.attrs["candidates"] = [
                dataclasses.asdict(c) if dataclasses.is_dataclass(c)
                else dict(c) for c in candidates]
            if original_placement is not None:
                plan.attrs["spilled"] = True
                plan.attrs["original_placement"] = dict(
                    original_placement)
            root.child(self._sid(), "queue-wait", now)
            tr = TicketTrace(ticket.ticket_id, ticket.graph_name,
                             ticket.query.algorithm, ticket.tier, root)
            self._traces[ticket.ticket_id] = tr
            self.counters["tickets"] += 1
            while len(self._traces) > self.trace_depth:
                self._traces.popitem(last=False)
                self.counters["evicted"] += 1

    def on_dequeue(self, ticket_ids: Iterable[int]) -> None:
        """Close the queue-wait span — the ticket was claimed."""
        now = self.clock()
        with self._lock:
            for tid in ticket_ids:
                tr = self._traces.get(tid)
                if tr is None:
                    continue
                qw = tr.find("queue-wait")
                if qw is not None and qw.t1 is None:
                    qw.t1 = now
                    qw.attrs["wait_s"] = now - qw.t0

    def on_attempt_start(self, ticket_ids: list, attempt: int,
                         fused: bool = False) -> dict:
        """Open attempt spans (one per ticket) around one shared
        execute span.  Solo units share trivially (one ticket); a
        fused group's members all point at the *same* execute Span
        object, which carries one ``ticket[i]`` child per member —
        the 'one execution, K tickets' shape made visible."""
        now = self.clock()
        with self._lock:
            execute = self._span("execute", now, fused=fused)
            if fused:
                execute.attrs["group"] = list(ticket_ids)
                for tid in ticket_ids:
                    execute.child(self._sid(), "ticket", now,
                                  ticket_id=tid)
            attempts = {}
            for tid in ticket_ids:
                tr = self._traces.get(tid)
                if tr is None:
                    continue
                span = tr.root.child(self._sid(), "attempt", now,
                                     attempt=attempt)
                span.children.append(execute)
                attempts[tid] = span
            return {"execute": execute, "attempts": attempts,
                    "attempt": attempt}

    def on_attempt_end(self, handle: dict,
                       error: Optional[BaseException] = None) -> None:
        """Close one attempt.  A failure records the error — and, on
        the final attempt of a dead-lettering ticket, the full
        ``__cause__`` chain rides along (attempt k's error is the
        cause of attempt k+1's)."""
        now = self.clock()
        with self._lock:
            execute = handle["execute"]
            if execute.t1 is None:
                execute.t1 = now
            for child in execute.children:
                if child.t1 is None:
                    child.t1 = now
            for span in handle["attempts"].values():
                span.t1 = now
                if error is not None:
                    span.attrs["error"] = repr(error)
                    span.attrs["error_chain"] = _error_chain(error)

    def on_retry(self, ticket_ids: Iterable[int], attempt: int,
                 sleep_s: float) -> None:
        self.ticket_event(ticket_ids, "retry",
                          {"after_attempt": attempt, "sleep_s": sleep_s})

    def on_execute_result(self, ticket_ids: list, *, engine: str,
                          attrs: dict,
                          per_ticket: Optional[dict] = None) -> None:
        """Annotate the most recent execute span with what actually ran
        (engine, realized variant/mode, iterations, superstep
        counters).  ``per_ticket`` adds attrs onto a fused group's
        per-ticket child spans."""
        with self._lock:
            execute = self._last_execute(ticket_ids)
            if execute is None:
                return
            execute.attrs["engine"] = engine
            execute.attrs.update(attrs)
            if per_ticket:
                for child in execute.children:
                    tid = child.attrs.get("ticket_id")
                    if tid in per_ticket:
                        child.attrs.update(per_ticket[tid])

    def _last_execute(self, ticket_ids: list) -> Optional[Span]:
        for tid in ticket_ids:
            tr = self._traces.get(tid)
            if tr is None:
                continue
            attempts = tr.find_all("attempt")
            if not attempts:
                continue
            for child in attempts[-1].children:
                if child.name == "execute":
                    return child
        return None

    def on_resolve(self, ticket_ids: Iterable[int], status: str,
                   error: Optional[BaseException] = None) -> None:
        """Close the root: the ticket reached ``done`` /
        ``dead-letter`` (or resolved straight from the cache)."""
        now = self.clock()
        with self._lock:
            for tid in ticket_ids:
                tr = self._traces.get(tid)
                if tr is None:
                    continue
                resolve = tr.root.child(self._sid(), "resolve", now,
                                        status=status)
                resolve.t1 = now
                if error is not None:
                    resolve.attrs["error"] = repr(error)
                tr.root.t1 = now
                tr.root.attrs["status"] = status

    def ticket_event(self, ticket_ids: Iterable[int], name: str,
                     attrs: Optional[dict] = None) -> None:
        """Record an instantaneous event on each ticket's root span
        (cache hits, transfers, spills, retries)."""
        now = self.clock()
        with self._lock:
            for tid in ticket_ids:
                tr = self._traces.get(tid)
                if tr is not None:
                    tr.root.event(now, name, attrs)

    # -- observer seam ------------------------------------------------------
    def record_event(self, kind: str, attrs: dict) -> None:
        """Sink for :func:`emit` — the global (non-ticket-scoped) event
        stream: registry fault injections, ledger transfers."""
        with self._lock:
            self.events.append((self.clock(), kind, dict(attrs)))
            self.counters["events"] += 1

    # -- chrome trace export ------------------------------------------------
    def export_chrome_trace(self, path=None) -> dict:
        """Write (and return) the trace in Chrome/Perfetto trace-event
        JSON: one timeline row (``tid``) per ticket, complete ('X')
        events for spans, instant ('i') events for marks.  A fused
        group's shared execute span is emitted on every member's row
        (same ``args.span_id``) so each ticket's timeline is complete
        on its own."""
        events = []
        with self._lock:
            traces = list(self._traces.values())
        for tr in traces:
            for s in tr.root.walk():
                t1 = s.t1 if s.t1 is not None else s.t0
                events.append({
                    "name": s.name, "cat": "service", "ph": "X",
                    "ts": s.t0 * 1e6, "dur": max(t1 - s.t0, 0.0) * 1e6,
                    "pid": 1, "tid": tr.ticket_id,
                    "args": _json_safe({"span_id": s.span_id, **s.attrs}),
                })
                for (t, name, attrs) in s.events:
                    events.append({
                        "name": name, "cat": "event", "ph": "i",
                        "ts": t * 1e6, "s": "t",
                        "pid": 1, "tid": tr.ticket_id,
                        "args": _json_safe(attrs),
                    })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def _error_chain(error: BaseException) -> list:
    chain, e = [], error
    while e is not None and len(chain) < 32:
        chain.append(f"{type(e).__name__}: {e}")
        e = e.__cause__
    return chain


def _json_safe(value):
    if isinstance(value, dict):
        return {str(k): _json_safe(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_json_safe(v) for v in value]
    if isinstance(value, float) and not math.isfinite(value):
        return repr(value)
    if isinstance(value, (str, int, float, bool)) or value is None:
        return value
    return repr(value)


def validate_chrome_trace(doc) -> int:
    """Validate trace-event JSON structure (a path, a JSON string, or
    the loaded object).  Returns the event count; raises ``ValueError``
    on the first violation — the CI schema gate."""
    if isinstance(doc, str):
        try:
            with open(doc) as f:
                doc = json.load(f)
        except OSError:
            doc = json.loads(doc)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("chrome trace: top level must be an object "
                         "with a 'traceEvents' array")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("chrome trace: 'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"chrome trace: event {i} is not an object")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(
                    f"chrome trace: event {i} missing {field!r}")
        if not isinstance(ev["name"], str):
            raise ValueError(f"chrome trace: event {i} name not a string")
        if ev["ph"] not in ("X", "i", "B", "E", "M"):
            raise ValueError(
                f"chrome trace: event {i} has unknown phase "
                f"{ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"chrome trace: event {i} bad ts")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                raise ValueError(
                    f"chrome trace: complete event {i} needs dur >= 0")
    return len(events)


# ---------------------------------------------------------------------------
# explain() rendering
# ---------------------------------------------------------------------------

def _ms(seconds: Optional[float]) -> str:
    if seconds is None:
        return "open"
    return f"{seconds * 1e3:.2f} ms"


def _candidate_lines(plan_span: Span) -> list:
    cands = plan_span.attrs.get("candidates") or []
    if not cands:
        return []
    chosen = [c for c in cands if c.get("chosen")]
    chosen_est = chosen[0]["est_s"] if chosen else None
    lines = ["candidates (pool/engine/variant/mode):"]

    def order(c):
        est = c.get("est_s")
        return (not c.get("chosen"), not c.get("feasible", True),
                est if isinstance(est, (int, float))
                and math.isfinite(est) else float("inf"))

    for c in sorted(cands, key=order):
        where = "/".join(str(c.get(k)) if c.get(k) is not None else "-"
                         for k in ("pool", "engine", "variant", "mode"))
        est = c.get("est_s")
        est_txt = (f"{est * 1e3:9.3f} ms"
                   if isinstance(est, (int, float)) and math.isfinite(est)
                   else "      inf   ")
        if c.get("chosen"):
            why = "<- chosen"
        elif not c.get("feasible", True):
            why = f"infeasible: {c.get('note') or 'cost is infinite'}"
        elif chosen_est is not None and isinstance(est, (int, float)):
            why = f"+{(est - chosen_est) * 1e3:.3f} ms vs chosen"
        else:
            why = c.get("note") or ""
        lines.append(f"  {where:<42} {est_txt}  {why}")
    return lines


def _span_lines(span: Span, depth: int) -> list:
    pad = "  " * depth
    head = f"{pad}{span.name} [{_ms(span.duration_s)}]"
    skip = {"candidates", "error_chain", "group", "span_id"}
    attrs = {k: v for k, v in span.attrs.items() if k not in skip}
    if attrs:
        head += "  " + " ".join(
            f"{k}={_fmt_attr(v)}" for k, v in sorted(attrs.items()))
    lines = [head]
    if span.name == "plan":
        lines += [f"{pad}  {ln}" for ln in _candidate_lines(span)]
    if "error_chain" in span.attrs:
        for i, entry in enumerate(span.attrs["error_chain"]):
            lines.append(f"{pad}  cause[{i}]: {entry}")
    for (_, name, attrs_) in span.events:
        detail = " ".join(f"{k}={_fmt_attr(v)}"
                          for k, v in sorted(attrs_.items()))
        lines.append(f"{pad}  * {name}" + (f" {detail}" if detail else ""))
    for child in span.children:
        lines += _span_lines(child, depth + 1)
    return lines


def _fmt_attr(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, (list, tuple)) and len(v) > 16:
        return f"[{len(v)} entries]"
    return str(v)


def render_trace(trace: TicketTrace) -> str:
    """The human-readable span tree behind ``service.explain`` — spans
    with durations, the plan span's losing candidates and why they
    lost, superstep counters, events, and error chains."""
    header = (f"ticket #{trace.ticket_id} "
              f"{trace.algorithm!r} on {trace.graph_name!r} "
              f"tier={trace.tier} "
              f"status={trace.root.attrs.get('status', 'pending')}")
    return "\n".join([header] + _span_lines(trace.root, 0))


# ---------------------------------------------------------------------------
# Plan accuracy meter — estimate vs measured wall
# ---------------------------------------------------------------------------

class PlanAccuracyMeter:
    """Thread-safe planner-feedback recorder.

    One sample per resolved execution: the plan's estimate next to the
    measured wall, keyed by (algorithm, engine, variant, pool).  Fused
    groups record one sample (the shared execution's wall against the
    head ticket's estimate, with the group width noted); cache hits
    record nothing — no execution happened.  Per-key sample windows are
    bounded (``max_samples``), so a long-lived service keeps a rolling
    view.
    """

    def __init__(self, max_samples: int = 512):
        self.max_samples = int(max_samples)
        self._samples: dict[tuple, deque] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(algorithm: str, engine: str, variant, pool) -> tuple:
        return (str(algorithm), str(engine),
                variant if variant is None else str(variant),
                pool if pool is None else str(pool))

    def record(self, algorithm: str, engine: str, variant, pool,
               est_s: float, wall_s: float, mode: str = "full",
               width: int = 1) -> None:
        key = self._key(algorithm, engine, variant, pool)
        with self._lock:
            dq = self._samples.get(key)
            if dq is None:
                dq = self._samples[key] = deque(maxlen=self.max_samples)
            dq.append((float(est_s), float(wall_s), str(mode),
                       int(width)))

    def snapshot(self) -> dict:
        """The ``metrics()['accuracy']`` block: total samples, the
        overall mean absolute relative error of the estimates, and a
        per-key row with mean estimate, mean wall, and the mean
        wall/est ratio (the multiplier a refit would fold in)."""
        with self._lock:
            by_key, errs, n_total = {}, [], 0
            for key, dq in sorted(self._samples.items(),
                                  key=lambda kv: kv[0]):
                ests = [s[0] for s in dq]
                walls = [s[1] for s in dq]
                n = len(dq)
                n_total += n
                ratios = [w / e for e, w in zip(ests, walls) if e > 0]
                errs += [abs(w - e) / e
                         for e, w in zip(ests, walls) if e > 0]
                algorithm, engine, variant, pool = key
                name = "|".join((algorithm, engine, variant or "-",
                                 pool or "-"))
                by_key[name] = {
                    "n": n,
                    "est_s_mean": sum(ests) / n,
                    "wall_s_mean": sum(walls) / n,
                    "wall_over_est": (sum(ratios) / len(ratios)
                                      if ratios else None),
                }
            return {
                "samples": n_total,
                "mean_abs_rel_err": (sum(errs) / len(errs)
                                     if errs else None),
                "by_key": by_key,
            }

    def calibration_samples(self) -> dict:
        """``{algorithm: [(measured_wall_s, estimated_s), ...]}`` — the
        exact pair shape ``benchmarks.algo_suite.emit_calibration``
        fits per-algorithm scales from, sourced from production traces
        instead of a dedicated sweep."""
        with self._lock:
            out: dict[str, list] = {}
            for (algorithm, _, _, _), dq in self._samples.items():
                out.setdefault(algorithm, []).extend(
                    (wall, est) for est, wall, _, _ in dq if est > 0)
            return out


# ---------------------------------------------------------------------------
# Prometheus-style text exposition
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(prefix: str, path: tuple) -> str:
    parts = [_NAME_RE.sub("_", str(p)) for p in (prefix,) + path]
    name = "_".join(p for p in parts if p)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _flatten(value, path: tuple, out: list) -> None:
    if isinstance(value, dict):
        for k, v in value.items():
            _flatten(v, path + (k,), out)
        return
    if isinstance(value, (list, tuple)):
        for i, v in enumerate(value):
            _flatten(v, path + (str(i),), out)
        return
    out.append((path, value))


def render_prometheus(metrics: dict, prefix: str = "gas") -> str:
    """Flatten a (possibly nested) metrics dict into Prometheus text
    exposition.  Every scalar leaf becomes one sample named by its
    sanitized path — booleans as 1/0, ``None`` as ``NaN`` (Prometheus
    has no null; :func:`parse_prometheus` maps it back).  The output
    round-trips every leaf of ``GraphAnalyticsService.metrics()``."""
    leaves: list = []
    _flatten(metrics, (), leaves)
    lines = []
    for path, value in leaves:
        name = _metric_name(prefix, path)
        if value is None:
            txt = "NaN"
        elif isinstance(value, bool):
            txt = "1" if value else "0"
        elif isinstance(value, (int, float)):
            txt = repr(float(value)) if isinstance(value, float) \
                else str(value)
        else:
            lines.append(f"# {name} {value!r}")
            continue
        lines.append(f"{name} {txt}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Parse :func:`render_prometheus` output back into
    ``{name: float}`` (``NaN`` values included — compare with
    ``math.isnan``).  The round-trip half of the exposition tests."""
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out


# ---------------------------------------------------------------------------
# Observer seam — events from layers with no tracer in reach
# ---------------------------------------------------------------------------

_OBSERVERS: "weakref.WeakSet" = weakref.WeakSet()


def install_observer(observer) -> None:
    """Register an object with ``record_event(kind, attrs)`` (a
    :class:`Tracer`) for process-wide events.  Held weakly: a dropped
    tracer unregisters itself."""
    _OBSERVERS.add(observer)


def uninstall_observer(observer) -> None:
    _OBSERVERS.discard(observer)


def emit(kind: str, **attrs) -> None:
    """Broadcast one event to every installed observer.  The hot-path
    contract: with no observers this is a single falsy check, so the
    registry's fault hook and the ledger's transfer recorder cost
    nothing when tracing is off."""
    if not _OBSERVERS:
        return
    for obs in list(_OBSERVERS):
        try:
            obs.record_event(kind, attrs)
        except Exception:
            pass
