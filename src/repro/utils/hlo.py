"""Parse collective traffic out of post-SPMD HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT
collective traffic — the collective schedule only exists in the optimized
HLO after SPMD partitioning, so we regex it out of ``compiled.as_text()``.

For every ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction we take the result
shape's byte size and weight it by the ring-transfer factor for the
collective type (bytes that actually cross links per participating chip):

    all-reduce        2 (n-1)/n      (ring reduce-scatter + all-gather)
    all-gather        (n-1)/n        (per-chip share of gathered bytes)
    reduce-scatter    (n-1)/n        (input bytes = result * n)
    all-to-all        (n-1)/n
    collective-permute 1
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %all-reduce.1 = bf16[8,128,4096]{2,1,0} all-reduce(...)
#       ROOT %tuple ... (f32[16], u32[]) all-gather(...)
_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")

RING_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n if n > 1 else 0.0,
    "all-gather": lambda n: (n - 1) / n if n > 1 else 0.0,
    "reduce-scatter": lambda n: (n - 1) / n if n > 1 else 0.0,
    "all-to-all": lambda n: (n - 1) / n if n > 1 else 0.0,
    "collective-permute": lambda n: 1.0,
}

_GROUPS_RE = re.compile(r"replica_groups=\{(?P<groups>[^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(?P<dims>[0-9,]+)\]<=\[")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dtype")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format: replica_groups=[G,S]<=[...] -> group size S
        dims = [int(x) for x in m.group("dims").split(",")]
        return dims[-1] if dims else default
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group("groups").split("}")[0].strip("{ ")
        if first:
            return len(first.split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    # raw result-bytes per op type (per chip, as they appear in the
    # partitioned module) and link-weighted bytes using ring factors
    raw_bytes: dict
    link_bytes: dict
    counts: dict

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    @property
    def total_raw_bytes(self) -> float:
        return sum(self.raw_bytes.values())


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    raw: dict = {}
    link: dict = {}
    counts: dict = {}
    seen_started: set = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # async pairs appear as -start/-done; count the pair once
        if "-done(" in line:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("shape"))
        if op == "all-gather" and "-start(" in line:
            # all-gather-start result tuple holds (operand, result); the
            # shape regex already summed both — subtract operand share.
            nbytes = int(nbytes)  # keep: operand+result; adjust below
        n = _group_size(line, default_group)
        factor = RING_FACTOR[op](n)
        raw[op] = raw.get(op, 0.0) + nbytes
        link[op] = link.get(op, 0.0) + nbytes * factor
        counts[op] = counts.get(op, 0) + 1
    return CollectiveStats(raw, link, counts)
