from repro.data import synthetic
from repro.data import etl
