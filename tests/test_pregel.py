"""BSP engine unit tests (single-device path) + distributed-path tests
via subprocess (XLA device-count flags must precede jax init, so the
multi-device cases run in their own interpreter).
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core.partition import partition_1d, partition_2d
from repro.core.pregel import PregelSpec, run_pregel
from repro.data import synthetic as S


def test_partition_1d_conserves_edges():
    src, dst = S.user_follow_graph(200, 4.0, seed=0)
    g = G.build_coo(src, dst, 200)
    sg = partition_1d(g, 4)
    s = np.asarray(sg.src)
    valid = s < 200
    assert valid.sum() == g.n_edges


def test_partition_2d_dst_ranges():
    src, dst = S.user_follow_graph(200, 4.0, seed=0)
    g = G.build_coo(src, dst, 200)
    sg = partition_2d(g, 2, 4)
    d = np.asarray(sg.dst).reshape(2 * 4, -1)
    v_local = sg.v_local
    # shard (dd, m) at index dd*4+m holds only dst in range m
    for dd in range(2):
        for m in range(4):
            row = d[dd * 4 + m]
            real = row[row < 200]
            if real.size:
                assert (real // v_local == m).all()


def test_pregel_degree_count():
    """combine=sum with message=1 computes in-degrees."""
    src, dst = S.user_follow_graph(100, 3.0, seed=2)
    g = G.build_coo(src, dst, 100)
    sg = partition_1d(g, 1)
    spec = PregelSpec(
        message=lambda x, w: jnp.ones_like(w),
        combine="sum",
        apply=lambda old, agg, ids, gval: agg,
        identity=0.0,
    )
    state, iters = run_pregel(spec, sg, jnp.zeros(100), max_iters=1)
    ref = np.bincount(np.asarray(g.dst)[:g.n_edges], minlength=100)
    np.testing.assert_allclose(np.asarray(state), ref)


def test_pregel_halt_short_circuits():
    src, dst = S.user_follow_graph(100, 3.0, seed=2)
    g = G.build_coo(src, dst, 100, symmetrize=True)
    sg = partition_1d(g, 1)
    spec = PregelSpec(
        message=lambda lbl, w: lbl,
        combine="min",
        apply=lambda old, agg, ids, gval: jnp.minimum(old, agg),
        identity=np.iinfo(np.int32).max,
        halt=lambda old, new, valid: jnp.logical_not(
            jnp.any(jnp.logical_and(valid, new != old))),
    )
    labels, iters = run_pregel(spec, sg, jnp.arange(100, dtype=jnp.int32),
                               max_iters=100)
    assert int(iters) < 100                  # converged early


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import graph as G
    from repro.core.algorithms.pagerank import pagerank, pagerank_reference
    from repro.core.algorithms.connected_components import (
        connected_components, connected_components_reference)
    from repro.data import synthetic as S
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4, 2), ('data', 'model'))
    src, dst = S.user_follow_graph(800, 5.0, seed=3)
    g = G.build_coo(src, dst, 800)
    ref, _ = pagerank_reference(np.asarray(g.src)[:g.n_edges],
                                np.asarray(g.dst)[:g.n_edges], 800,
                                max_iters=60, tol=1e-10)
    for nd, nm in [(4, 1), (4, 2)]:
        r, it = pagerank(g, max_iters=60, tol=1e-10, mesh=mesh,
                         n_data=nd, n_model=nm)
        assert float(jnp.max(jnp.abs(r - ref))) < 1e-6, (nd, nm)

    gs = G.build_coo(src, dst, 800, symmetrize=True)
    labref = connected_components_reference(src, dst, 800)
    for nd, nm in [(4, 1), (4, 2)]:
        lab, _ = connected_components(gs, mesh=mesh, n_data=nd, n_model=nm,
                                      accelerated=(nm == 1))
        assert (np.asarray(lab) == labref).all(), (nd, nm)
    print('MULTI_DEVICE_OK')
""")


def test_distributed_pregel_multi_device():
    """1-D and 2-D partitioned engines on an 8-device virtual mesh."""
    r = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "MULTI_DEVICE_OK" in r.stdout, r.stderr[-2000:]


GRID_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.utils.compat import shard_map
    from repro.launch.mesh import make_mesh
    from repro.core.graph import round_up

    # small PageRank iteration via the 2-D grid scheme vs dense reference
    mesh = make_mesh((4, 2), ('data', 'model'))
    rng = np.random.default_rng(0)
    V, E = 64, 300
    src = rng.integers(0, V, E).astype(np.int32)
    dst = rng.integers(0, V, E).astype(np.int32)
    w = rng.random(E).astype(np.float32)
    n_data, n_model = 4, 2
    v_d, v_m = V // n_data, V // n_model
    # bin edges by (src_range, dst_range); pad shards equal
    shards = [[[] for _ in range(n_model)] for _ in range(n_data)]
    for s_, d_, w_ in zip(src, dst, w):
        shards[s_ // v_d][d_ // v_m].append((s_, d_, w_))
    e_shard = round_up(max(len(c) for row in shards for c in row), 8)
    S = np.full((n_data, n_model, e_shard), V, np.int32)
    D = np.full((n_data, n_model, e_shard), V, np.int32)
    W = np.zeros((n_data, n_model, e_shard), np.float32)
    for i in range(n_data):
        for j in range(n_model):
            for k, (s_, d_, w_) in enumerate(shards[i][j]):
                S[i, j, k], D[i, j, k], W[i, j, k] = s_, d_, w_
    Sf, Df, Wf = (a.reshape(-1) for a in (S, D, W))
    x0 = rng.random(V).astype(np.float32)

    def body(src, dst, w, x_d):
        d_idx = lax.axis_index('data')
        m_idx = lax.axis_index('model')
        local_src = jnp.clip(src - d_idx * v_d, 0, v_d - 1)
        msgs = x_d[local_src] * w
        local_dst = jnp.where(dst >= V, v_m,
                              jnp.clip(dst - m_idx * v_m, 0, v_m))
        agg = jax.ops.segment_sum(msgs, local_dst, num_segments=v_m + 1)[:v_m]
        agg = lax.psum(agg, 'data')
        new_m = 0.15 / V + 0.85 * agg
        mine = jnp.where(m_idx == d_idx % n_model, new_m,
                         jnp.zeros_like(new_m))
        # NOTE: general reshard needs d_idx-th slice; with v_d != v_m we
        # reconstruct from the full state for the test's V (gather fine
        # at this scale; the paper-scale lowering uses the masked psum
        # with n_data == n_model)
        full = lax.all_gather(new_m, 'model', tiled=True)
        new_d = lax.dynamic_slice_in_dim(full, d_idx * v_d, v_d)
        return new_d

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(('data', 'model')),) * 3 + (P('data'),),
                   out_specs=P('data'), check_vma=False)
    with mesh:
        got = jax.jit(fn)(jnp.asarray(Sf), jnp.asarray(Df), jnp.asarray(Wf),
                          jnp.asarray(x0))
    ref = 0.15 / V + 0.85 * np.bincount(
        dst, weights=x0[src] * w, minlength=V)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-6)
    print('GRID_OK')
""")


def test_grid_partition_pagerank_step():
    """2-D grid-partitioned superstep (the graph-engine hillclimb) is
    numerically identical to the dense reference."""
    r = subprocess.run([sys.executable, "-c", GRID_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "GRID_OK" in r.stdout, r.stderr[-2000:]
