"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M]: llama-arch small model.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
Also the end-to-end training-example arch (reduced) in examples/.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_head=64,
    d_ff=2560,
    vocab_size=49152,
    mlp_act="silu",
    tie_embeddings=True,
)
