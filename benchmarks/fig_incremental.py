"""Incremental-vs-cold sweep: what the daily delta actually buys.

The paper's pipeline re-lands the whole follow graph every day even
though consecutive snapshots differ by a small edge delta.  This sweep
measures the end-to-end payoff of the time-versioned catalog: register
a base snapshot, answer a query cold, land a delta snapshot
(``add_snapshot(..., added=...)``), and answer the *same* query on the
new version through the service — which seeds a localized incremental
repair (CC/BFS/k-core, byte-identical to cold) or a warm-started
fixpoint (PageRank/HITS, same vector within tolerance) from the
parent's cached result.

Axes: delta fraction (0.1% .. 10% of the edge set) x graph size.  Per
cell we record the cold wall (the same engine running the query with
no seed), the incremental wall (the same context executing the seeded
plan), the speedup, and the iterations cold vs seeded.  **Parity is
asserted here**, not just in the test suite: exact algorithms must
match the cold run byte for byte, fixpoints within their convergence
tolerance.

The graphs are degree-capped (the paper's MaxAdjacentNodes knob,
Table I): the production pipeline bounds adjacency skew before
shipping the graph, and the bounded ELL width is what lets the
frontier superstep run the repair wavefront in work proportional to
the *actual* frontier instead of the whole edge set.

Both paths are warmed before timing (derived graph state built, XLA
programs compiled), so the walls compare pure execution — the
recurring per-query cost the daily cadence actually pays.  The cold
wall is the *best* of the planner-chosen variant and the dense oracle,
so the reported speedup is conservative.  Results land in
``BENCH_incremental.json`` (``--out`` overrides).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.query import GraphQuery
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

SIZES = (50_000, 200_000)
DELTA_FRACTIONS = (0.001, 0.01, 0.1)
#: exact algorithms: seeded repair must be byte-identical to cold
EXACT = ("connected_components", "bfs")
#: fixpoint algorithms: seeded run must land within tol, fewer iters
FIXPOINT = ("pagerank", "hits")
#: the paper's follow graph averages ~30 edges per vertex (30 B edges
#: over ~1 B vertices); 16 keeps the sweep in that density regime
#: without blowing the CI wall clock
MEAN_DEGREE = 16.0
#: MaxAdjacentNodes: per-endpoint adjacency cap applied before the
#: symmetrize, the paper's Table I skew bound
DEGREE_CAP = 64


def _queries(coo: G.GraphCOO) -> dict:
    # BFS from the best-connected vertex: the degree cap can orphan a
    # low-degree id whose few followees were all over-subscribed hubs
    deg = np.bincount(np.asarray(coo.src)[: coo.n_edges],
                      minlength=coo.n_vertices)
    return {
        "connected_components": GraphQuery.of("connected_components"),
        "bfs": GraphQuery.of("bfs", sources=(int(np.argmax(deg)),)),
        "pagerank": GraphQuery.of("pagerank", max_iters=100),
        "hits": GraphQuery.of("hits", max_iters=50),
    }


def _group_rank(keys: np.ndarray) -> np.ndarray:
    """Occurrence rank of each element within its value group."""
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    starts = np.r_[0, np.flatnonzero(np.diff(sorted_keys)) + 1]
    lengths = np.diff(np.r_[starts, len(keys)])
    rank = np.empty(len(keys), np.int64)
    rank[order] = (np.arange(len(keys))
                   - np.repeat(starts, lengths))
    return rank


def _base_graph(n: int, seed: int = 0) -> G.GraphCOO:
    src, dst = S.user_follow_graph(n, mean_degree=MEAN_DEGREE, seed=seed)
    # MaxAdjacentNodes: keep each vertex's first DEGREE_CAP edges per
    # endpoint role, bounding the post-symmetrize degree at 2*cap
    keep = ((_group_rank(src) < DEGREE_CAP)
            & (_group_rank(dst) < DEGREE_CAP))
    # symmetrized: CC requires it, and the traversal/fixpoint answers
    # are just as meaningful on the undirected follow graph
    return G.build_coo(src[keep], dst[keep], n, symmetrize=True)


def _delta_edges(n_vertices: int, n_edges: int, rng) -> np.ndarray:
    return np.stack([rng.integers(0, n_vertices, n_edges),
                     rng.integers(0, n_vertices, n_edges)], axis=1)


def _materialize(value):
    """Force device results to the host so timings include them."""
    if isinstance(value, dict):
        for v in value.values():
            np.asarray(v)
    else:
        np.asarray(value)


def _wall(fn, iters: int = 3):
    """Median wall seconds over ``iters`` runs (callers warm first)."""
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        _materialize(r.value)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def _assert_parity(alg: str, seeded, cold, tol: float = 1e-4) -> None:
    if alg in EXACT:
        a, b = np.asarray(seeded), np.asarray(cold)
        if not np.array_equal(a, b):
            raise AssertionError(
                f"{alg}: seeded result differs from cold recompute "
                f"({int(np.sum(a != b))} mismatching entries)")
        return
    if alg == "hits":
        for half in ("hubs", "authorities"):
            if not np.allclose(np.asarray(seeded[half]),
                               np.asarray(cold[half]), atol=tol):
                raise AssertionError(f"hits: {half} outside tol {tol}")
        return
    if not np.allclose(np.asarray(seeded), np.asarray(cold), atol=tol):
        raise AssertionError(f"{alg}: seeded vector outside tol {tol}")


def _run_cell(coo: G.GraphCOO, added: np.ndarray, alg: str, q) -> dict:
    """One (graph, delta, algorithm) measurement through a fresh
    service: land the base snapshot, answer ``q`` cold (populating the
    seed), land the delta version, then time the cold and the seeded
    execution on the *same* child context with derived state and
    compilation already paid on both paths."""
    svc = GraphAnalyticsService()
    svc.add_snapshot("g", coo, as_of=0)
    parent = svc.call("g", q)               # the seed-to-be
    svc.add_snapshot("g", as_of=1, added=added)
    ctx = svc.context("g", as_of=1)

    # cold: same engine, same child bytes, no seed.  Timed under both
    # the planner-chosen variant and the dense oracle; the *faster* one
    # is the baseline, so the speedup is conservative.
    plan_cold = ctx.plan(q)
    engine = ctx.engine(plan_cold.engine)

    def cold_variant_fn(variant):
        def fn():
            return engine.run(q.algorithm, q.params,
                              count_only=q.count_only, variant=variant)
        return fn

    cold_fn = cold_variant_fn(plan_cold.variant)
    cold_dense_fn = (cold_variant_fn("dense")
                     if "dense" in (R.get(alg).variants or ()) else cold_fn)

    # seeded: the catalog's lineage lookup + seeded plan, executed
    # through the context (svc.call would answer repeats from the
    # result cache, which is exactly what a timing loop must not hit)
    seed, seed_mode = svc._seed_for(ctx, q)
    plan_inc = ctx.plan(q, seed_mode=seed_mode)

    def inc_fn():
        return ctx.execute(q, plan_inc, seed=seed)

    cold_fn()                   # build derived state + compile, all paths
    cold_dense_fn()
    inc_fn()
    t_cold, cold = _wall(cold_fn)
    t_dense, _ = _wall(cold_dense_fn)
    t_cold = min(t_cold, t_dense)
    t_inc, seeded = _wall(inc_fn)

    _assert_parity(alg, seeded.value, cold.value)
    # the real service path once more, for the meter + mode bookkeeping
    served = svc.call("g", q, as_of=1)
    assert served.meta.get("mode") == seeded.meta.get("mode")
    metr = svc.metrics()["incremental"]
    return {
        "algorithm": alg,
        "mode": seeded.meta.get("mode") or "full",
        "cold_s": t_cold,
        "incremental_s": t_inc,
        "speedup": t_cold / max(t_inc, 1e-9),
        "iters_cold": cold.iterations,
        "iters_seeded": seeded.iterations,
        "iterations_saved": metr["iterations_saved"],
        "delta_bytes_applied": metr["delta_bytes_applied"],
        "parent_iters": parent.iterations,
    }


def sweep(sizes=SIZES, fractions=DELTA_FRACTIONS, seed: int = 0) -> dict:
    P.set_calibration(None)       # analytic model: box-independent plans
    rng = np.random.default_rng(seed)
    rows = []
    for n in sizes:
        coo = _base_graph(n, seed=seed)
        queries = _queries(coo)
        for frac in fractions:
            n_add = max(1, int(frac * coo.n_edges))
            added = _delta_edges(n, n_add, rng)
            for alg, q in queries.items():
                # HITS' doubled role graph is heavy at the top size;
                # its iteration accounting is fully covered at the
                # smaller scales
                if alg == "hits" and n > 100_000:
                    continue
                row = _run_cell(coo, added, alg, q)
                row.update(n_vertices=n, n_edges=coo.n_edges,
                           delta_fraction=frac, n_added=n_add)
                rows.append(row)
                print(f"V={n:>7} frac={frac:<6} {alg:<22} "
                      f"mode={row['mode']:<11} "
                      f"cold={row['cold_s']*1e3:8.1f}ms "
                      f"inc={row['incremental_s']*1e3:8.1f}ms "
                      f"speedup={row['speedup']:6.1f}x "
                      f"iters {row['iters_cold']}->{row['iters_seeded']}")
    # headline: best exact-algorithm speedup at <=1% delta (the
    # acceptance bar: incremental repair of a small daily delta)
    small = [r for r in rows if r["algorithm"] in EXACT
             and r["delta_fraction"] <= 0.01 and r["mode"] == "incremental"]
    warm = [r for r in rows if r["algorithm"] in FIXPOINT
            and r["mode"] == "warm"]
    return {
        "sizes": list(sizes),
        "delta_fractions": list(fractions),
        "rows": rows,
        "exact_small_delta_max_speedup": max(
            (r["speedup"] for r in small), default=None),
        "exact_small_delta_min_speedup": min(
            (r["speedup"] for r in small), default=None),
        "warm_iterations_saved_total": sum(
            max(r["parent_iters"] - (r["iters_seeded"] or 0), 0)
            for r in warm),
        "parity": "asserted per cell (byte-identical for exact, "
                  "tolerance for fixpoints)",
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_incremental.json")
    ap.add_argument("--quick", action="store_true",
                    help="small single-size sweep (CI smoke)")
    args = ap.parse_args(argv)
    if args.quick:
        result = sweep(sizes=(20_000,), fractions=(0.001, 0.01))
    else:
        result = sweep()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}: exact<=1% speedup "
          f"{result['exact_small_delta_min_speedup']:.1f}x .. "
          f"{result['exact_small_delta_max_speedup']:.1f}x, "
          f"warm iterations saved "
          f"{result['warm_iterations_saved_total']}")


if __name__ == "__main__":
    main()
