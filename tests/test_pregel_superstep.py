"""The fused/frontier superstep variants and their exactness contract.

Covers the tentpole and its satellites:

* fused-kernel (Pallas interpret + jnp ref) vs dense-loop parity for
  every registered superstep-variant algorithm on random/star/self-loop/
  empty graphs, on both engines;
* frontier path bit-identical final state AND iteration counts to dense
  on BFS/SSSP/CC (monotone) and k-core (delta);
* mixed-precision message channels: bit-parity across strategies at
  reduced precision, a tolerance bound vs the full-precision result, and
  the validation gates (structured combine rejected, inexact sum behind
  the explicit opt-in);
* fused-batch (``batched_spec``) parity on the new path;
* planner-visible variant selection and the unconditional dense
  fallback (budget/mesh/spec preconditions).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engines as E
from repro.core import graph as G
from repro.core import planner as P
from repro.core import pregel
from repro.core import registry as R
from repro.core.algorithms import community, traversal
from repro.core.algorithms.triangles import _kcore_spec
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.partition import partition_1d

N = 250


def _bits(v):
    return np.asarray(v).tobytes()


def _random_graph(n=N, seed=3):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 6 * n)
    dst = rng.integers(0, n, 6 * n)
    w = rng.uniform(0.1, 2.0, 6 * n).astype(np.float32)
    return G.build_coo(src, dst, n, w=w, symmetrize=True)


def _star_graph(n=64):
    leaves = np.arange(1, n)
    return G.build_coo(np.zeros(n - 1, np.int64), leaves, n,
                       symmetrize=True)


def _self_loop_graph():
    src = np.array([0, 1, 2, 0, 3, 3])
    dst = np.array([1, 2, 0, 0, 3, 1])
    return G.build_coo(src, dst, 4, symmetrize=True)


def _empty_graph(n=5):
    e = np.array([], dtype=np.int64)
    return G.build_coo(e, e, n, symmetrize=True)


GRAPHS = {
    "random": _random_graph,
    "star": _star_graph,
    "self_loop": _self_loop_graph,
    "empty": _empty_graph,
}

# Every registered algorithm that carries superstep variants, with
# params valid on the smallest GRAPHS entry (V=4).
ALGOS = [
    ("bfs", {"sources": (0, 3)}),
    ("sssp", {"source": 0}),
    ("connected_components", {}),
    ("k_core", {"k": 3}),
]


def _engine(kind, g):
    if kind == "local":
        return LocalEngine(g)
    return DistributedEngine(g, n_data=2)


# ------------------------------------------------------------ variant parity

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("kind", ["local", "distributed"])
@pytest.mark.parametrize("algo,params", ALGOS)
def test_variant_parity_every_algorithm(gname, kind, algo, params):
    """Each registered strategy returns a bit-identical value and the
    same iteration count as the dense oracle — graphs x engines."""
    g = GRAPHS[gname]()
    eng = _engine(kind, g)
    defn = R.get(algo)
    assert set(defn.variants) == {"dense", "fused", "frontier"}
    base = eng.run(algo, params, variant="dense")
    for v in sorted(defn.variants):
        r = eng.run(algo, params, variant=v)
        assert _bits(r.value) == _bits(base.value), (algo, v)
        assert r.iterations == base.iterations, (algo, v)


def test_fused_pallas_interpret_parity():
    """use_pallas engines drive the Pallas kernel (interpret mode on
    CPU) on the fused variant — same bits as the dense path."""
    g = _random_graph()
    ref = LocalEngine(g).run("bfs", {"sources": (0,)}, variant="dense")
    eng = LocalEngine(g, use_pallas=True)
    r = eng.run("bfs", {"sources": (0,)}, variant="fused")
    assert _bits(r.value) == _bits(ref.value)
    assert r.iterations == ref.iterations


def test_frontier_loop_direct():
    """run_pregel_frontier against run_pregel without the engine in the
    way: same final state, same iteration count."""
    g = _random_graph(seed=11)
    V = g.n_vertices
    s = np.asarray(g.src)[: g.n_edges]
    d = np.asarray(g.dst)[: g.n_edges]
    w = np.asarray(g.w)[: g.n_edges]
    kout = int(np.bincount(s, minlength=V).max())
    ell = G.build_ell(s, d, V, kout, w=w, direction="out")
    init = jnp.full((V,), jnp.inf).at[0].set(0.0)
    spec = traversal._SSSP_SPEC
    dense, it_d = pregel.run_pregel(spec, partition_1d(g, 1), init, V)
    front, it_f = pregel.run_pregel_frontier(spec, ell, init, V)
    assert int(it_d) == int(it_f)
    assert _bits(dense[:V]) == _bits(front)


# ------------------------------------------------------- dense fallback

def test_budget_fallback_is_exact(monkeypatch):
    """Past the uncapped-ELL byte budget the variants silently take the
    dense path — forced variants still return the oracle's bits."""
    g = _star_graph(128)          # uncapped width = V-1: the worst case
    eng = LocalEngine(g)
    base = eng.run("connected_components", {}, variant="dense")
    monkeypatch.setattr(E, "SUPERSTEP_ELL_BUDGET", 16)
    spec = pregel.PregelSpec(
        message=lambda d, w: d, combine="min",
        apply=lambda st, a, i, gv: jnp.minimum(st, a),
        identity=np.iinfo(np.int32).max, halt=pregel.converged_halt,
        elementwise_message=True, frontier_mode="monotone")
    assert not eng.superstep_supported(spec, "fused")
    assert not eng.superstep_supported(spec, "frontier")
    for v in ("fused", "frontier"):
        r = eng.run("connected_components", {}, variant=v)
        assert _bits(r.value) == _bits(base.value)


def test_unsupported_specs_fall_back_dense():
    g = _random_graph()
    eng = LocalEngine(g)
    lpa = community._lpa_spec(8, 1.0)
    assert not eng.superstep_supported(lpa, "fused")      # structured
    assert not eng.superstep_supported(lpa, "frontier")
    dense_only = dataclasses.replace(
        traversal._BFS_SPEC, elementwise_message=False, frontier_init=None)
    assert not eng.superstep_supported(dense_only, "fused")
    with pytest.raises(ValueError):
        pregel.run_pregel_fused(dense_only, None, jnp.zeros(4), 1)
    no_frontier = dataclasses.replace(traversal._BFS_SPEC,
                                      frontier_mode=None,
                                      frontier_init=None)
    with pytest.raises(ValueError):
        pregel.run_pregel_frontier(no_frontier, None, jnp.zeros(4), 1)


def test_mesh_model_sharding_disables_variants():
    g = _random_graph()
    eng = DistributedEngine(g, n_data=2, n_model=2)
    # model-sharded vertex state: single-device ELL layouts don't apply
    assert not eng.superstep_supported(traversal._BFS_SPEC, "fused")
    assert not eng.superstep_supported(traversal._BFS_SPEC, "frontier")
    # ... but a meshless edge-sharded engine supports both, and 'auto'
    # picks the frontier for a monotone spec
    flat = DistributedEngine(g, n_data=2)
    assert flat.superstep_supported(traversal._BFS_SPEC, "frontier")
    init = jnp.full((flat.sharded.n_pad,), jnp.inf).at[0].set(0.0)
    out, _ = flat.run_superstep(traversal._BFS_SPEC, init,
                                g.n_vertices, variant="auto")
    ref, _ = pregel.run_pregel(traversal._BFS_SPEC, flat.sharded, init,
                               g.n_vertices)
    assert _bits(out) == _bits(ref[: g.n_vertices])


# ---------------------------------------------------------- mixed precision

def test_reduced_precision_parity_and_tolerance():
    """bf16 message channel: all three strategies agree bit-for-bit
    (per-message rounding happens before the exact min fold), and the
    result stays within the per-hop rounding bound of full precision."""
    g = _random_graph(seed=5)
    rp = pregel.reduced_precision(traversal._SSSP_SPEC, jnp.bfloat16)
    eng = LocalEngine(g)
    init = jnp.full((eng.sharded.n_pad,), jnp.inf).at[0].set(0.0)
    V = g.n_vertices
    full, iters = eng.run_superstep(traversal._SSSP_SPEC, init, V)
    outs = {v: eng.run_superstep(rp, init, V, variant=v)[0]
            for v in ("dense", "fused", "frontier")}
    assert _bits(outs["dense"]) == _bits(outs["fused"])
    assert _bits(outs["dense"]) == _bits(outs["frontier"])
    red = np.asarray(outs["dense"], dtype=np.float64)
    ref = np.asarray(full[:V], dtype=np.float64)
    assert (np.isfinite(red) == np.isfinite(ref)).all()
    fin = np.isfinite(ref)
    # bf16: 8 mantissa bits -> per-message relative rounding 2^-8,
    # compounded over at most `iters` relaxation hops
    bound = int(iters) * 2.0 ** -7
    assert np.all(np.abs(red[fin] - ref[fin])
                  <= bound * np.maximum(ref[fin], 1e-6) + 1e-6)


def test_precision_validation_gates():
    # min always tolerates a reduced channel
    pregel.check_precision(
        pregel.reduced_precision(traversal._BFS_SPEC, jnp.float16))
    # inexact sums need the explicit opt-in
    with pytest.raises(ValueError, match="allow_inexact_sum"):
        pregel.reduced_precision(_kcore_spec(2), jnp.bfloat16)
    opted = pregel.reduced_precision(_kcore_spec(2), jnp.bfloat16,
                                     allow_inexact_sum=True)
    assert opted.message_dtype == "bfloat16"
    # structured (grouped-monoid) messages can't take a channel dtype
    with pytest.raises(ValueError, match="structured"):
        pregel.reduced_precision(community._lpa_spec(8, 1.0),
                                 jnp.bfloat16)
    # the dense path validates too
    bad = dataclasses.replace(_kcore_spec(2), message_dtype="bfloat16")
    g = _self_loop_graph()
    with pytest.raises(ValueError, match="allow_inexact_sum"):
        pregel.run_pregel(bad, partition_1d(g, 1),
                          jnp.ones(g.n_vertices), 2)


# ------------------------------------------------------------- fused batch

def test_batched_spec_rides_superstep_variants():
    """The [V, K] fused-batch program runs through run_superstep
    ('auto' resolves frontier here) with every column bit-identical to
    its solo dense run."""
    g = _random_graph(seed=9)
    V = g.n_vertices
    eng = LocalEngine(g)
    bs = pregel.batched_spec(traversal._BFS_SPEC)
    assert bs.elementwise_message and bs.frontier_mode == "monotone"
    assert eng.superstep_supported(bs, "frontier")
    source_sets = [(0,), (5,), (9, 17)]
    init = np.full((eng.sharded.n_pad, len(source_sets)), np.inf,
                   dtype=np.float32)
    for b, srcs in enumerate(source_sets):
        init[np.asarray(srcs, dtype=np.int64), b] = 0.0
    fused, _ = eng.run_superstep(bs, jnp.asarray(init), V, variant="auto")
    dense, _ = eng.run_superstep(bs, jnp.asarray(init), V, variant="dense")
    assert _bits(fused) == _bits(dense)
    for b, srcs in enumerate(source_sets):
        solo = eng.run("bfs", {"sources": srcs}, variant="dense")
        assert _bits(fused[:V, b]) == _bits(solo.value)


# ------------------------------------------------------- planner selection

def test_planner_sees_superstep_variants():
    stats = P.GraphStats(10**6, 5 * 10**6, 6 * 10**7)
    for algo in ("bfs", "sssp", "connected_components", "k_core"):
        specs = P.specs_for(algo, stats)
        assert [s.variant for s in specs] == ["dense", "fused", "frontier"]
        by_v = {s.variant: s for s in specs}
        assert (by_v["frontier"].edge_bytes_factor
                < by_v["fused"].edge_bytes_factor
                < by_v["dense"].edge_bytes_factor)


def test_service_plan_picks_frontier_and_caches_across_variants():
    from repro.core.query import GraphPlatform, GraphQuery
    g = _random_graph()
    plat = GraphPlatform(g, force_engine="local")
    r = plat.query(GraphQuery.bfs([0]))
    assert r.meta.get("variant") == "frontier"
    dense = LocalEngine(g).run("bfs", {"sources": (0,)}, variant="dense")
    assert _bits(r.value) == _bits(dense.value)


def test_calibration_overrides_superstep_factor():
    prof = P.CalibrationProfile(
        superstep_edge_bytes={"frontier": 9.0})
    assert prof.superstep_factor("frontier") == 9.0
    assert prof.superstep_factor("dense") == 1.0
    old = P.active_calibration()
    try:
        P.set_calibration(prof)
        stats = P.GraphStats(10**6, 5 * 10**6, 6 * 10**7)
        specs = {s.variant: s for s in P.specs_for("bfs", stats)}
        assert specs["frontier"].edge_bytes_factor == 9.0
    finally:
        P.set_calibration(old)
