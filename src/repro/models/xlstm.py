"""xLSTM LM: alternating mLSTM / sLSTM blocks (arXiv:2405.04517).

Layer layout follows the assigned 12-layer config as 6 scanned *pairs*
(mLSTM then sLSTM) — pairing keeps ``lax.scan`` over depth legal even
though the two block types differ.  d_ff=0: blocks are pure token mixers
with up/down projections, no separate FFN.

* mLSTM: matrix memory C in [B,H,dh,dh] with stabilized exponential
  gating — h_t = (C_t q_t) / max(|n_t.q_t|, 1).  Implemented as a time
  scan (the chunkwise-parallel form is a §Perf candidate, the recurrence
  is the numerics oracle).
* sLSTM: scalar memory per channel with diagonal recurrent gate weights
  and the same exp-gating stabilizer.  Inherently sequential.

Recurrent state is O(1) per token -> the arch runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import DenseLM, dp_axes


def _chunked_time_scan(step, carry, xs, tc: int = 128):
    """lax.scan over time with gradient checkpointing every ``tc`` steps:
    backward recomputes within a chunk instead of saving the (large)
    recurrent carry at every timestep — for the mLSTM matrix memory the
    per-step save is B*H*dh^2 f32, i.e. tens of GB over a 4k sequence."""
    T = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if T <= tc:
        return jax.lax.scan(step, carry, xs)
    nc = T // tc if T % tc == 0 else 1
    if nc <= 1:
        return jax.lax.scan(step, carry, xs)
    xs_c = jax.tree_util.tree_map(
        lambda x: x.reshape(nc, tc, *x.shape[1:]), xs)

    @jax.checkpoint
    def chunk(c, xc):
        return jax.lax.scan(step, c, xc)

    carry, ys = jax.lax.scan(chunk, carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape(nc * tc, *y.shape[2:]), ys)
    return carry, ys


class XLSTMLM(DenseLM):
    family = "ssm"

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        assert cfg.n_layers % 2 == 0
        self.n_pairs = cfg.n_layers // 2
        self.di = cfg.ssm_expand * cfg.d_model
        self.dh = self.di // cfg.n_heads

    # ------------------------------------------------------------- params
    def _init_layers(self, key) -> dict:
        cfg = self.cfg
        d, di, h = cfg.d_model, self.di, cfg.n_heads
        pr = self.n_pairs
        ks = jax.random.split(key, 12)
        shp = (lambda *s: (pr,) + s)
        return {
            "m_ln": jnp.zeros(shp(d), jnp.float32),
            "m_up": jax.random.normal(ks[0], shp(d, 2 * di)) * d ** -0.5,
            "m_q": jax.random.normal(ks[1], shp(di, di)) * di ** -0.5,
            "m_k": jax.random.normal(ks[2], shp(di, di)) * di ** -0.5,
            "m_v": jax.random.normal(ks[3], shp(di, di)) * di ** -0.5,
            "m_gates": jax.random.normal(ks[4], shp(di, 2 * h)) * di ** -0.5,
            "m_down": jax.random.normal(ks[5], shp(di, d))
                      * di ** -0.5 / max(cfg.n_layers, 1) ** 0.5,
            "s_ln": jnp.zeros(shp(d), jnp.float32),
            "s_gates": jax.random.normal(ks[6], shp(d, 4 * di)) * d ** -0.5,
            "s_rec": jax.random.normal(ks[7], shp(4, di)) * 0.1,
            "s_down": jax.random.normal(ks[8], shp(di, d))
                      * di ** -0.5 / max(cfg.n_layers, 1) ** 0.5,
        }

    # ------------------------------------------------------- mLSTM block
    def _mlstm(self, p, x, state):
        """x [B,S,D]; state (C [B,H,dh,dh], n [B,H,dh], m [B,H]).
        Returns (out [B,S,D], new_state)."""
        cfg = self.cfg
        b, s, d = x.shape
        h_, dh = cfg.n_heads, self.dh
        dt = x.dtype
        hn = L.rms_norm(x, p["m_ln"])
        up = hn @ p["m_up"].astype(dt)
        xm, z = jnp.split(up, 2, axis=-1)                       # [B,S,di]
        q = (xm @ p["m_q"].astype(dt)).reshape(b, s, h_, dh)
        k = (xm @ p["m_k"].astype(dt)).reshape(b, s, h_, dh) * dh ** -0.5
        v = (xm @ p["m_v"].astype(dt)).reshape(b, s, h_, dh)
        gates = (xm @ p["m_gates"].astype(dt)).astype(jnp.float32)
        i_raw, f_raw = jnp.split(gates.reshape(b, s, h_, 2), 2, axis=-1)
        i_raw, f_raw = i_raw[..., 0], f_raw[..., 0]             # [B,S,H]
        f_log = jax.nn.log_sigmoid(f_raw)

        def step(carry, xs):
            C, n, m = carry
            qt, kt, vt, it, ft = xs                             # [B,H,*]
            m_new = jnp.maximum(ft + m, it)
            decay = jnp.exp(ft + m - m_new)[..., None]
            inp = jnp.exp(it - m_new)[..., None]
            kf = kt.astype(jnp.float32)
            vf = vt.astype(jnp.float32)
            C = decay[..., None] * C + inp[..., None] * \
                (vf[..., :, None] * kf[..., None, :])           # [B,H,dh,dh]
            n = decay * n + inp * kf
            qf = qt.astype(jnp.float32)
            num = jnp.einsum("bhij,bhj->bhi", C, qf)
            den = jnp.maximum(jnp.abs(jnp.sum(n * qf, axis=-1)), 1.0)
            h_t = num / den[..., None]                          # [B,H,dh]
            return (C, n, m_new), h_t

        xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), i_raw.transpose(1, 0, 2),
              f_log.transpose(1, 0, 2))
        state, hs = _chunked_time_scan(step, state, xs)
        hs = hs.transpose(1, 0, 2, 3).reshape(b, s, self.di).astype(dt)
        out = (hs * jax.nn.silu(z)) @ p["m_down"].astype(dt)
        return out, state

    # ------------------------------------------------------- sLSTM block
    def _slstm(self, p, x, state):
        """state (c, n, m, h_prev) each [B, di]."""
        cfg = self.cfg
        b, s, d = x.shape
        dt = x.dtype
        hn = L.rms_norm(x, p["s_ln"])
        gates = (hn @ p["s_gates"].astype(dt)).astype(jnp.float32)
        zg, ig, fg, og = jnp.split(gates.reshape(b, s, 4, self.di), 4, axis=2)
        zg, ig, fg, og = zg[:, :, 0], ig[:, :, 0], fg[:, :, 0], og[:, :, 0]
        rec = p["s_rec"].astype(jnp.float32)                    # [4, di]

        def step(carry, xs):
            c, n, m, h_prev = carry
            z_t, i_t, f_t, o_t = xs                             # [B,di]
            z_t = jnp.tanh(z_t + rec[0] * h_prev)
            i_t = i_t + rec[1] * h_prev
            f_t = jax.nn.log_sigmoid(f_t + rec[2] * h_prev)
            o_t = jax.nn.sigmoid(o_t + rec[3] * h_prev)
            m_new = jnp.maximum(f_t + m, i_t)
            c = jnp.exp(f_t + m - m_new) * c + jnp.exp(i_t - m_new) * z_t
            n = jnp.exp(f_t + m - m_new) * n + jnp.exp(i_t - m_new)
            h_t = o_t * c / jnp.maximum(n, 1.0)
            return (c, n, m_new, h_t), h_t

        xs = (zg.transpose(1, 0, 2), ig.transpose(1, 0, 2),
              fg.transpose(1, 0, 2), og.transpose(1, 0, 2))
        state, hs = _chunked_time_scan(step, state, xs)
        hs = hs.transpose(1, 0, 2).astype(dt)                   # [B,S,di]
        out = hs @ p["s_down"].astype(dt)
        return out, state

    # ------------------------------------------------------------ states
    def _zero_pair_state(self, b):
        cfg = self.cfg
        h_, dh, di = cfg.n_heads, self.dh, self.di
        pr = self.n_pairs
        return {
            "mC": jnp.zeros((pr, b, h_, dh, dh), jnp.float32),
            "mn": jnp.zeros((pr, b, h_, dh), jnp.float32),
            "mm": jnp.full((pr, b, h_), -1e30, jnp.float32),
            "sc": jnp.zeros((pr, b, di), jnp.float32),
            "sn": jnp.zeros((pr, b, di), jnp.float32),
            "sm": jnp.full((pr, b, di), -1e30, jnp.float32),
            "sh": jnp.zeros((pr, b, di), jnp.float32),
        }

    # ----------------------------------------------------------- forward
    def _run(self, params, x, state):
        def body(carry, xs):
            p_l, st = xs
            carry = self._constrain_act(carry)
            m_out, m_state = self._mlstm(p_l, carry, (st["mC"], st["mn"],
                                                      st["mm"]))
            carry = carry + m_out
            s_out, s_state = self._slstm(p_l, carry, (st["sc"], st["sn"],
                                                      st["sm"], st["sh"]))
            carry = carry + s_out
            new = {"mC": m_state[0], "mn": m_state[1], "mm": m_state[2],
                   "sc": s_state[0], "sn": s_state[1], "sm": s_state[2],
                   "sh": s_state[3]}
            return carry, new

        if self.cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_state = lax.scan(body, x, (params["layers"], state))
        return x, new_state

    def forward(self, params, batch):
        x = L.embed_tokens(params, batch["tokens"], self.cfg, self.dtype)
        x, _ = self._run(params, x, self._zero_pair_state(x.shape[0]))
        return L.unembed(params, x, self.cfg)

    def loss(self, params, batch, vocab_chunk: int = 8):
        # reuse the dense chunked-CE via a tiny adapter
        cfg = self.cfg
        x = L.embed_tokens(params, batch["tokens"], cfg, self.dtype)
        x, _ = self._run(params, x, self._zero_pair_state(x.shape[0]))
        return self._ce_from_hidden(params, x, batch["labels"], vocab_chunk)

    def _ce_from_hidden(self, params, x, targets, vocab_chunk):
        cfg = self.cfg
        b, s = targets.shape
        nc = vocab_chunk if s % vocab_chunk == 0 else 1
        xc = x.reshape(b, nc, s // nc, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, s // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_loss(carry, xs):
            xx, tt = xs
            logits = L.unembed(params, xx, cfg)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tt, 0)[..., None], axis=-1)[..., 0]
            valid = (tt >= 0)
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = lax.scan(chunk_loss, (jnp.float32(0), jnp.int32(0)),
                                 (xc, tc))
        loss = tot / jnp.maximum(cnt, 1)
        return loss, {"loss": loss, "tokens": cnt}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, cache_len: int) -> dict:
        # recurrent states only — O(1) in cache_len (the long_500k story)
        return self._zero_pair_state(batch_size)

    def prefill(self, params, batch, cache_len=None):
        x = L.embed_tokens(params, batch["tokens"], self.cfg, self.dtype)
        x, state = self._run(params, x,
                             self._zero_pair_state(x.shape[0]))
        logits = L.unembed(params, x[:, -1:, :], self.cfg)
        return logits, state

    def decode_step(self, params, tokens, cache, index):
        x = L.embed_tokens(params, tokens, self.cfg, self.dtype)
        x, new_state = self._run(params, x, cache)
        logits = L.unembed(params, x, self.cfg)
        return logits, new_state

    # ------------------------------------------------------- shardings
    def _layer_spec(self, fs) -> dict:
        return {
            "m_ln": P(None, None),
            "m_up": P(None, fs, "model"),
            "m_q": P(None, fs, "model"),
            "m_k": P(None, fs, "model"),
            "m_v": P(None, fs, "model"),
            "m_gates": P(None, "model", None),
            "m_down": P(None, "model", fs),
            "s_ln": P(None, None),
            "s_gates": P(None, fs, "model"),
            "s_rec": P(None, None, "model"),
            "s_down": P(None, "model", fs),
        }

    def cache_spec(self, multi_pod: bool = True) -> dict:
        dp = dp_axes(multi_pod)
        # shard the (large) per-head state dim, not the tiny head count
        return {
            "mC": P(None, dp, None, "model", None),
            "mn": P(None, dp, None, "model"),
            "mm": P(None, dp, None),
            "sc": P(None, dp, "model"),
            "sn": P(None, dp, "model"),
            "sm": P(None, dp, "model"),
            "sh": P(None, dp, "model"),
        }
