"""Public jit'd wrapper for the ELL combine kernel.

Handles padding to TPU tile alignment (rows -> block multiple, K -> 128
lanes), routes to interpret mode on CPU hosts, and exposes the pure-jnp
reference under the same signature so engines can flip implementations.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.ell_combine.kernel import ell_combine_pallas
from repro.kernels.ell_combine.ref import ell_combine_ref

_LANE = 128
# Bytes of gather source we allow VMEM-resident.  Sized in bytes (not
# element count) so dtype width and trailing state dims count against
# the budget.
VMEM_X_BUDGET_BYTES = 16 * 1024 * 1024


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _round_up(n, m):
    return ((n + m - 1) // m) * m


def ell_spmv(nbr, mask, w, x, op: str = "sum", block_rows: int = 512):
    """Pallas path (interpret on CPU). Falls back to ref when the gather
    source exceeds the VMEM budget the kernel design assumes."""
    V, K = nbr.shape
    if x.size * x.dtype.itemsize > VMEM_X_BUDGET_BYTES:
        return ell_combine_ref(nbr, mask, w, x, op=op)
    vp = _round_up(max(V, block_rows), block_rows)
    kp = _round_up(K, _LANE)
    if (vp, kp) != (V, K):
        nbr = jnp.pad(nbr, ((0, vp - V), (0, kp - K)))
        mask = jnp.pad(mask, ((0, vp - V), (0, kp - K)))
        w = jnp.pad(w, ((0, vp - V), (0, kp - K)))
    y = ell_combine_pallas(nbr, mask, w, x, op=op, block_rows=block_rows,
                           interpret=_on_cpu())
    return y[:V]


def ell_spmv_ref(nbr, mask, w, x, op: str = "sum", block_rows: int = 512):
    """Reference path under the kernel's signature."""
    return ell_combine_ref(nbr, mask, w, x, op=op)
