"""GraphAnalyticsService — the platform as a shared analytics service.

The paper's system is not a one-query-at-a-time library: it fields many
concurrent analytics queries over a catalog of graph snapshots, routing
each across the interactive/batch divide (Sections III–IV; the companion
SQL-serving paper makes the admission/routing layer explicit).  This
module is that service tier:

* **Catalog** — named graph snapshots, content-digest-deduplicated: two
  names over byte-identical snapshots share one :class:`GraphContext`
  (engines, derived state, plan cache), and every graph shares one
  result cache keyed on content digests, so a query answered for any
  snapshot is a hit for every byte-identical reload.
* **Admission & tiers** — ``submit`` plans the query first, classifies
  it *interactive* vs *batch* from the planner's cost estimate
  (thresholds come from the active :class:`~repro.core.planner.
  CalibrationProfile` unless overridden), and rejects over-budget
  queries up front with the plan attached — the user sees *why* before
  any engine burns a cycle.
* **Deterministic FIFO scheduling** — tickets queue per (engine, tier);
  ``drain`` runs each engine's interactive queue before its batch
  queue, in submission order.  ``result(ticket)`` on an interactive
  ticket executes it immediately, bypassing all queued batch work (the
  paper's "<2 s count while the 10-min table job waits" property).
* **Fused batch execution** — the NScale insight: many small per-source
  computations over one graph should run as *one* shared execution.
  The scheduler coalesces queued batch tickets with equal
  ``(graph, algorithm, fuse-key)`` into a single
  ``AlgorithmDef.batch_runner`` call — K BFS/SSSP frontiers as one
  ``[V, K]`` pregel program, K jaccard pair-batches as one kernel
  call — and scatters the per-ticket results (each bit-identical to a
  solo run) back through the shared result cache.

``GraphPlatform`` (``repro.core.query``) survives as a thin per-graph
facade over these primitives: its synchronous ``query`` is
:meth:`GraphAnalyticsService.call` on a one-entry catalog.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Optional

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.engines import DistributedEngine, LocalEngine, QueryResult


class AdmissionRejected(Exception):
    """Raised by ``submit`` when a query's estimated cost exceeds the
    admission budget.  Carries the plan, so the caller sees the engine
    choice and both estimates that sank the query."""

    def __init__(self, graph_name: str, query, plan: P.Plan, est_s: float,
                 budget_s: float):
        self.graph_name = graph_name
        self.query = query
        self.plan = plan
        self.est_s = est_s
        self.budget_s = budget_s
        super().__init__(
            f"query {query.algorithm!r} on {graph_name!r} rejected: "
            f"estimated {est_s:.3g}s exceeds the admission budget "
            f"{budget_s:.3g}s ({plan.reason})")


@dataclasses.dataclass
class QueryTicket:
    """One admitted query: its plan, its tier, and its place in line.

    The ticket pins the ``GraphContext`` it was planned against, so a
    later ``add_graph`` rebinding the same catalog name (or a
    ``remove_graph``) never redirects queued work onto a different
    snapshot — the ticket executes against the bytes it was admitted
    for.  ``fuse_key`` is computed once at submit (over validated
    params); ``None`` means unfusable."""

    ticket_id: int
    graph_name: str
    query: Any                    # GraphQuery (duck-typed to avoid cycle)
    plan: P.Plan
    tier: str                     # 'interactive' | 'batch'
    est_s: float
    status: str = "queued"        # 'queued' | 'done' | 'failed'
    context: Any = dataclasses.field(default=None, repr=False)
    fuse_key: Any = dataclasses.field(default=None, repr=False)
    error: Optional[BaseException] = dataclasses.field(default=None,
                                                       repr=False)


class GraphContext:
    """One graph snapshot's service primitives: lazy engines over shared
    derived state, measured-stats feedback, and a per-shape plan cache.

    This is the machinery ``GraphPlatform`` used to own inline; the
    platform is now a facade over a single-entry catalog of these.
    """

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None,
                 plan_cache_size: int = 128):
        self.coo = coo
        self.mesh = mesh
        self.force_engine = force_engine
        self._base_stats = P.GraphStats.of(coo)
        self.stats = self._base_stats
        self._local: Optional[LocalEngine] = None
        self._dist: Optional[DistributedEngine] = None
        self._local_max_degree = local_max_degree
        self._n_data, self._n_model = n_data, n_model
        if mesh is not None:
            self.n_chips = 1
            for s in mesh.devices.shape:
                self.n_chips *= s
        else:
            self.n_chips = max(n_data * n_model, 1)
        self._plan_cache_size = plan_cache_size
        self._plan_cache: OrderedDict = OrderedDict()
        self._applied_measurements: dict = {}
        self._profile_generation = P.calibration_generation()

    def config_key(self) -> tuple:
        """What must match for two catalog entries to share this context."""
        return (id(self.mesh), self._n_data, self._n_model,
                self._local_max_degree, self.force_engine)

    # lazy engine construction: building ELL/partitions is ETL work we
    # only pay when the planner actually routes there.
    @property
    def local(self) -> LocalEngine:
        if self._local is None:
            self._local = LocalEngine(self.coo, self._local_max_degree)
        return self._local

    @property
    def distributed(self) -> DistributedEngine:
        if self._dist is None:
            self._dist = DistributedEngine(self.coo, mesh=self.mesh,
                                           n_data=self._n_data,
                                           n_model=self._n_model)
        return self._dist

    def engine(self, name: str):
        return self.local if name == "local" else self.distributed

    def current_stats(self) -> P.GraphStats:
        """Stats with every measurement the engines have fed back so far
        (observed max in-degree, built ``OrientedELL`` width).  A change
        invalidates the plan cache, and so does a calibration-profile
        swap: cached plans were costed on constants (analytic stand-ins,
        old profile) that just got replaced."""
        meas: dict = {}
        for eng in (self._local, self._dist):
            if eng is not None:
                meas.update(eng.measurements())
        if meas != self._applied_measurements:
            self._applied_measurements = meas
            self.stats = self._base_stats.with_measurements(meas)
            self._plan_cache.clear()
        gen = P.calibration_generation()
        if gen != self._profile_generation:
            self._profile_generation = gen
            self._plan_cache.clear()
        return self.stats

    @staticmethod
    def _query_key(q):
        try:
            key = q.key()
            hash(key)           # force the check: freeze() may pass
            return key          # exotic values through unhashed
        except TypeError:       # unhashable parameter value: skip caching
            return None

    def plan(self, q) -> P.Plan:
        """Cost every (engine, variant) pair and pick one (cached per
        query shape)."""
        stats = self.current_stats()
        key = self._query_key(q)
        if key is not None and key in self._plan_cache:
            self._plan_cache.move_to_end(key)
            return self._plan_cache[key]
        defn = R.get(q.algorithm)
        specs = P.specs_for(q.algorithm, stats, count_only=q.count_only,
                            **q.params)
        plan = P.choose_plan(stats, specs, self.n_chips)
        chosen_engine = plan.engine
        if self.force_engine:
            plan = dataclasses.replace(plan, engine=self.force_engine,
                                       reason=f"forced: {self.force_engine}")
        if plan.engine not in defn.engines:
            # capability clamp wins over both the cost model and forcing
            plan = dataclasses.replace(
                plan, engine=defn.engines[0],
                reason=f"{q.algorithm} runs on {'/'.join(defn.engines)} "
                       f"only")
        if len(specs) > 1 and plan.engine != chosen_engine:
            # engine was overridden: re-pick the cheapest variant for it
            best = P.best_spec_for_engine(stats, specs, plan.engine,
                                          self.n_chips)
            plan = dataclasses.replace(plan, variant=best.variant)
        if key is not None and self._plan_cache_size:
            self._plan_cache[key] = plan
            while len(self._plan_cache) > self._plan_cache_size:
                self._plan_cache.popitem(last=False)
        return plan

    def execute(self, q, plan: P.Plan) -> QueryResult:
        r = self.engine(plan.engine).run(
            q.algorithm, q.params, count_only=q.count_only,
            variant=plan.variant)
        r.meta["plan"] = plan
        return r


class GraphAnalyticsService:
    """Catalog + admission + scheduling + fusion over GraphContexts.

    One instance serves many snapshots and many in-flight queries.  The
    result cache is shared across the whole catalog and keyed on
    ``(content digest, algorithm, frozen params, count_only)`` — engine-
    and variant-free, because results are contractually independent of
    both — so byte-identical snapshots hit each other's entries no
    matter which engine answered first.
    """

    def __init__(self, cache_size: int = 256,
                 result_cache: Optional[OrderedDict] = None,
                 interactive_threshold_s: Optional[float] = None,
                 admission_budget_s: Optional[float] = None,
                 history_size: int = 1024):
        self._catalog: dict[str, GraphContext] = {}
        self._by_digest: dict[tuple, GraphContext] = {}
        self.cache_size = cache_size
        self._result_cache: OrderedDict = (
            OrderedDict() if result_cache is None else result_cache)
        self.cache_stats = {"hits": 0, "misses": 0}
        # None -> follow the active calibration profile (so a
        # load_calibration() retunes live services)
        self._interactive_threshold_s = interactive_threshold_s
        self._admission_budget_s = admission_budget_s
        # tickets/results/log are bounded: a long-lived service fielding
        # continuous traffic must not accrete one ticket + one O(V)
        # result per query forever.  Only *resolved* tickets age out
        # (oldest first, once history_size is exceeded); pending tickets
        # are never evicted.
        self.history_size = history_size
        self._tickets: dict[int, QueryTicket] = {}
        self._results: dict[int, QueryResult] = {}
        self._resolved_order: deque = deque()
        self._next_ticket = 0
        self._queues: dict[tuple, deque] = {}   # (engine, tier) -> tickets
        self.execution_log: deque = deque(maxlen=history_size)
        self.stats = {"submitted": 0, "rejected": 0, "executed": 0,
                      "failed": 0, "fused_batches": 0, "fused_tickets": 0}

    # -- tier thresholds ----------------------------------------------------
    @property
    def interactive_threshold_s(self) -> float:
        if self._interactive_threshold_s is not None:
            return self._interactive_threshold_s
        return P.active_calibration().interactive_threshold_s

    @property
    def admission_budget_s(self) -> float:
        if self._admission_budget_s is not None:
            return self._admission_budget_s
        return P.active_calibration().admission_budget_s

    # -- catalog ------------------------------------------------------------
    def add_graph(self, name: str, coo: G.GraphCOO, mesh=None,
                  n_data: int = 1, n_model: int = 1,
                  local_max_degree: int = 128,
                  force_engine: Optional[str] = None,
                  plan_cache_size: Optional[int] = None) -> GraphContext:
        """Register a snapshot under ``name``.  Byte-identical snapshots
        with the same engine configuration share one ``GraphContext`` —
        the catalog-level dedup that makes reloading a snapshot free.
        ``plan_cache_size`` defaults to the service's ``cache_size``, so
        ``cache_size=0`` disables plan caching alongside result caching."""
        ctx = GraphContext(coo, mesh=mesh, n_data=n_data, n_model=n_model,
                           local_max_degree=local_max_degree,
                           force_engine=force_engine,
                           plan_cache_size=(self.cache_size
                                            if plan_cache_size is None
                                            else plan_cache_size))
        dedup_key = (coo.content_digest(),) + ctx.config_key()
        existing = self._by_digest.get(dedup_key)
        if existing is not None:
            ctx = existing
        else:
            self._by_digest[dedup_key] = ctx
        self._catalog[name] = ctx
        return ctx

    def remove_graph(self, name: str) -> None:
        """Drop ``name`` from the catalog — the eviction path for
        rolling-snapshot traffic.  Pending tickets pinned their context
        at submit, so they still execute against the snapshot they were
        admitted for; the context's device state is freed once the
        catalog, the dedup map and every live ticket release it."""
        ctx = self._catalog.pop(name, None)
        if ctx is not None and ctx not in self._catalog.values():
            self._by_digest = {k: v for k, v in self._by_digest.items()
                               if v is not ctx}

    def graph_names(self) -> list[str]:
        return sorted(self._catalog)

    def context(self, graph_name: str) -> GraphContext:
        try:
            return self._catalog[graph_name]
        except KeyError:
            raise KeyError(
                f"unknown graph {graph_name!r}; catalog: "
                f"{self.graph_names()}") from None

    # -- result cache -------------------------------------------------------
    def _result_key(self, ctx: GraphContext, q):
        qkey = ctx._query_key(q)
        if qkey is None:
            return None
        # content digest, not id(): a recycled address must never alias
        # a dead graph's results, and byte-identical reloads must share.
        # Engine and variant are deliberately absent — results are
        # contractually identical across both, so either one's answer
        # serves the query (the PR-3 variant argument, finished).
        return (ctx.coo.content_digest(),) + qkey

    def _cache_get(self, key) -> Optional[QueryResult]:
        if key is None or key not in self._result_cache:
            self.cache_stats["misses"] += 1
            return None
        self._result_cache.move_to_end(key)
        self.cache_stats["hits"] += 1
        hit = self._result_cache[key]
        return dataclasses.replace(hit, meta={**hit.meta, "cache": "hit"})

    def _cache_put(self, key, r: QueryResult) -> None:
        if key is None or not self.cache_size:
            return
        self._result_cache[key] = r
        while len(self._result_cache) > self.cache_size:
            self._result_cache.popitem(last=False)

    # -- synchronous path (GraphPlatform.query) -----------------------------
    def call(self, graph_name: str, q) -> QueryResult:
        """Plan → cache → execute, synchronously.  No admission control:
        this is the library-compatible single-query path."""
        ctx = self.context(graph_name)
        plan = ctx.plan(q)
        key = self._result_key(ctx, q)
        hit = self._cache_get(key)
        if hit is not None:
            return hit
        r = ctx.execute(q, plan)
        self.stats["executed"] += 1
        self._cache_put(key, r)
        return r

    # -- submission ---------------------------------------------------------
    def submit(self, graph_name: str, q) -> QueryTicket:
        """Admit one query: plan it, classify its tier, queue it.

        Raises :class:`AdmissionRejected` (plan attached) when the
        estimate exceeds the admission budget.  Admitted tickets queue
        FIFO per (engine, tier); nothing executes until ``drain`` or
        ``result``.
        """
        ctx = self.context(graph_name)
        plan = ctx.plan(q)
        est = P.plan_cost(plan)
        # an infinite estimate means the planner itself declared the
        # (forced/clamped) engine infeasible — reject even under the
        # default infinite budget, where `inf > inf` would admit it
        if est > self.admission_budget_s or est == float("inf"):
            self.stats["rejected"] += 1
            raise AdmissionRejected(graph_name, q, plan, est,
                                    self.admission_budget_s)
        tier = ("interactive" if est <= self.interactive_threshold_s
                else "batch")
        defn = R.get(q.algorithm)
        ticket = QueryTicket(
            self._next_ticket, graph_name, q, plan, tier, est,
            context=ctx,
            fuse_key=self._fuse_key(defn, q) if defn.fusable else None)
        self._next_ticket += 1
        self._tickets[ticket.ticket_id] = ticket
        self._queues.setdefault((plan.engine, tier), deque()).append(ticket)
        self.stats["submitted"] += 1
        return ticket

    # -- resolution ---------------------------------------------------------
    def drain(self) -> list[QueryTicket]:
        """Run every queued ticket to completion, deterministically:
        engines in fixed order, each engine's interactive queue strictly
        before its batch queue, each queue FIFO — with batch tickets
        coalesced into fused executions where the registry allows.
        Returns the tickets finished by this call, in execution order."""
        finished: list[QueryTicket] = []
        for engine in ("local", "distributed"):
            q_int = self._queues.get((engine, "interactive"))
            while q_int:
                t = q_int.popleft()
                if t.status != "queued":    # resolved out of band
                    continue
                self._run_solo(t)
                finished.append(t)
            q_batch = self._queues.get((engine, "batch"))
            while q_batch:
                head = q_batch.popleft()
                if head.status != "queued":
                    continue
                group = self._take_fuse_group(q_batch, head)
                finished.extend(self._run_group(engine, group))
        return finished

    def result(self, ticket: QueryTicket) -> QueryResult:
        """The ticket's result, executing work as needed.  Interactive
        tickets bypass the batch queue entirely: only the ticket itself
        runs.  Batch tickets drain the service (their fuse group rides
        along for free)."""
        t = self._tickets.get(ticket.ticket_id)
        if t is not ticket:
            raise ValueError(
                f"ticket #{ticket.ticket_id} was not issued by this "
                f"service (ids are per-service), or its result aged out "
                f"of the {self.history_size}-entry history")
        if t.status == "queued":
            if t.tier == "interactive":
                self._run_solo(t)
            else:
                self.drain()
        if t.status == "failed":
            raise t.error
        return self._results[t.ticket_id]

    def pending(self) -> list[QueryTicket]:
        return [t for t in self._tickets.values() if t.status == "queued"]

    # -- execution internals ------------------------------------------------
    @staticmethod
    def _fuse_key(defn: R.AlgorithmDef, q):
        """The query's fuse compatibility key, computed once at submit
        over *validated* params (the registry's fuse contract) — a
        directly-constructed query without schema defaults filled must
        not crash the scheduler.  ``None`` means unfusable: the ticket
        runs solo and any schema error surfaces at execution, attributed
        to that ticket."""
        try:
            return (defn.name, defn.fuse(defn.validate(q.params)))
        except Exception:
            return None

    @staticmethod
    def _take_fuse_group(queue: Optional[deque],
                         head: QueryTicket) -> list[QueryTicket]:
        """Pull every queued ticket fusable with ``head`` (same pinned
        context, equal precomputed fuse key) out of ``queue``,
        preserving the FIFO order of everything left behind."""
        group = [head]
        if queue is None or head.fuse_key is None:
            return group
        keep = deque()
        while queue:
            t = queue.popleft()
            if t.status != "queued":
                continue
            if t.context is head.context and t.fuse_key == head.fuse_key:
                group.append(t)
            else:
                keep.append(t)
        queue.extend(keep)
        return group

    def _finish(self, t: QueryTicket, r: QueryResult) -> None:
        t.status = "done"
        self._results[t.ticket_id] = r
        self._age_out(t)

    def _fail(self, tickets, error: BaseException) -> None:
        """An execution raised: the tickets must not be stranded (out of
        every queue, forever 'queued').  They finish as 'failed' and
        ``result`` re-raises the stored error; the drain continues with
        the rest of the queue."""
        for t in tickets:
            t.status = "failed"
            t.error = error
            self._age_out(t)
        self.stats["failed"] += len(tickets)

    def _age_out(self, t: QueryTicket) -> None:
        """Record ``t`` as resolved and evict the oldest resolved
        tickets (and their stored results) beyond ``history_size``."""
        self._resolved_order.append(t.ticket_id)
        while len(self._resolved_order) > max(self.history_size, 0):
            old = self._resolved_order.popleft()
            self._tickets.pop(old, None)
            self._results.pop(old, None)

    def _log(self, engine: str, tier: str, tickets, fused: bool,
             algorithm: str) -> None:
        self.execution_log.append({
            "engine": engine, "tier": tier, "fused": fused,
            "algorithm": algorithm,
            "tickets": [t.ticket_id for t in tickets]})

    def _run_solo(self, t: QueryTicket) -> None:
        ctx = t.context
        key = self._result_key(ctx, t.query)
        hit = self._cache_get(key)
        if hit is not None:
            self._finish(t, hit)
            return
        try:
            r = ctx.execute(t.query, t.plan)
        except Exception as e:
            self._fail([t], e)
            return
        self.stats["executed"] += 1
        self._cache_put(key, r)
        self._finish(t, r)
        self._log(t.plan.engine, t.tier, [t], fused=False,
                  algorithm=t.query.algorithm)

    def _run_group(self, engine: str,
                   group: list[QueryTicket]) -> list[QueryTicket]:
        """Execute one fuse group: cached tickets answered for free, the
        rest as a single fused batch program (or solo when only one —
        or the algorithm has no batch path — remains)."""
        ctx = group[0].context
        run: list[QueryTicket] = []
        for t in group:
            hit = self._cache_get(self._result_key(ctx, t.query))
            if hit is not None:
                self._finish(t, hit)
            else:
                run.append(t)
        if not run:
            return group
        defn = R.get(group[0].query.algorithm)
        if len(run) == 1 or not defn.fusable:
            for t in run:
                try:
                    r = ctx.execute(t.query, t.plan)
                except Exception as e:
                    self._fail([t], e)
                    continue
                self.stats["executed"] += 1
                self._cache_put(self._result_key(ctx, t.query), r)
                self._finish(t, r)
                self._log(engine, "batch", [t], fused=False,
                          algorithm=t.query.algorithm)
            return group
        try:
            results = ctx.engine(engine).run_batch(
                defn, [t.query.params for t in run],
                count_only=[t.query.count_only for t in run])
        except Exception as e:
            self._fail(run, e)
            return group
        self.stats["executed"] += 1
        self.stats["fused_batches"] += 1
        self.stats["fused_tickets"] += len(run)
        for t, r in zip(run, results):
            r.meta["plan"] = t.plan
            # the cached copy drops 'fused' — it describes THIS run, and
            # a later hit replaying it would claim a fusion that never
            # happened for that caller (the ticket keeps the full meta)
            cached = dataclasses.replace(
                r, meta={k: v for k, v in r.meta.items() if k != "fused"})
            self._cache_put(self._result_key(ctx, t.query), cached)
            self._finish(t, r)
        self._log(engine, "batch", run, fused=True,
                  algorithm=defn.name)
        return group
