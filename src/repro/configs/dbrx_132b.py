"""DBRX-132B [hf:databricks/dbrx-base]: fine-grained 16-expert top-4 MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert) vocab=100352.
36B active / 132B total — FSDP + TP + EP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    capacity_factor=1.25,
    mlp_act="silu",
    tie_embeddings=False,
    fsdp=True,
)
