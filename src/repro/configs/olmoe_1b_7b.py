"""OLMoE-1B-7B [arXiv:2409.02060]: 64-expert top-8 MoE.

16L d_model=2048 16H (MHA kv=16) d_ff=1024 (per expert) vocab=50304.
1B active / 7B total.  Experts sharded over the model axis (EP == TP
axis); token dispatch is the all-to-all that dominates its roofline.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    capacity_factor=1.25,
    mlp_act="silu",
    tie_embeddings=False,
)
