"""PaliGemma-3B backbone [arXiv:2407.07726]: SigLIP prefix + Gemma LM.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.  The SigLIP
vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, 256, d_model) prepended to the token
sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    prefix_len=256,
    mlp_act="gelu",
    tie_embeddings=True,
)
