"""Registry-driven platform tests.

Three guarantees the registry refactor must hold:

1. **Parity** — every registered algorithm produces identical results on
   ``LocalEngine`` and ``DistributedEngine`` (both now share the one
   generic ``Engine.run`` path), and matches its host-side oracle where
   one exists.  The suite iterates the registry, so a newly registered
   algorithm is covered automatically — and *must* declare
   ``example_params`` (or an override here) or the coverage test fails.
2. **Caching** — a repeated identical ``GraphQuery`` on the same
   ``GraphPlatform`` is served from the result cache without re-running
   the engine; differing params / count_only / engine miss.
3. **Registration is the only extension point** — a throwaway algorithm
   registered at runtime is immediately plannable, queryable and
   cacheable through ``GraphPlatform`` with zero edits to the
   engine/planner/query layers.
"""
from collections import OrderedDict

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.query import GraphPlatform, GraphQuery
from repro.data import synthetic as S

N = 300

# Per-algorithm parameter overrides for the parity sweep; algorithms not
# listed here run with their registered ``example_params``.
PARAM_OVERRIDES = {
    "two_hop": {"dedup": True},
    "pagerank": {"tol": 1e-10},
}


def _edges(g):
    return (np.asarray(g.src)[: g.n_edges], np.asarray(g.dst)[: g.n_edges],
            np.asarray(g.w)[: g.n_edges])


@pytest.fixture(scope="module")
def graphs():
    src, dst = S.user_follow_graph(N, 4.0, seed=13)
    keep = src != dst
    return {False: G.build_coo(src, dst, N),
            True: G.build_coo(src[keep], dst[keep], N, symmetrize=True)}


@pytest.fixture(scope="module")
def engines(graphs):
    # max_degree above the true max in-degree so ELL-based algorithms
    # (two_hop, jaccard) see the uncapped adjacency
    built = {}
    for sym, g in graphs.items():
        _, d, _ = _edges(g)
        maxdeg = int(np.bincount(d, minlength=N).max())
        built[sym] = (LocalEngine(g, max_degree=maxdeg),
                      DistributedEngine(g, n_data=4, max_degree=maxdeg))
    return built


def _case_params(defn):
    if defn.name in PARAM_OVERRIDES:
        return {**(defn.example_params or {}), **PARAM_OVERRIDES[defn.name]}
    return dict(defn.example_params)


def _assert_same(a, b, ctx=""):
    if isinstance(a, dict):
        assert sorted(a) == sorted(b), ctx
        for k in a:
            _assert_same(a[k], b[k], f"{ctx}[{k}]")
        return
    if isinstance(a, tuple):
        assert isinstance(b, tuple) and len(a) == len(b), ctx
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_same(x, y, f"{ctx}[{i}]")
        return
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, ctx
    if np.issubdtype(a.dtype, np.floating):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7, err_msg=ctx)
    else:
        np.testing.assert_array_equal(a, b, err_msg=ctx)


def test_every_registration_declares_parity_params():
    """A new algorithm must ship representative parameters (or a
    PARAM_OVERRIDES entry above) so the parity sweep exercises it."""
    for name, defn in R.items():
        assert defn.example_params is not None or name in PARAM_OVERRIDES, \
            f"{name}: no example_params and no parity override"


@pytest.mark.parametrize("name", R.names())
def test_engine_parity(name, engines):
    """The acceptance bar: every registered algorithm, identical results
    through the shared Engine.run path on both engines, including the
    count-only fast path where one exists."""
    defn = R.get(name)
    params = _case_params(defn)
    local, dist = engines[defn.requires_symmetric]
    r_local = local.run(defn, params)
    assert r_local.engine == "local"
    if "distributed" in defn.engines:
        r_dist = dist.run(defn, params)
        assert r_dist.engine == "distributed"
        _assert_same(r_local.value, r_dist.value, f"{name} full result")
    if defn.has_count_path:
        c_local = local.run(defn, params, count_only=True)
        assert np.asarray(c_local.value).size == 1, name
        if "distributed" in defn.engines:
            c_dist = dist.run(defn, params, count_only=True)
            _assert_same(c_local.value, c_dist.value, f"{name} count")


# ------------------------------------------------------------- oracles

def test_parity_oracles(graphs, engines):
    """Registered runs vs the host-side numpy oracles."""
    from repro.core.algorithms.connected_components import (
        connected_components_reference)
    from repro.core.algorithms.pagerank import pagerank_reference
    from repro.core.algorithms.traversal import bfs_reference, sssp_reference
    from repro.core.algorithms.triangles import (
        k_core_reference, triangle_count_reference)
    from repro.core.algorithms.two_hop import two_hop_reference

    dig, sym = graphs[False], graphs[True]
    s, d, w = _edges(dig)
    ss, sd, _ = _edges(sym)
    lod, los = engines[False][0], engines[True][0]

    ref, _ = pagerank_reference(s, d, N, tol=1e-10)
    np.testing.assert_allclose(
        np.asarray(lod.run("pagerank", {"tol": 1e-10}).value), ref,
        atol=1e-6)

    np.testing.assert_array_equal(
        np.asarray(los.run("connected_components").value),
        connected_components_reference(ss, sd, N))

    np.testing.assert_array_equal(
        np.asarray(lod.run("bfs", {"sources": (0,)}).value),
        bfs_reference(s, d, N, [0]))

    np.testing.assert_allclose(
        np.asarray(lod.run("sssp", {"source": 0}).value),
        sssp_reference(s, d, w, N, 0), atol=1e-5)

    assert los.run("triangle_count").value == \
        triangle_count_reference(ss, sd, N)

    np.testing.assert_array_equal(
        np.asarray(los.run("k_core", {"k": 3}).value),
        k_core_reference(ss, sd, N, 3))

    # two-hop: distinct pairs sharing an in-neighbor ("identifier" = dst)
    pairs, valid, count = lod.run("two_hop").value
    got = {(int(p[0]), int(p[1]))
           for p, ok in zip(np.asarray(pairs), np.asarray(valid)) if ok}
    ref_pairs = two_hop_reference(s, d, N)
    assert got == ref_pairs and count == len(ref_pairs)

    # jaccard oracle via python sets over in-neighborhoods
    u, v = 0, 1
    nbrs = [set() for _ in range(N)]
    for a, b in zip(s, d):
        nbrs[int(b)].add(int(a))
    inter = len(nbrs[u] & nbrs[v])
    union = len(nbrs[u] | nbrs[v])
    want = inter / union if union else 0.0
    got_j = float(np.asarray(lod.run("jaccard", {"u": [u], "v": [v]}).value)[0])
    assert got_j == pytest.approx(want)


def test_two_hop_count_consistent_across_engines_and_exact(graphs):
    """Satellite fix: both engines answer the count-only two-hop query
    from *exact* COO in-degrees — a degree-capped local ELL must not
    change the answer."""
    dig = graphs[False]
    s, d, _ = _edges(dig)
    deg = np.bincount(d, minlength=N).astype(np.int64)
    want = int((deg * (deg - 1) // 2).sum())
    # a small cap would previously make the local engine undercount
    lo = LocalEngine(dig, max_degree=2)
    di = DistributedEngine(dig, n_data=4, max_degree=2)
    assert lo.two_hop_count().value == want
    assert di.two_hop_count().value == want


def test_distributed_two_hop_ell_cached(graphs):
    """Satellite fix: the distributed engine's ELL is built once and
    reused across two-hop calls (it used to rebuild per call)."""
    eng = DistributedEngine(graphs[False], n_data=4)
    first = eng.run("two_hop").value
    assert eng._ell is not None
    ell = eng._ell
    eng.run("two_hop")
    assert eng._ell is ell


# ------------------------------------------------------ schema validation

def test_unknown_algorithm_rejected():
    with pytest.raises(KeyError, match="unknown algorithm"):
        GraphQuery.of("page_rank")


def test_unknown_param_rejected():
    with pytest.raises(ValueError, match="unknown parameter"):
        GraphQuery.of("pagerank", aplha=0.9)


def test_missing_required_param_rejected():
    with pytest.raises(ValueError, match="missing required"):
        GraphQuery.of("bfs")


def test_invalid_value_rejected():
    with pytest.raises(ValueError, match="invalid value"):
        GraphQuery.of("pagerank", alpha=1.5)
    with pytest.raises(ValueError, match="invalid value"):
        GraphQuery.of("k_core", k=0)


def test_defaults_filled_and_normalized():
    q = GraphQuery.of("pagerank")
    assert q.params == {"alpha": 0.85, "tol": 1e-8, "max_iters": 100}
    q = GraphQuery.of("bfs", sources=[3, 1])
    assert q.params["sources"] == (3, 1)       # normalized to tuple


def test_engine_capability_flags(graphs):
    """jaccard is registered local-only: the distributed engine rejects
    it and the platform clamps the plan to the local engine even when
    forcing distributed."""
    defn = R.get("jaccard")
    assert defn.engines == ("local",)
    with pytest.raises(ValueError, match="supports engine"):
        DistributedEngine(graphs[False], n_data=4).run(
            "jaccard", {"u": [0], "v": [1]})
    plat = GraphPlatform(graphs[False], force_engine="distributed")
    r = plat.query(GraphQuery.of("jaccard", u=[0], v=[1]))
    assert r.engine == "local"
    assert "local" in r.meta["plan"].reason


# ---------------------------------------------------------- result cache

@pytest.fixture()
def platform(graphs):
    return GraphPlatform(graphs[True], n_data=4)


def test_repeated_query_served_from_cache(platform):
    q1 = GraphQuery.connected_components(count_only=True)
    r1 = platform.query(q1)
    runs = platform.local.n_runs + (
        platform._dist.n_runs if platform._dist else 0)
    # a *fresh* but identical query object must hit
    r2 = platform.query(GraphQuery.connected_components(count_only=True))
    assert r2.value == r1.value
    assert r2.meta.get("cache") == "hit"
    assert "cache" not in r1.meta               # stored copy untouched
    assert platform.local.n_runs + (
        platform._dist.n_runs if platform._dist else 0) == runs
    assert platform.cache_stats == {"hits": 1, "misses": 1}


def test_differing_params_miss(platform):
    platform.query(GraphQuery.connected_components(count_only=True))
    platform.query(GraphQuery.connected_components(count_only=True,
                                                   max_iters=199))
    platform.query(GraphQuery.connected_components(count_only=False))
    assert platform.cache_stats["hits"] == 0
    assert platform.cache_stats["misses"] == 3


def test_cache_engine_independent(graphs):
    """Results are contractually engine-independent, so the cache key
    carries no engine: the same query re-planned onto the other engine
    (``force_engine`` toggled) is a *hit* through a shared store — the
    spurious-miss bug this PR fixed.  Distinct stores still miss."""
    auto = GraphPlatform(graphs[True], n_data=4)
    forced = GraphPlatform(graphs[True], n_data=4,
                           force_engine="distributed")
    q = GraphQuery.connected_components(count_only=True)
    assert auto.query(q).engine == "local"
    assert forced.query(q).engine == "distributed"   # separate stores miss
    assert auto.query(q).value == forced.query(q).value

    shared = OrderedDict()
    local = GraphPlatform(graphs[True], n_data=4, result_cache=shared)
    first = local.query(q)
    assert first.engine == "local"
    re_planned = GraphPlatform(graphs[True], n_data=4,
                               force_engine="distributed",
                               result_cache=shared)
    r = re_planned.query(q)
    assert r.meta.get("cache") == "hit"          # engine not in the key
    assert r.value == first.value
    assert re_planned._dist is None              # engine never built


def test_cache_lru_eviction(graphs):
    plat = GraphPlatform(graphs[True], cache_size=1)
    q_a = GraphQuery.connected_components(count_only=True)
    q_b = GraphQuery.degree_stats()
    plat.query(q_a)
    plat.query(q_b)                  # evicts q_a
    plat.query(q_a)                  # miss again
    assert plat.cache_stats == {"hits": 0, "misses": 3}
    plat.query(q_a)
    assert plat.cache_stats["hits"] == 1


def test_cache_disabled(graphs):
    plat = GraphPlatform(graphs[True], cache_size=0)
    q = GraphQuery.connected_components(count_only=True)
    plat.query(q)
    r = plat.query(q)
    assert r.meta.get("cache") is None
    assert plat.cache_stats == {"hits": 0, "misses": 2}


def test_plan_cache_returns_same_plan(platform):
    q = GraphQuery.pagerank()
    p1 = platform.plan(q)
    p2 = platform.plan(GraphQuery.pagerank())
    assert p1 is p2


# ------------------------------------------- registration as extension

def test_register_new_algorithm_end_to_end(graphs):
    """The tentpole property: a new algorithm registered at runtime is
    immediately plannable, runnable on both engines, queryable through
    GraphPlatform and result-cached — with zero edits to the
    engines/planner/query layers."""
    name = "scaled_in_degree_test"

    def _run(eng, scale):
        return G.in_degrees(eng.coo) * scale, 1

    R.register(R.AlgorithmDef(
        name=name,
        run=_run,
        params=(R.Param("scale", 1.0, check=lambda s: s > 0,
                        normalize=float),),
        count=lambda v: float(np.asarray(v).max()),
        count_method="max_scaled_in_degree_test",
        cost=lambda g, params, count_only: P.QuerySpec(
            name, 1 if count_only else g.n_vertices, iterations=1),
    ))
    try:
        plat = GraphPlatform(graphs[False], n_data=4)
        q = GraphQuery.of(name, scale=2.0)
        plan = plat.plan(q)
        assert plan.engine in ("local", "distributed")
        r = plat.query(q)
        s, d, _ = _edges(graphs[False])
        np.testing.assert_allclose(
            np.asarray(r.value), 2.0 * np.bincount(d, minlength=N))
        assert plat.query(GraphQuery.of(name, scale=2.0)).meta["cache"] == \
            "hit"
        # engine parity + the derived count method, via dynamic dispatch
        lo = LocalEngine(graphs[False])
        di = DistributedEngine(graphs[False], n_data=4)
        np.testing.assert_allclose(np.asarray(lo.run(name, {"scale": 2.0}).value),
                                   np.asarray(di.run(name, {"scale": 2.0}).value))
        assert lo.max_scaled_in_degree_test(scale=2.0).value == \
            float(np.asarray(r.value).max())
    finally:
        R.unregister(name)
    with pytest.raises(KeyError):
        R.get(name)
