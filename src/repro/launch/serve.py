"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch smollm-360m --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models.registry import build_model
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.prefix_len, cfg.d_model)),
            jnp.float32)

    cache_len = args.prompt_len + args.gen + \
        (cfg.prefix_len if cfg.family == "vlm" else 0)
    t0 = time.time()
    out = greedy_generate(model, params, batch, steps=args.gen,
                          cache_len=cache_len)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
