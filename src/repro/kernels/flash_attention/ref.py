"""Pure-jnp oracle for flash attention (all mask variants)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("causal", "window", "softcap"))
def mha_reference(q, k, v, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] (GQA: Hq % Hkv == 0).

    window > 0 restricts attention to the last ``window`` positions
    (sliding-window / local attention, gemma2-style).  softcap > 0
    applies  softcap * tanh(logits / softcap).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qf = q.astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
