"""GraphAnalyticsService — the platform as a shared analytics service.

The paper's system is not a one-query-at-a-time library: it fields many
concurrent analytics queries over a catalog of graph snapshots, routing
each across the interactive/batch divide (Sections III–IV; the companion
SQL-serving paper makes the admission/routing layer explicit).  This
module is that service tier:

* **Catalog** — named graph snapshots, content-digest-deduplicated: two
  names over byte-identical snapshots share one :class:`GraphContext`
  (engines, derived state, plan cache), and every graph shares one
  result cache keyed on content digests, so a query answered for any
  snapshot is a hit for every byte-identical reload.
* **Admission & tiers** — ``submit`` plans the query first, classifies
  it *interactive* vs *batch* from the planner's cost estimate
  (thresholds come from the active :class:`~repro.core.planner.
  CalibrationProfile` unless overridden), and rejects over-budget
  queries up front with the plan attached — the user sees *why* before
  any engine burns a cycle.  Queues are bounded: a tier at its depth
  budget rejects with a typed :class:`~repro.core.runtime.Backpressure`
  instead of accreting unbounded work.
* **Concurrent runtime** — ``drain(workers=N)`` runs the queues on a
  worker pool (one execution at a time per engine instance, enforced by
  the engine's own lock), so a fused batch on one engine overlaps
  interactive traffic on the other.  Workers *preempt at dequeue time*:
  every scan serves all interactive queues before any batch queue, so
  queued interactive tickets jump every batch group that has not
  started yet.  Per-ticket results are byte-identical to a serial
  ``drain()`` — the fusion contract (slices bit-identical to solo runs)
  makes results order-independent.
* **Retry & dead-letter** — a failed execution retries under the
  service's :class:`~repro.core.runtime.RetryPolicy` (jittered
  exponential backoff, deterministic per ticket given the service
  seed); schema-class errors and tickets out of attempts land in the
  ``dead-letter`` state keeping their full exception chain, ``result``
  re-raises, and the drain continues with the rest of the queue.
* **Fused batch execution** — the NScale insight: many small per-source
  computations over one graph should run as *one* shared execution.
  The scheduler coalesces queued batch tickets with equal
  ``(graph, algorithm, fuse-key)`` into a single
  ``AlgorithmDef.batch_runner`` call — K BFS/SSSP frontiers as one
  ``[V, K]`` pregel program, K jaccard pair-batches as one kernel
  call — and scatters the per-ticket results (each bit-identical to a
  solo run) back through the shared result cache.
* **Metrics** — ``metrics()`` snapshots queue depths, per-tier latency
  histograms, cache hit rates, fusion widths and retry/dead-letter
  counters under one lock — the in-process analogue of the exemplar
  queue-worker stacks' Prometheus gauges.
* **Time-versioned catalog** — ``add_snapshot(name, ..., as_of=...)``
  registers the daily reload of a graph as a new *version* of the same
  catalog name, either from full bytes or from a delta applied to the
  previous version (``added=``/``removed=`` edge lists).  Versions form
  a lineage chain through each snapshot's recorded ``parent_digest``;
  ``submit``/``call`` take ``as_of`` and resolve the newest version at
  or before that timestamp.  When a query arrives for a snapshot whose
  ancestor already answered the same query, the catalog finds that
  result through the digest-keyed result cache and hands it to the
  engine as a *seed*: exact monotone algorithms run a localized
  incremental repair from the delta's touched vertices (byte-identical
  to the cold run), fixpoint algorithms warm-start from the converged
  vector (same answer within tolerance, fewer iterations).  The
  planner prices incremental-vs-full per query
  (:func:`~repro.core.planner.price_incremental`), so an over-large
  delta falls back to a full recompute.
* **Federation** — a service built over a non-trivial
  :class:`~repro.core.pools.PoolSet` plans every query over
  (pool, engine, variant): ``add_graph(..., pools=[...])`` declares
  where each snapshot is *resident*, the planner prices non-resident
  placements with the pool's link bandwidth, queues become
  per-(pool, engine, tier), a :class:`~repro.core.runtime.PoolGate`
  caps per-pool in-flight work, and batch tickets **spill** to another
  resident pool when the preferred pool's batch queue is at its
  capacity.  Executing on a previously non-resident pool records the
  snapshot bytes in a :class:`~repro.core.runtime.TransferLedger` and
  marks the pool resident (bumping the context's residency generation,
  which plan and result cache keys include).  Results stay
  bit-identical regardless of the pool that runs them.

``GraphPlatform`` (``repro.core.query``) survives as a thin per-graph
facade over these primitives: its synchronous ``query`` is
:meth:`GraphAnalyticsService.call` on a one-entry catalog.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Iterable, Optional, Sequence

from repro.core import graph as G
from repro.core import obs
from repro.core import planner as P
from repro.core import pools as PL
from repro.core import registry as R
from repro.core import runtime as RT
from repro.core.engines import DistributedEngine, LocalEngine, QueryResult

# re-exported so service users see one import surface for the typed
# submit-time rejections (AdmissionRejected lives here, Backpressure in
# runtime.py next to the policies that drive it)
Backpressure = RT.Backpressure


class AdmissionRejected(Exception):
    """Raised by ``submit`` when a query's estimated cost exceeds the
    admission budget.  Carries the plan, so the caller sees the engine
    choice and both estimates that sank the query."""

    def __init__(self, graph_name: str, query, plan: P.Plan, est_s: float,
                 budget_s: float):
        self.graph_name = graph_name
        self.query = query
        self.plan = plan
        self.est_s = est_s
        self.budget_s = budget_s
        super().__init__(
            f"query {query.algorithm!r} on {graph_name!r} rejected: "
            f"estimated {est_s:.3g}s exceeds the admission budget "
            f"{budget_s:.3g}s ({plan.reason})")


@dataclasses.dataclass
class QueryTicket:
    """One admitted query: its plan, its tier, and its place in line.

    The ticket pins the ``GraphContext`` it was planned against, so a
    later ``add_graph`` rebinding the same catalog name (or a
    ``remove_graph``) never redirects queued work onto a different
    snapshot — the ticket executes against the bytes it was admitted
    for.  ``fuse_key`` is computed once at submit (over validated
    params); ``None`` means unfusable.

    Lifecycle: ``queued`` → ``running`` (claimed by a worker or an
    inline ``result``) → ``done`` | ``dead-letter``.  A dead-lettered
    ticket keeps its exception chain in ``error`` (attempt k's error is
    the ``__cause__`` of attempt k+1's) and ``attempts`` records how
    many executions it consumed."""

    ticket_id: int
    graph_name: str
    query: Any                    # GraphQuery (duck-typed to avoid cycle)
    plan: P.Plan
    tier: str                     # 'interactive' | 'batch'
    est_s: float
    status: str = "queued"        # | 'running' | 'done' | 'dead-letter'
    context: Any = dataclasses.field(default=None, repr=False)
    fuse_key: Any = dataclasses.field(default=None, repr=False)
    error: Optional[BaseException] = dataclasses.field(default=None,
                                                       repr=False)
    attempts: int = 0
    queued_at: float = dataclasses.field(default=0.0, repr=False)
    pool: Optional[str] = None    # placement pool (None = legacy/trivial)
    # warm-start seed (an ancestor snapshot's QueryResult) pinned at
    # submit for plans whose mode is not 'full'; None otherwise
    seed: Any = dataclasses.field(default=None, repr=False)


class GraphContext:
    """One graph snapshot's service primitives: lazy engines over shared
    derived state, measured-stats feedback, and a per-shape plan cache.

    This is the machinery ``GraphPlatform`` used to own inline; the
    platform is now a facade over a single-entry catalog of these.
    """

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None,
                 plan_cache_size: int = 128,
                 pools: Optional[PL.PoolSet] = None,
                 residency: Optional[Iterable[str]] = None):
        self.coo = coo
        self.mesh = mesh
        self.force_engine = force_engine
        # -- federation: the service's poolset and this snapshot's
        # residency.  ``_declared`` pools come from add_graph; the
        # ``_materialized`` set grows when an execution builds derived
        # state on a pool the snapshot was not declared on.  Effective
        # residency is their union; every change bumps the residency
        # generation, which the plan cache (below) and the service's
        # result-cache keys incorporate.
        self._pools = pools
        self._declared: set = set(residency or ())
        self._materialized: set = set()
        self._residency_generation = 0
        self._seen_residency_gen = 0
        self._pools_generation = (pools.generation
                                  if pools is not None else 0)
        self._base_stats = P.GraphStats.of(coo)
        self.stats = self._base_stats
        self._local: Optional[LocalEngine] = None
        self._dist: Optional[DistributedEngine] = None
        self._local_max_degree = local_max_degree
        self._n_data, self._n_model = n_data, n_model
        if mesh is not None:
            self.n_chips = 1
            for s in mesh.devices.shape:
                self.n_chips *= s
        else:
            self.n_chips = max(n_data * n_model, 1)
        self._plan_cache_size = plan_cache_size
        self._plan_cache: OrderedDict = OrderedDict()
        self._applied_measurements: dict = {}
        self._profile_generation = P.calibration_generation()
        # submit-time planning may race worker-thread executions that
        # feed measurements back; the plan cache and stats swap are the
        # shared state (engine construction is also guarded here)
        self._lock = threading.RLock()

    def config_key(self) -> tuple:
        """What must match for two catalog entries to share this context."""
        return (id(self.mesh), self._n_data, self._n_model,
                self._local_max_degree, self.force_engine)

    # lazy engine construction: building ELL/partitions is ETL work we
    # only pay when the planner actually routes there.
    @property
    def local(self) -> LocalEngine:
        with self._lock:
            if self._local is None:
                self._local = LocalEngine(self.coo, self._local_max_degree)
            return self._local

    @property
    def distributed(self) -> DistributedEngine:
        with self._lock:
            if self._dist is None:
                self._dist = DistributedEngine(self.coo, mesh=self.mesh,
                                               n_data=self._n_data,
                                               n_model=self._n_model)
            return self._dist

    def engine(self, name: str, pool=None):
        """The engine for ``name`` — the process-default instance, or
        its pool-bound twin when a :class:`~repro.core.pools.DevicePool`
        is given (the ``Engine.for_pool`` seam)."""
        base = self.local if name == "local" else self.distributed
        if pool is None:
            return base
        return base.for_pool(pool)

    def pool_for_plan(self, plan: P.Plan):
        """Resolve a plan's pool name to the DevicePool to execute on;
        ``None`` for legacy plans and trivial (single default) poolsets,
        which keeps the pre-federation execution path byte-for-byte."""
        if self._pools is None or plan.pool is None or self._pools.trivial:
            return None
        return self._pools.get(plan.pool)

    # -- residency ----------------------------------------------------------
    def _residency_change(self, declared=None, materialize=None) -> bool:
        before = self._declared | self._materialized
        if declared is not None:
            self._declared = set(declared)
        if materialize is not None:
            self._materialized.add(materialize)
        changed = (self._declared | self._materialized) != before
        if changed:
            self._residency_generation += 1
        return changed

    @property
    def residency(self) -> frozenset:
        """Pool names where this snapshot is resident (declared at
        add_graph plus pools materialized by execution)."""
        with self._lock:
            return frozenset(self._declared | self._materialized)

    @property
    def residency_generation(self) -> int:
        with self._lock:
            return self._residency_generation

    def declare_residency(self, names: Iterable[str]) -> bool:
        """Replace the declared residency set (the service recomputes it
        as the union over catalog names sharing this context).  Returns
        whether the effective residency changed (generation bumped)."""
        with self._lock:
            return self._residency_change(declared=names)

    def mark_resident(self, pool_name: str) -> bool:
        """Record that an execution materialized derived state on
        ``pool_name``.  True iff the pool was newly resident — the
        moment the service charges the transfer ledger."""
        with self._lock:
            return self._residency_change(materialize=pool_name)

    def current_stats(self) -> P.GraphStats:
        """Stats with every measurement the engines have fed back so far
        (observed max in-degree, built ``OrientedELL`` width).  A change
        invalidates the plan cache, and so does a calibration-profile
        swap: cached plans were costed on constants (analytic stand-ins,
        old profile) that just got replaced."""
        with self._lock:
            meas: dict = {}
            for eng in (self._local, self._dist):
                if eng is not None:
                    meas.update(eng.measurements())
                    for twin in eng.pool_twins().values():
                        meas.update(twin.measurements())
            if meas != self._applied_measurements:
                self._applied_measurements = meas
                self.stats = self._base_stats.with_measurements(meas)
                self._plan_cache.clear()
            gen = P.calibration_generation()
            if gen != self._profile_generation:
                self._profile_generation = gen
                self._plan_cache.clear()
            # federation invalidation: a pool-health flip (poolset
            # generation) or a residency change (replica removed, pool
            # materialized) re-costs every cached plan
            if self._pools is not None:
                pg = self._pools.generation
                if pg != self._pools_generation:
                    self._pools_generation = pg
                    self._plan_cache.clear()
            if self._residency_generation != self._seen_residency_gen:
                self._seen_residency_gen = self._residency_generation
                self._plan_cache.clear()
            return self.stats

    @staticmethod
    def _query_key(q):
        try:
            key = q.key()
            hash(key)           # force the check: freeze() may pass
            return key          # exotic values through unhashed
        except TypeError:       # unhashable parameter value: skip caching
            return None

    def _placement_pools(self):
        """Pools the planner minimizes over, or ``None`` for the legacy
        (engine, variant)-only path.  A trivial poolset (one pool, unit
        scale) stays on the legacy path so its plans — estimates, reason
        strings, ``pool=None`` — match the pre-federation planner
        exactly."""
        if self._pools is None or self._pools.trivial:
            return None
        return self._pools.pools()

    def plan(self, q, seed_mode: Optional[str] = None) -> P.Plan:
        """Cost every (pool, engine, variant) placement and pick one
        (cached per query shape; the cache is cleared on measurement,
        calibration, pool-health and residency changes).

        ``seed_mode`` (from the service's lineage lookup) prices the
        incremental/warm path against the chosen full recompute —
        :func:`~repro.core.planner.price_incremental`.  It joins the
        cache key: the same query shape plans differently once an
        ancestor's result appears in the cache, and the delta itself is
        immutable per context (``self.coo.delta``) so it need not."""
        with self._lock:
            stats = self.current_stats()
            qkey = self._query_key(q)
            key = None if qkey is None else (qkey, seed_mode)
            if key is not None and key in self._plan_cache:
                self._plan_cache.move_to_end(key)
                return self._plan_cache[key]
            pools = self._placement_pools()
            plan = self._plan_uncached(
                q, stats, pools,
                self.residency if pools is not None else None,
                seed_mode=seed_mode)
            if key is not None and self._plan_cache_size:
                self._plan_cache[key] = plan
                while len(self._plan_cache) > self._plan_cache_size:
                    self._plan_cache.popitem(last=False)
            return plan

    def plan_for_pools(self, q, pool_names: Sequence[str]) -> P.Plan:
        """Re-place ``q`` restricted to ``pool_names`` — the service's
        batch-spill path.  Never cached: the restriction reflects live
        queue depths, not the query's shape."""
        with self._lock:
            stats = self.current_stats()
            pools = [self._pools.get(n) for n in pool_names]
            return self._plan_uncached(q, stats, pools, self.residency)

    def _plan_uncached(self, q, stats, pools, resident,
                       seed_mode: Optional[str] = None) -> P.Plan:
        """One planning pipeline for both the legacy and the pool-aware
        paths: cost-model choice, then force_engine, then the
        capability clamp (which wins over both), then variant re-pick
        for the overridden engine, then — exactly once, on the final
        plan — the incremental-vs-full pricing."""
        defn = R.get(q.algorithm)
        specs = P.specs_for(q.algorithm, stats,
                            count_only=q.count_only, **q.params)

        def priced(plan):
            if seed_mode is None:
                return plan
            spec = next((s for s in specs if s.variant == plan.variant),
                        specs[0])
            return P.price_incremental(
                plan, stats, spec, delta=getattr(self.coo, "delta", None),
                seed_mode=seed_mode)

        if pools is None:
            plan = P.choose_plan(stats, specs, self.n_chips)
        else:
            plan = P.choose_plan(stats, specs, self.n_chips,
                                 pools=pools, resident=resident)
        chosen_engine = plan.engine
        target = why = None
        if self.force_engine:
            target, why = self.force_engine, f"forced: {self.force_engine}"
        if (target or plan.engine) not in defn.engines:
            # capability clamp wins over the cost model and forcing
            target = defn.engines[0]
            why = f"{q.algorithm} runs on {'/'.join(defn.engines)} only"
        if target is None:
            return priced(plan)
        if pools is not None:
            # re-run the placement with the engine axis pinned, so the
            # override still picks the best (pool, variant) for it
            if target != chosen_engine:
                plan = P.choose_plan(stats, specs, self.n_chips,
                                     pools=pools, resident=resident,
                                     engines=(target,))
            return priced(dataclasses.replace(
                plan, reason=f"{why}; {plan.reason}"))
        plan = dataclasses.replace(plan, engine=target, reason=why)
        if len(specs) > 1 and target != chosen_engine:
            # engine was overridden: re-pick its cheapest variant
            best = P.best_spec_for_engine(stats, specs, target,
                                          self.n_chips)
            plan = dataclasses.replace(plan, variant=best.variant)
        return priced(plan)

    def execute(self, q, plan: P.Plan, seed=None,
                profile: bool = False) -> QueryResult:
        """Run the plan.  ``seed`` (an ancestor snapshot's QueryResult)
        is forwarded to the engine only for non-full plans; incremental
        plans also hand over this snapshot's recorded delta so the
        algorithm's localized-repair hook can seed its frontier.  A
        hook that declines falls back to the cold run inside
        ``Engine.run`` — the answer is the same either way.
        ``profile`` asks the engine for superstep counters
        (``meta['superstep']``); result values are identical either
        way."""
        kw = {}
        if seed is not None and plan.mode != "full":
            kw["seed"] = seed
            if plan.mode == "incremental":
                kw["delta"] = getattr(self.coo, "delta", None)
        r = self.engine(plan.engine, self.pool_for_plan(plan)).run(
            q.algorithm, q.params, count_only=q.count_only,
            variant=plan.variant, profile=profile, **kw)
        r.meta["plan"] = plan
        return r


@dataclasses.dataclass
class _WorkUnit:
    """One dequeued execution: a solo interactive ticket or a fused
    batch group.  ``busy_key`` identifies the (context, engine) pair the
    unit will occupy — the runtime never hands two units with the same
    key to different workers (the engine lock would just serialize them
    while an idle engine starves)."""

    kind: str                     # 'solo' | 'group'
    engine: str
    tickets: list
    pool: Optional[str] = None    # placement pool (gate slot to release)

    @property
    def busy_key(self) -> tuple:
        return (id(self.tickets[0].context), self.pool, self.engine)


class GraphAnalyticsService:
    """Catalog + admission + concurrent runtime + fusion over
    GraphContexts.

    One instance serves many snapshots and many in-flight queries.  The
    result cache is shared across the whole catalog and keyed on
    ``(content digest, algorithm, frozen params, count_only)`` — engine-
    and variant-free, because results are contractually independent of
    both — so byte-identical snapshots hit each other's entries no
    matter which engine answered first.

    ``workers`` sets the default drain parallelism (1 = the serial
    reference schedule); ``retry`` the backoff/dead-letter policy;
    ``tier_depth`` the per-tier queue depth budget (int for both tiers,
    or ``{"interactive": ..., "batch": ...}``; ``None`` = unbounded);
    ``seed`` makes every backoff schedule deterministic per ticket;
    ``pools`` the federation topology — a
    :class:`~repro.core.pools.PoolSet` (or a DevicePool sequence),
    defaulting to a trivial single pool that reproduces the
    pre-federation service exactly.
    """

    ENGINE_ORDER = ("local", "distributed")
    TIER_ORDER = ("interactive", "batch")

    def __init__(self, cache_size: int = 256,
                 result_cache: Optional[OrderedDict] = None,
                 interactive_threshold_s: Optional[float] = None,
                 admission_budget_s: Optional[float] = None,
                 history_size: int = 1024,
                 workers: int = 1,
                 retry: Optional[RT.RetryPolicy] = None,
                 tier_depth=None,
                 seed: int = 0,
                 pools=None,
                 trace_depth: int = 0,
                 tracer: Optional[obs.Tracer] = None):
        if pools is None:
            self.pools = PL.single_pool()
        elif isinstance(pools, PL.PoolSet):
            self.pools = pools
        else:
            self.pools = PL.PoolSet(pools)
        self._pool_gate = RT.PoolGate(
            {p.name: p.max_inflight for p in self.pools})
        self._ledger = RT.TransferLedger()
        self._pool_spills = {p.name: 0 for p in self.pools}
        self._name_pools: dict[str, tuple] = {}   # name -> declared pools
        self._catalog: dict[str, GraphContext] = {}
        self._by_digest: dict[tuple, GraphContext] = {}
        # -- time-versioned catalog: name -> [version dicts] sorted by
        # as_of (each {'as_of', 'ctx', 'digest', 'parent'}), plus a
        # digest -> context index for walking lineage chains when a
        # query hunts for an ancestor's cached result to seed from
        self._versions: dict[str, list] = {}
        self._digest_ctx: dict[str, GraphContext] = {}
        self._meter = RT.IncrementalMeter()
        self.cache_size = cache_size
        self._result_cache: OrderedDict = (
            OrderedDict() if result_cache is None else result_cache)
        self.cache_stats = {"hits": 0, "misses": 0}
        # None -> follow the active calibration profile (so a
        # load_calibration() retunes live services)
        self._interactive_threshold_s = interactive_threshold_s
        self._admission_budget_s = admission_budget_s
        # tickets/results/log are bounded: a long-lived service fielding
        # continuous traffic must not accrete one ticket + one O(V)
        # result per query forever.  Only *resolved* tickets age out
        # (oldest first, once history_size is exceeded); pending tickets
        # are never evicted.
        self.history_size = history_size
        self._tickets: dict[int, QueryTicket] = {}
        self._results: dict[int, QueryResult] = {}
        self._resolved_order: deque = deque()
        self._next_ticket = 0
        # (pool, engine, tier) -> tickets; pool is None for plans from
        # the legacy/trivial-poolset path
        self._queues: dict[tuple, deque] = {}
        self.execution_log: deque = deque(maxlen=history_size)
        self.stats = {"submitted": 0, "rejected": 0, "backpressure": 0,
                      "executed": 0, "failed": 0, "retries": 0,
                      "dead_letters": 0, "fused_batches": 0,
                      "fused_tickets": 0, "spilled": 0}
        # -- runtime ---------------------------------------------------
        self.workers = max(int(workers), 1)
        self.retry = RT.RetryPolicy() if retry is None else retry
        self.seed = int(seed)
        if tier_depth is None:
            self._tier_depth: dict[str, Optional[int]] = {}
        elif isinstance(tier_depth, int):
            self._tier_depth = {t: tier_depth for t in self.TIER_ORDER}
        else:
            self._tier_depth = dict(tier_depth)
        # one lock for all scheduler/bookkeeping state; the condition
        # wakes workers when new work or a completion arrives, and
        # result() waiters when a ticket resolves
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._busy: set = set()        # busy (context, pool, engine)
        self._inflight = 0             # units currently executing
        self._hist = {t: RT.LatencyHistogram() for t in self.TIER_ORDER}
        self._fusion_widths: deque = deque(maxlen=4096)
        # -- observability ---------------------------------------------
        # ``trace_depth > 0`` (or an explicit tracer) turns on span
        # tracing: per-ticket span trees bounded to the newest
        # trace_depth tickets, superstep profiling on every traced
        # execution, and process-wide fault/transfer events routed in
        # through the observer seam.  Off (the default) every hook is a
        # single ``is not None`` check.  The PlanAccuracyMeter is
        # always on — recording two floats per execution is cheaper
        # than the estimate it corrects.
        if tracer is not None:
            self.tracer: Optional[obs.Tracer] = tracer
        elif trace_depth > 0:
            self.tracer = obs.Tracer(trace_depth=trace_depth)
        else:
            self.tracer = None
        if self.tracer is not None:
            obs.install_observer(self.tracer)
        self._accuracy = obs.PlanAccuracyMeter()

    # -- tier thresholds ----------------------------------------------------
    @property
    def interactive_threshold_s(self) -> float:
        if self._interactive_threshold_s is not None:
            return self._interactive_threshold_s
        return P.active_calibration().interactive_threshold_s

    @property
    def admission_budget_s(self) -> float:
        if self._admission_budget_s is not None:
            return self._admission_budget_s
        return P.active_calibration().admission_budget_s

    # -- catalog ------------------------------------------------------------
    def add_graph(self, name: str, coo: G.GraphCOO, mesh=None,
                  n_data: int = 1, n_model: int = 1,
                  local_max_degree: int = 128,
                  force_engine: Optional[str] = None,
                  plan_cache_size: Optional[int] = None,
                  pools: Optional[Sequence[str]] = None) -> GraphContext:
        """Register a snapshot under ``name``.  Byte-identical snapshots
        with the same engine configuration share one ``GraphContext`` —
        the catalog-level dedup that makes reloading a snapshot free.
        ``pools`` names the pools the snapshot is *resident* on
        (default: all of them — the pre-federation behaviour); replicas
        of the same bytes under different names merge into one context
        whose residency is the union of their declarations.
        ``plan_cache_size`` defaults to the service's ``cache_size``, so
        ``cache_size=0`` disables plan caching alongside result caching."""
        declared = (self.pools.names() if pools is None
                    else self.pools.validate_names(pools))
        ctx = GraphContext(coo, mesh=mesh, n_data=n_data, n_model=n_model,
                           local_max_degree=local_max_degree,
                           force_engine=force_engine,
                           plan_cache_size=(self.cache_size
                                            if plan_cache_size is None
                                            else plan_cache_size),
                           pools=self.pools, residency=declared)
        with self._lock:
            dedup_key = (coo.content_digest(),) + ctx.config_key()
            existing = self._by_digest.get(dedup_key)
            if existing is not None:
                ctx = existing
            else:
                self._by_digest[dedup_key] = ctx
            self._catalog[name] = ctx
            self._name_pools[name] = tuple(declared)
            self._refresh_residency(ctx)
            return ctx

    def remove_graph(self, name: str) -> None:
        """Drop ``name`` from the catalog — the eviction path for
        rolling-snapshot traffic.  Pending tickets pinned their context
        at submit, so they still execute against the snapshot they were
        admitted for; the context's device state is freed once the
        catalog, the dedup map and every live ticket release it.
        Removing one replica of a multi-pool snapshot shrinks the
        shared context's declared residency — a residency-generation
        bump that invalidates cached plans placed on the gone pool."""
        with self._lock:
            ctx = self._catalog.pop(name, None)
            self._name_pools.pop(name, None)
            if ctx is None:
                return
            if ctx not in self._catalog.values():
                self._by_digest = {k: v for k, v in self._by_digest.items()
                                   if v is not ctx}
            else:
                self._refresh_residency(ctx)

    def _refresh_residency(self, ctx: GraphContext) -> None:
        """Re-derive ``ctx``'s declared residency as the union over the
        catalog names that share it (caller holds the lock)."""
        union: set = set()
        for name, c in self._catalog.items():
            if c is ctx:
                union |= set(self._name_pools.get(name, ()))
        ctx.declare_residency(union)

    def set_pool_health(self, name: str, healthy: bool) -> PL.DevicePool:
        """Flip one pool's health.  A real change bumps the poolset
        generation, so every context's cached plans (and the result-
        cache keys) that priced the old topology are invalidated."""
        return self.pools.set_health(name, healthy)

    # -- time-versioned catalog ---------------------------------------------
    def add_snapshot(self, name: str, coo: Optional[G.GraphCOO] = None, *,
                     as_of=None, added=None, removed=None, added_w=None,
                     **kw) -> GraphContext:
        """Register one *version* of the rolling snapshot ``name``.

        Two forms:

        * ``add_snapshot(name, coo, as_of=t)`` — full bytes.  If ``coo``
          came out of :meth:`~repro.core.graph.GraphCOO.apply_delta` its
          recorded ``parent_digest``/``delta`` lineage rides along.
        * ``add_snapshot(name, as_of=t, added=..., removed=...)`` — the
          daily-delta form: the edge lists are applied to the *latest*
          registered version of ``name`` (``GraphCOO.apply_delta``), so
          the catalog never rebuilds the unchanged bulk of the graph.

        ``as_of`` is any totally ordered timestamp (int day number, ISO
        date string, ...) and must be strictly greater than the previous
        version's; it defaults to ``last + 1`` (or 0 for the first
        version).  The bare catalog name always resolves to the newest
        version; ``context``/``call``/``submit`` accept ``as_of`` to pin
        an older one.  Engine keyword arguments (``mesh``, ``pools``,
        ``force_engine``, ...) pass through to :meth:`add_graph`.
        """
        with self._lock:
            chain = self._versions.get(name, [])
            if coo is None:
                if added is None and removed is None:
                    raise ValueError(
                        "add_snapshot needs either a graph or a delta "
                        "(added=/removed= edge lists)")
                if not chain:
                    raise KeyError(
                        f"no base version of {name!r} to apply a delta "
                        f"to; register the first snapshot with full bytes")
                coo = chain[-1]["ctx"].coo.apply_delta(
                    added=added, removed=removed, added_w=added_w)
            elif added is not None or removed is not None:
                raise ValueError(
                    "pass either a graph or added=/removed=, not both")
            if as_of is None:
                as_of = chain[-1]["as_of"] + 1 if chain else 0
            if chain and not chain[-1]["as_of"] < as_of:
                raise ValueError(
                    f"snapshot versions must advance: as_of {as_of!r} is "
                    f"not after {name!r}'s latest {chain[-1]['as_of']!r}")
            ctx = self.add_graph(name, coo, **kw)
            digest = coo.content_digest()
            self._versions.setdefault(name, []).append({
                "as_of": as_of, "ctx": ctx, "digest": digest,
                "parent": getattr(coo, "parent_digest", None)})
            self._digest_ctx[digest] = ctx
            return ctx

    def snapshot_versions(self, name: str) -> list:
        """The registered ``as_of`` timestamps of ``name``, oldest
        first (empty for graphs added via plain ``add_graph``)."""
        with self._lock:
            return [e["as_of"] for e in self._versions.get(name, ())]

    def graph_names(self) -> list[str]:
        with self._lock:
            return sorted(self._catalog)

    def context(self, graph_name: str, as_of=None) -> GraphContext:
        """The context serving ``graph_name`` — its newest version, or
        with ``as_of`` the newest *version at or before* that timestamp
        (catalog time travel; older versions stay queryable after the
        bare name moved on)."""
        with self._lock:
            if as_of is not None:
                chain = self._versions.get(graph_name)
                if not chain:
                    raise KeyError(
                        f"graph {graph_name!r} has no time-versioned "
                        f"snapshots (register them with add_snapshot); "
                        f"catalog: {self.graph_names()}")
                cands = [e for e in chain if e["as_of"] <= as_of]
                if not cands:
                    raise KeyError(
                        f"no version of {graph_name!r} at or before "
                        f"{as_of!r}; versions: "
                        f"{[e['as_of'] for e in chain]}")
                return cands[-1]["ctx"]
            try:
                return self._catalog[graph_name]
            except KeyError:
                raise KeyError(
                    f"unknown graph {graph_name!r}; catalog: "
                    f"{self.graph_names()}") from None

    # -- lineage seeding ----------------------------------------------------
    def _peek_ancestor_result(self, digest: str, qkey) \
            -> Optional[QueryResult]:
        """The cached result of ``qkey`` on the snapshot whose content
        digest is ``digest``, without touching hit/miss counters or LRU
        order — a seed probe, not a cache hit."""
        ctx = self._digest_ctx.get(digest)
        if ctx is None:
            return None
        key = (digest, ctx.residency_generation,
               self.pools.generation) + qkey
        with self._lock:
            return self._result_cache.get(key)

    def _seed_for(self, ctx: GraphContext, q):
        """Hunt the lineage chain for a warm-start seed for ``q`` on
        ``ctx``'s snapshot.  Returns ``(seed, mode)``:

        * ``(result, 'incremental')`` — the *direct parent* answered
          ``q`` and this snapshot records the delta that produced it
          (the only ancestor whose delta describes the edit, so the
          only one a localized repair may seed from);
        * ``(result, 'warm')`` — some ancestor within 4 hops answered
          ``q`` and the algorithm can warm-start a fixpoint from it;
        * ``(None, None)`` — no lineage, no cached ancestor result, or
          the algorithm registered neither hook.
        """
        qkey = ctx._query_key(q)
        if qkey is None:
            return None, None
        parent = getattr(ctx.coo, "parent_digest", None)
        if parent is None:
            return None, None
        defn = R.get(q.algorithm)
        if defn.incremental is not None \
                and getattr(ctx.coo, "delta", None) is not None:
            seed = self._peek_ancestor_result(parent, qkey)
            if seed is not None:
                return seed, "incremental"
        if defn.warm_start is not None:
            digest = parent
            for _ in range(4):
                if digest is None:
                    break
                seed = self._peek_ancestor_result(digest, qkey)
                if seed is not None:
                    return seed, "warm"
                anc = self._digest_ctx.get(digest)
                digest = getattr(anc.coo, "parent_digest", None) \
                    if anc is not None else None
        return None, None

    def _record_incremental(self, r: QueryResult, seed,
                            ctx: GraphContext) -> None:
        """Feed the meter after a seeded execution resolved.  The mode
        in ``r.meta`` is what the engine *actually* ran (a declining
        hook leaves no mode — the cold fallback is not a hit)."""
        mode = r.meta.get("mode")
        if mode is None:
            return
        saved = 0
        prev_iters = getattr(seed, "iterations", None)
        if prev_iters is not None and r.iterations is not None:
            saved = max(int(prev_iters) - int(r.iterations), 0)
        delta = getattr(ctx.coo, "delta", None) \
            if mode == "incremental" else None
        self._meter.record(mode, iterations_saved=saved,
                           delta_bytes=delta.nbytes() if delta else 0)

    # -- result cache -------------------------------------------------------
    def _result_key(self, ctx: GraphContext, q):
        qkey = ctx._query_key(q)
        if qkey is None:
            return None
        # content digest, not id(): a recycled address must never alias
        # a dead graph's results, and byte-identical reloads must share.
        # Engine and variant are deliberately absent — results are
        # contractually identical across both, so either one's answer
        # serves the query (the PR-3 variant argument, finished).  The
        # residency and poolset generations ARE present: a replica
        # removal or health flip must not replay entries admitted under
        # the old topology (they start at 0 everywhere, so fresh
        # services sharing a cache still hit each other's entries).
        return (ctx.coo.content_digest(), ctx.residency_generation,
                self.pools.generation) + qkey

    def _cache_get(self, key) -> Optional[QueryResult]:
        with self._lock:
            if key is None or key not in self._result_cache:
                self.cache_stats["misses"] += 1
                return None
            self._result_cache.move_to_end(key)
            self.cache_stats["hits"] += 1
            hit = self._result_cache[key]
            return dataclasses.replace(hit,
                                       meta={**hit.meta, "cache": "hit"})

    def _cache_put(self, key, r: QueryResult) -> None:
        with self._lock:
            if key is None or not self.cache_size:
                return
            self._result_cache[key] = r
            while len(self._result_cache) > self.cache_size:
                self._result_cache.popitem(last=False)

    # -- synchronous path (GraphPlatform.query) -----------------------------
    def call(self, graph_name: str, q, as_of=None) -> QueryResult:
        """Plan → cache → execute, synchronously.  No admission control:
        this is the library-compatible single-query path.  ``as_of``
        pins a time-versioned snapshot; lineage seeding (incremental
        repair / warm start from an ancestor's cached result) applies
        exactly as on the ``submit`` path."""
        ctx = self.context(graph_name, as_of)
        key = self._result_key(ctx, q)
        hit = self._cache_get(key)
        if hit is not None:
            return hit
        seed, seed_mode = self._seed_for(ctx, q)
        plan = ctx.plan(q, seed_mode=seed_mode)
        self._account_transfer(ctx, plan)
        t0 = time.perf_counter()
        r = ctx.execute(q, plan, seed=seed)
        self._accuracy.record(q.algorithm, plan.engine, plan.variant,
                              plan.pool, est_s=P.plan_cost(plan),
                              wall_s=time.perf_counter() - t0,
                              mode=plan.mode)
        with self._lock:
            self.stats["executed"] += 1
        self._record_incremental(r, seed, ctx)
        # re-key: accounting may have just materialized the pool
        # (residency-generation bump), and the entry must be findable
        # under the keys later lookups will compute
        self._cache_put(self._result_key(ctx, q), r)
        return r

    def _account_transfer(self, ctx: GraphContext, plan: P.Plan,
                          tickets: Sequence[QueryTicket] = ()) -> None:
        """Executing on a pool materializes the snapshot's derived state
        there: the first time charges the snapshot bytes to the transfer
        ledger and marks the pool resident (declared-resident pools were
        never charged — the replica was already in place).  A charged
        transfer is marked on each involved ticket's trace."""
        if plan.pool is None:
            return
        if ctx.mark_resident(plan.pool):
            self._ledger.record(plan.pool, ctx.stats.bytes_coo)
            if self.tracer is not None and tickets:
                self.tracer.ticket_event(
                    [t.ticket_id for t in tickets], "transfer",
                    {"pool": plan.pool, "bytes": ctx.stats.bytes_coo})

    # -- submission ---------------------------------------------------------
    def submit(self, graph_name: str, q, as_of=None) -> QueryTicket:
        """Admit one query: plan it, classify its tier, queue it.

        Raises :class:`AdmissionRejected` (plan attached) when the
        estimate exceeds the admission budget, and
        :class:`~repro.core.runtime.Backpressure` when the destination
        queue is at its tier's depth budget.  Admitted tickets queue
        FIFO per (pool, engine, tier); nothing executes until ``drain``
        or ``result``.  Batch tickets whose preferred pool's batch
        queue is at the pool's ``capacity`` *spill*: they re-place onto
        another healthy pool where the snapshot is resident (tier and
        admission estimate unchanged).

        ``as_of`` resolves a time-versioned snapshot; when an ancestor
        of that snapshot already answered ``q``, the ticket carries the
        ancestor's result as a warm-start seed and its plan is priced
        (and tiered) on the incremental estimate.  Seeded tickets never
        fuse — the seed is per-snapshot state a shared batch program
        cannot carry.
        """
        ctx = self.context(graph_name, as_of)
        seed, seed_mode = self._seed_for(ctx, q)
        plan = ctx.plan(q, seed_mode=seed_mode)
        if plan.mode == "full":
            seed = None
        est = P.plan_cost(plan)
        with self._lock:
            # an infinite estimate means the planner itself declared the
            # (forced/clamped) engine infeasible — reject even under the
            # default infinite budget, where `inf > inf` would admit it
            if est > self.admission_budget_s or est == float("inf"):
                self.stats["rejected"] += 1
                if self.tracer is not None:
                    self.tracer.record_event("admission-rejected", {
                        "graph": graph_name, "algorithm": q.algorithm,
                        "est_s": est, "budget_s": self.admission_budget_s})
                raise AdmissionRejected(graph_name, q, plan, est,
                                        self.admission_budget_s)
            tier = ("interactive" if est <= self.interactive_threshold_s
                    else "batch")
            planned = plan
            if tier == "batch":
                plan = self._maybe_spill(ctx, q, plan)
            budget = self._tier_depth.get(tier)
            if budget is not None:
                depth = self._queue_depth(plan.engine, tier)
                if depth >= budget:
                    self.stats["backpressure"] += 1
                    if self.tracer is not None:
                        self.tracer.record_event("backpressure", {
                            "graph": graph_name,
                            "algorithm": q.algorithm, "tier": tier,
                            "depth": depth, "budget": budget})
                    raise RT.Backpressure(graph_name, q, plan.engine,
                                          tier, depth, budget)
            defn = R.get(q.algorithm)
            fusable = defn.fusable and plan.mode == "full"
            ticket = QueryTicket(
                self._next_ticket, graph_name, q, plan, tier, est,
                context=ctx,
                fuse_key=self._fuse_key(defn, q) if fusable else None,
                queued_at=time.perf_counter(),
                pool=plan.pool,
                seed=seed)
            self._next_ticket += 1
            self._tickets[ticket.ticket_id] = ticket
            self._queues.setdefault((plan.pool, plan.engine, tier),
                                    deque()).append(ticket)
            self.stats["submitted"] += 1
            if self.tracer is not None:
                original = None
                if plan is not planned:    # _maybe_spill re-placed it
                    original = {"pool": planned.pool,
                                "engine": planned.engine,
                                "variant": planned.variant,
                                "est_s": planned.est_s}
                self.tracer.on_submit(
                    ticket, ticket.queued_at,
                    admission={"est_s": est,
                               "budget_s": self.admission_budget_s,
                               "threshold_s": self.interactive_threshold_s,
                               "tier": tier},
                    plan_attrs={"engine": plan.engine,
                                "variant": plan.variant,
                                "pool": plan.pool, "mode": plan.mode,
                                "est_s": P.plan_cost(plan),
                                "reason": plan.reason},
                    candidates=plan.candidates,
                    original_placement=original)
            self._cond.notify_all()       # wake a parked worker
            return ticket

    def _maybe_spill(self, ctx: GraphContext, q, plan: P.Plan) -> P.Plan:
        """Batch-tier spill (caller holds the lock): when the planned
        pool's batch queue is at the pool's ``capacity``, re-place onto
        the cheapest other healthy pool where the snapshot is resident
        and whose own batch queue has room.  No candidate (or no
        capacity configured) keeps the original plan — spill sheds
        load, it never strands a query."""
        if plan.pool is None or len(self.pools) < 2:
            return plan
        pool = self.pools.get(plan.pool)
        if pool.capacity is None:
            return plan
        depth = self._pool_batch_depth(plan.pool)
        if depth < pool.capacity:
            return plan
        resident = ctx.residency
        cands = [p.name for p in self.pools
                 if p.healthy and p.name != plan.pool
                 and p.name in resident
                 and (p.capacity is None
                      or self._pool_batch_depth(p.name) < p.capacity)]
        if not cands:
            return plan
        try:
            spilled = ctx.plan_for_pools(q, cands)
        except ValueError:
            return plan
        self.stats["spilled"] += 1
        self._pool_spills[plan.pool] += 1
        return dataclasses.replace(
            spilled,
            reason=f"spilled from {plan.pool} (batch depth {depth} >= "
                   f"capacity {pool.capacity}); {spilled.reason}")

    def _queue_depth_key(self, key: tuple) -> int:
        """Live (still-queued) depth of one queue — resolved-out-of-band
        tickets linger in the deque until a dequeue skips them, so
        ``len`` alone over-counts."""
        q = self._queues.get(key)
        if not q:
            return 0
        return sum(1 for t in q if t.status == "queued")

    def _queue_depth(self, engine: str, tier: str) -> int:
        """Depth of one (engine, tier) aggregated over pools — the view
        tier backpressure budgets and ``metrics()['queue_depths']``
        keep from before federation."""
        return sum(self._queue_depth_key(k) for k in self._queues
                   if k[1] == engine and k[2] == tier)

    def _pool_batch_depth(self, pool_name: str) -> int:
        """Queued batch tickets bound for one pool (the spill trigger)."""
        return sum(self._queue_depth_key((pool_name, e, "batch"))
                   for e in self.ENGINE_ORDER)

    # -- resolution ---------------------------------------------------------
    def drain(self, workers: Optional[int] = None) -> list[QueryTicket]:
        """Run every queued ticket to completion and return the tickets
        finished by this call, in completion order.

        ``workers=1`` (the default unless the service was built with
        more) is the deterministic serial reference: engines in fixed
        order, every interactive queue strictly before any batch queue,
        each queue FIFO, batch tickets coalesced into fused executions
        where the registry allows.  ``workers>=2`` runs the same
        dequeue protocol from a thread pool — at most one in-flight
        unit per (context, engine), interactive still preempting batch
        at every dequeue — and per-ticket results are byte-identical to
        the serial schedule (the fusion/caching contracts make results
        order-independent)."""
        n = self.workers if workers is None else max(int(workers), 1)
        finished: list[QueryTicket] = []
        if n == 1:
            while True:
                with self._lock:
                    unit = self._next_unit()
                if unit is None:
                    break
                try:
                    self._execute_unit(unit, finished)
                finally:
                    self._pool_gate.release(unit.pool)
            return finished
        threads = [
            threading.Thread(target=self._worker_loop, args=(finished,),
                             name=f"gas-worker-{i}", daemon=True)
            for i in range(n)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        return finished

    def result(self, ticket: QueryTicket) -> QueryResult:
        """The ticket's result, executing work as needed.  Interactive
        tickets bypass the batch queue entirely: only the ticket itself
        runs.  Batch tickets drain the service (their fuse group rides
        along for free).  A ticket currently executing on a worker is
        awaited, not re-run."""
        with self._lock:
            t = self._tickets.get(ticket.ticket_id)
            if t is not ticket:
                raise ValueError(
                    f"ticket #{ticket.ticket_id} was not issued by this "
                    f"service (ids are per-service), or its result aged "
                    f"out of the {self.history_size}-entry history")
        while True:
            claimed = drain_needed = False
            with self._cond:
                if t.status == "done":
                    return self._results[t.ticket_id]
                if t.status == "dead-letter":
                    raise t.error
                if t.status == "running":
                    self._cond.wait(0.05)     # a worker owns it: await
                    continue
                # queued: claim it (interactive) or drain the service
                if t.tier == "interactive":
                    t.status = "running"
                    if self.tracer is not None:
                        self.tracer.on_dequeue([t.ticket_id])
                    claimed = True
                else:
                    drain_needed = True
            if claimed:
                # inline interactive execution deliberately bypasses the
                # pool gate: the caller is already blocked on this one
                # result, and the engine lock still serializes the pool's
                # actual device work
                self._execute_unit(_WorkUnit("solo", t.plan.engine, [t],
                                             pool=t.plan.pool), [])
            elif drain_needed:
                self.drain()

    def pending(self) -> list[QueryTicket]:
        with self._lock:
            return [t for t in self._tickets.values()
                    if t.status in ("queued", "running")]

    # -- metrics ------------------------------------------------------------
    def metrics(self) -> dict:
        """One consistent snapshot of the service's observable state:
        live queue depths, counters, cache hit rate, per-tier latency
        (submit→resolution) histograms with exact p50/p99 over the
        sample window, fusion widths, and the retry policy's counters.
        See docs/architecture.md for the field table."""
        with self._lock:
            depths = {f"{e}.{t}": self._queue_depth(e, t)
                      for e in self.ENGINE_ORDER for t in self.TIER_ORDER}
            hits = self.cache_stats["hits"]
            misses = self.cache_stats["misses"]
            total = hits + misses
            widths = list(self._fusion_widths)
            return {
                "workers": self.workers,
                "queue_depths": depths,
                "tier_depth_budget": dict(self._tier_depth),
                "counters": dict(self.stats),
                "cache": {"hits": hits, "misses": misses,
                          "hit_rate": (hits / total) if total else None},
                "tier_latency_s": {t: h.snapshot()
                                   for t, h in self._hist.items()},
                "fusion": {
                    "batches": self.stats["fused_batches"],
                    "tickets": self.stats["fused_tickets"],
                    "mean_width": (sum(widths) / len(widths)
                                   if widths else None),
                    "max_width": max(widths, default=None)},
                "retry": {"max_attempts": self.retry.max_attempts,
                          "retries": self.stats["retries"],
                          "dead_letters": self.stats["dead_letters"]},
                "incremental": self._meter.snapshot(),
                "pools": {p.name: self._pool_metrics(p)
                          for p in self.pools},
                "accuracy": self._accuracy.snapshot(),
                "trace": (self.tracer.counters_snapshot()
                          if self.tracer is not None
                          else {"enabled": 0, "depth": 0, "retained": 0,
                                "tickets": 0, "spans": 0, "evicted": 0,
                                "events": 0}),
            }

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of :meth:`metrics` — every
        numeric field flattened to a ``gas_``-prefixed sample line
        (``None`` becomes ``NaN``), non-numeric fields preserved as
        comment lines.  ``obs.parse_prometheus`` round-trips it."""
        return obs.render_prometheus(self.metrics())

    def explain(self, ticket) -> str:
        """Human-readable span tree for one ticket: admission verdict,
        the full plan-candidate table (losers annotated with why they
        lost), queue wait, each attempt with retry/fault events, the
        superstep counters of the execution that served it, and the
        resolution.  ``ticket`` is a :class:`QueryTicket` or a raw
        ticket id.  Requires the service to have been built with
        ``trace_depth > 0`` (or an explicit tracer)."""
        if self.tracer is None:
            raise RuntimeError(
                "tracing is off — construct the service with "
                "trace_depth > 0 (or pass tracer=) to record span trees")
        tid = getattr(ticket, "ticket_id", ticket)
        trace = self.tracer.trace(tid)
        if trace is None:
            raise KeyError(
                f"no trace retained for ticket #{tid}: it was never "
                f"submitted here, or it aged out of the "
                f"{self.tracer.trace_depth}-ticket trace ring")
        return obs.render_trace(trace)

    def _pool_metrics(self, p: PL.DevicePool) -> dict:
        """One pool's metrics row (caller holds the lock).  On a
        trivial poolset plans carry ``pool=None``, so the default
        pool's depths are read from the ``None``-keyed queues — the
        row always reflects the work actually bound for the pool."""
        key_pool = None if self.pools.trivial else p.name
        return {
            "healthy": p.healthy,
            "capacity": p.capacity,
            "max_inflight": p.max_inflight,
            "inflight": self._pool_gate.inflight(p.name),
            "queue_depths": {
                f"{e}.{t}": self._queue_depth_key((key_pool, e, t))
                for e in self.ENGINE_ORDER for t in self.TIER_ORDER},
            "transfer_bytes": self._ledger.bytes_for(p.name),
            "transfers": self._ledger.transfers_for(p.name),
            "spilled_away": self._pool_spills.get(p.name, 0),
        }

    # -- scheduling internals -----------------------------------------------
    @staticmethod
    def _fuse_key(defn: R.AlgorithmDef, q):
        """The query's fuse compatibility key, computed once at submit
        over *validated* params (the registry's fuse contract) — a
        directly-constructed query without schema defaults filled must
        not crash the scheduler.  ``None`` means unfusable: the ticket
        runs solo and any schema error surfaces at execution, attributed
        to that ticket."""
        try:
            return (defn.name, defn.fuse(defn.validate(q.params)))
        except Exception:
            return None

    def _next_unit(self, skip_busy: bool = False) -> Optional[_WorkUnit]:
        """Dequeue the next work unit (caller holds the lock).

        Interactive preemption lives here: every scan visits ALL
        interactive queues before ANY batch queue, so an interactive
        ticket submitted while batch work is queued is served by the
        next free worker.  Per queue the order is strictly FIFO — a
        head blocked on a busy (context, pool, engine) or a full pool
        gate parks its whole queue rather than letting younger tickets
        overtake it.  Dequeued tickets flip to ``running`` before the
        lock is released, so no two workers (or a worker and an inline
        ``result``) ever claim the same ticket.  The returned unit
        holds a pool-gate slot; the caller releases it after
        ``_execute_unit``."""
        for tier in self.TIER_ORDER:
            for engine in self.ENGINE_ORDER:
                for pool in self._pool_scan_order():
                    q = self._queues.get((pool, engine, tier))
                    while q:
                        head = q[0]
                        if head.status != "queued":  # resolved elsewhere
                            q.popleft()
                            continue
                        if skip_busy and \
                                (id(head.context), pool, engine) \
                                in self._busy:
                            break                 # queue parked; next one
                        if not self._pool_gate.try_acquire(pool):
                            break                 # pool at max_inflight
                        q.popleft()
                        if tier == "interactive":
                            head.status = "running"
                            if self.tracer is not None:
                                self.tracer.on_dequeue([head.ticket_id])
                            return _WorkUnit("solo", engine, [head],
                                             pool=pool)
                        group = self._take_fuse_group(q, head)
                        for t in group:
                            t.status = "running"
                        if self.tracer is not None:
                            self.tracer.on_dequeue(
                                [t.ticket_id for t in group])
                        return _WorkUnit("group", engine, group,
                                         pool=pool)
        return None

    def _pool_scan_order(self) -> tuple:
        """Queue-key pool axis in deterministic scan order: the
        ``None`` key (legacy/trivial plans) first, then pool order."""
        return (None,) + self.pools.names()

    @staticmethod
    def _take_fuse_group(queue: Optional[deque],
                         head: QueryTicket) -> list[QueryTicket]:
        """Pull every queued ticket fusable with ``head`` (same pinned
        context, equal precomputed fuse key) out of ``queue``,
        preserving the FIFO order of everything left behind."""
        group = [head]
        if queue is None or head.fuse_key is None:
            return group
        keep = deque()
        while queue:
            t = queue.popleft()
            if t.status != "queued":
                continue
            if t.context is head.context and t.fuse_key == head.fuse_key:
                group.append(t)
            else:
                keep.append(t)
        queue.extend(keep)
        return group

    def _worker_loop(self, finished: list) -> None:
        """One pool thread: claim units until the queues are empty and
        nothing is in flight.  An in-flight unit never *creates* queued
        work (retries run inline), but concurrent ``submit`` may — the
        condition wakes parked workers for both new work and freed
        (context, engine) pairs."""
        while True:
            with self._cond:
                unit = self._next_unit(skip_busy=True)
                if unit is None:
                    if self._inflight == 0 and not self._any_queued():
                        return
                    self._cond.wait(0.05)
                    continue
                self._inflight += 1
                self._busy.add(unit.busy_key)
            try:
                self._execute_unit(unit, finished)
            finally:
                self._pool_gate.release(unit.pool)
                with self._cond:
                    self._inflight -= 1
                    self._busy.discard(unit.busy_key)
                    self._cond.notify_all()

    def _any_queued(self) -> bool:
        return any(t.status == "queued"
                   for q in self._queues.values() for t in q)

    # -- execution internals ------------------------------------------------
    def _backoff_seed(self, ticket_id: int) -> int:
        # stable across runs for a fixed service seed and ticket id —
        # the determinism the stress harness replays
        return self.seed * 1_000_003 + ticket_id

    def _run_with_retries(self, thunk, seed_id: int, tickets: list,
                          fused: bool = False):
        """Execute ``thunk`` under the retry policy.  Returns
        ``(result, None)`` on success or ``(None, error)`` once the
        policy gives up; ``error`` carries the full attempt chain
        (attempt k's exception is the ``__cause__`` of attempt k+1's).
        Sleeps follow the jittered schedule seeded per ticket, so a
        replayed drain backs off identically.  Each attempt opens one
        attempt span per ticket around a shared execute span (tracing
        on); the final failure's span carries the whole chain."""
        schedule = self.retry.schedule(self._backoff_seed(seed_id))
        ids = [t.ticket_id for t in tickets]
        last: Optional[BaseException] = None
        for attempt in range(1, self.retry.max_attempts + 1):
            for t in tickets:
                t.attempts = attempt
            handle = None
            if self.tracer is not None:
                handle = self.tracer.on_attempt_start(ids, attempt,
                                                      fused=fused)
            try:
                out = thunk()
            except Exception as e:
                if last is not None and e is not last \
                        and e.__cause__ is None:
                    e.__cause__ = last       # preserve the attempt chain
                last = e
                if handle is not None:
                    self.tracer.on_attempt_end(handle, e)
                if not self.retry.retryable(e) \
                        or attempt >= self.retry.max_attempts:
                    return None, e
                with self._lock:
                    self.stats["retries"] += 1
                if self.tracer is not None:
                    self.tracer.on_retry(ids, attempt,
                                         schedule[attempt - 1])
                time.sleep(schedule[attempt - 1])
            else:
                if handle is not None:
                    self.tracer.on_attempt_end(handle)
                return out, None
        return None, last                    # pragma: no cover

    def _execute_unit(self, unit: _WorkUnit, finished: list) -> None:
        """Run one dequeued unit to resolution (outside the lock; only
        bookkeeping re-acquires it)."""
        if unit.kind == "solo":
            self._execute_solo(unit.tickets[0], finished)
        else:
            self._execute_group(unit.engine, unit.tickets, finished)

    def _execute_solo(self, t: QueryTicket, finished: list) -> None:
        ctx = t.context
        key = self._result_key(ctx, t.query)
        hit = self._cache_get(key)
        if hit is not None:
            if self.tracer is not None:
                self.tracer.ticket_event([t.ticket_id], "cache-hit")
            self._finish(t, hit)
            finished.append(t)
            return
        self._account_transfer(ctx, t.plan, [t])
        profile = self.tracer is not None
        t0 = time.perf_counter()
        r, err = self._run_with_retries(
            lambda: ctx.execute(t.query, t.plan, seed=t.seed,
                                profile=profile),
            t.ticket_id, [t])
        wall = time.perf_counter() - t0
        if err is not None:
            self._dead_letter([t], err)
            finished.append(t)
            return
        self._accuracy.record(t.query.algorithm, t.plan.engine,
                              t.plan.variant, t.plan.pool,
                              est_s=t.est_s, wall_s=wall,
                              mode=t.plan.mode)
        if self.tracer is not None:
            self.tracer.on_execute_result(
                [t.ticket_id], engine=r.engine,
                attrs=self._result_attrs(r, wall))
        self._record_incremental(r, t.seed, ctx)
        with self._lock:
            self.stats["executed"] += 1
            # re-key: accounting may have materialized the pool
            self._cache_put(self._result_key(ctx, t.query),
                            self._strip_run_meta(r))
            self._finish(t, r)
            self._log(t.plan.engine, t.tier, [t], fused=False,
                      algorithm=t.query.algorithm)
        finished.append(t)

    @staticmethod
    def _result_attrs(r: QueryResult, wall: float) -> dict:
        """Execute-span annotations from what actually ran."""
        attrs = {"wall_s": wall, "iterations": r.iterations}
        for k in ("variant", "mode"):
            if k in r.meta:
                attrs[k] = r.meta[k]
        if "superstep" in r.meta:
            attrs["superstep"] = dict(r.meta["superstep"])
        return attrs

    @staticmethod
    def _strip_run_meta(r: QueryResult,
                        also: Sequence[str] = ()) -> QueryResult:
        """The cacheable copy of a result: drop meta keys that describe
        THIS execution (superstep counters, fusion shape) — a later
        cache hit replaying them would claim an execution that never
        happened for that caller."""
        drop = {"superstep", *also}
        if not (drop & r.meta.keys()):
            return r
        return dataclasses.replace(
            r, meta={k: v for k, v in r.meta.items() if k not in drop})

    def _execute_group(self, engine: str, group: list[QueryTicket],
                       finished: list) -> None:
        """Execute one fuse group: cached tickets answered for free, the
        rest as a single fused batch program (or solo when only one —
        or the algorithm has no batch path — remains).  A failing fused
        execution retries (and dead-letters) as a unit: every ticket in
        it shares the attempt chain."""
        ctx = group[0].context
        run: list[QueryTicket] = []
        for t in group:
            hit = self._cache_get(self._result_key(ctx, t.query))
            if hit is not None:
                if self.tracer is not None:
                    self.tracer.ticket_event([t.ticket_id], "cache-hit")
                self._finish(t, hit)
                finished.append(t)
            else:
                run.append(t)
        if not run:
            return
        defn = R.get(group[0].query.algorithm)
        if len(run) == 1 or not defn.fusable:
            for t in run:
                self._execute_solo(t, finished)
            return
        self._account_transfer(ctx, run[0].plan, run)
        pool = ctx.pool_for_plan(run[0].plan)
        profile = self.tracer is not None
        t0 = time.perf_counter()
        r, err = self._run_with_retries(
            lambda: ctx.engine(engine, pool).run_batch(
                defn, [t.query.params for t in run],
                count_only=[t.query.count_only for t in run],
                profile=profile),
            run[0].ticket_id, run, fused=True)
        wall = time.perf_counter() - t0
        if err is not None:
            self._dead_letter(run, err)
            finished.extend(run)
            return
        # one fused execution, one accuracy sample: the group's shared
        # wall against the head ticket's estimate, width recorded
        head = run[0]
        self._accuracy.record(head.query.algorithm, head.plan.engine,
                              head.plan.variant, head.plan.pool,
                              est_s=head.est_s, wall_s=wall,
                              mode=head.plan.mode, width=len(run))
        if self.tracer is not None:
            self.tracer.on_execute_result(
                [t.ticket_id for t in run], engine=r[0].engine,
                attrs={**self._result_attrs(r[0], wall),
                       "batch_size": len(run)},
                per_ticket={t.ticket_id: {"est_s": t.est_s,
                                          "index": i}
                            for i, t in enumerate(run)})
        with self._lock:
            self.stats["executed"] += 1
            self.stats["fused_batches"] += 1
            self.stats["fused_tickets"] += len(run)
            self._fusion_widths.append(len(run))
            for t, res in zip(run, r):
                res.meta["plan"] = t.plan
                # the cached copy drops 'fused' (and the superstep
                # counters) — they describe THIS run; a later hit
                # replaying them would claim a fusion that never
                # happened for that caller (the ticket keeps the full
                # meta)
                cached = self._strip_run_meta(res, also=("fused",))
                self._cache_put(self._result_key(ctx, t.query), cached)
                self._finish(t, res)
            self._log(engine, "batch", run, fused=True,
                      algorithm=defn.name)
        finished.extend(run)

    def _finish(self, t: QueryTicket, r: QueryResult) -> None:
        with self._cond:
            t.status = "done"
            self._results[t.ticket_id] = r
            self._hist[t.tier].observe(time.perf_counter() - t.queued_at)
            self._age_out(t)
            self._cond.notify_all()
        if self.tracer is not None:
            self.tracer.on_resolve([t.ticket_id], "done")

    def _dead_letter(self, tickets, error: BaseException) -> None:
        """The retry policy gave up: the tickets must not be stranded
        (out of every queue, forever pending).  They land in the
        ``dead-letter`` state keeping the attempt chain, ``result``
        re-raises, and the drain continues with the rest of the queue."""
        with self._cond:
            for t in tickets:
                t.status = "dead-letter"
                t.error = error
                self._hist[t.tier].observe(
                    time.perf_counter() - t.queued_at)
                self._age_out(t)
            self.stats["failed"] += len(tickets)
            self.stats["dead_letters"] += len(tickets)
            self._cond.notify_all()
        if self.tracer is not None:
            self.tracer.on_resolve([t.ticket_id for t in tickets],
                                   "dead-letter", error)

    def _age_out(self, t: QueryTicket) -> None:
        """Record ``t`` as resolved and evict the oldest resolved
        tickets (and their stored results) beyond ``history_size``."""
        self._resolved_order.append(t.ticket_id)
        while len(self._resolved_order) > max(self.history_size, 0):
            old = self._resolved_order.popleft()
            self._tickets.pop(old, None)
            self._results.pop(old, None)

    def _log(self, engine: str, tier: str, tickets, fused: bool,
             algorithm: str) -> None:
        self.execution_log.append({
            "engine": engine, "tier": tier, "fused": fused,
            "algorithm": algorithm,
            "tickets": [t.ticket_id for t in tickets]})
