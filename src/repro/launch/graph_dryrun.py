import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Graph-engine dry-run at PAPER scale: prove the distributed BSP engine
# lowers, partitions and fits for the paper's production workloads on the
# v5e mesh — the reproduction's "would it actually run" artifact.
#
#   multi-account graph: 14.89B vertices, 30.86B edges (heterogeneous)
#   combined connected users: 2.41B vertices, 1.50B edges
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.graph_dryrun [--mesh single|multi]

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax import lax

from repro.utils.compat import shard_map

from repro.launch.mesh import make_production_mesh, n_chips
from repro.utils import roofline as RL
from repro.core.graph import round_up


def lower_pagerank_grid(mesh, n_vertices: int, n_edges: int,
                        n_iters: int = 20, state_bf16: bool = False):
    """Communication-optimal 2-D grid partition (the hillclimbed engine):
    shard (d, m) owns edges with src in range d (data axis) and dst in
    range m (model axis).  Vertex state x is sharded by SRC range over
    'data' — no all_gather of x at all; per superstep the new state
    (computed per dst range) reshards model->data with one all_to_all of
    V/chips per chip.  Collectives drop from O(V) to O(V / n_data)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = sizes.get("data", 1) * sizes.get("pod", 1)
    n_model = sizes.get("model", 1)
    e_shard = round_up(-(-n_edges // (n_data * n_model)), 1024)
    v_loc_d = round_up(-(-n_vertices // n_data), 8)     # x by src range
    v_loc_m = round_up(-(-n_vertices // n_model), 8)    # agg by dst range
    V = n_vertices

    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    edge_spec = P((*data_axes, "model"))
    x_spec = P(data_axes)

    sdt = jnp.bfloat16 if state_bf16 else jnp.float32

    def body(src, dst, w, x_d):
        d_idx = lax.axis_index(data_axes[-1]) if len(data_axes) == 1 else (
            lax.axis_index(data_axes[0]) * sizes["data"]
            + lax.axis_index(data_axes[1]))
        m_idx = lax.axis_index("model")
        src_start = d_idx * v_loc_d
        dst_start = m_idx * v_loc_m

        def one_iter(x_d, _):
            # local src ids -> slice of x owned by this data row
            local_src = jnp.clip(src - src_start, 0, v_loc_d - 1)
            msgs = x_d[local_src].astype(jnp.float32) * w
            local_dst = jnp.where(dst >= V, v_loc_m,
                                  jnp.clip(dst - dst_start, 0, v_loc_m))
            agg = jax.ops.segment_sum(msgs, local_dst,
                                      num_segments=v_loc_m + 1)[:v_loc_m]
            for ax in data_axes:
                agg = lax.psum(agg, ax)                  # combine src rows
            new_m = 0.15 / V + 0.85 * agg                # x by dst range
            # reshard dst-range(model) -> src-range(data): after the data
            # psum, new_m is replicated across data rows, so the chip
            # with m_idx == d_idx holds exactly the slice this chip needs
            # next round.  A masked psum over 'model' delivers it with
            # one ring all-reduce of V/16 floats — O(V/n) instead of the
            # O(V) full gather of the 1-D layout.
            # bf16 wire: PageRank tolerates bf16 state with f32 message
            # accumulation (segment_sum above is f32)
            new_m = new_m.astype(sdt)
            mine = jnp.where(m_idx == d_idx, new_m, jnp.zeros_like(new_m))
            new_d = lax.psum(mine, "model")
            if v_loc_d != v_loc_m:
                new_d = new_d[:v_loc_d]
            return new_d, None

        x_d, _ = lax.scan(one_iter, x_d, None, length=n_iters)
        return x_d

    total_shards = n_data * n_model
    src_sds = jax.ShapeDtypeStruct((total_shards * e_shard,), jnp.int32,
                                   sharding=NamedSharding(mesh, edge_spec))
    w_sds = jax.ShapeDtypeStruct((total_shards * e_shard,), jnp.float32,
                                 sharding=NamedSharding(mesh, edge_spec))
    x_sds = jax.ShapeDtypeStruct((n_data * v_loc_d,), sdt,
                                 sharding=NamedSharding(mesh, x_spec))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(edge_spec, edge_spec, edge_spec, x_spec),
                   out_specs=x_spec, check_vma=False)
    with mesh:
        lowered = jax.jit(fn).lower(src_sds, src_sds, w_sds, x_sds)
        t0 = time.time()
        compiled = lowered.compile()
        dt = time.time() - t0
    sb = 2 if state_bf16 else 4
    return compiled, {
        "e_shard": e_shard, "v_local": v_loc_m, "compile_s": dt,
        "chips": n_chips(mesh),
        "flops": 2.0 * e_shard + 5.0 * v_loc_d,
        "bytes": e_shard * 12 + (v_loc_d + v_loc_m) * 2 * sb,
        # psum of dst aggregates (f32, ring over data) + masked-psum
        # reshard (state dtype, ring over model) — both O(V/16)
        "coll_bytes": (v_loc_m * 4 * 2 * (n_data - 1) / n_data
                       + v_loc_m * sb * 2 * (n_model - 1) / n_model),
    }


def lower_pagerank(mesh, n_vertices: int, n_edges: int, n_iters: int = 20,
                   vertex_sharded: bool = True):
    """AOT-lower the BSP PageRank superstep loop over abstract edge
    shards of the production scale.  Vertex state is sharded over
    'model' (the 2-D vertex-cut); edges over ('data','model')."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = sizes.get("data", 1) * sizes.get("pod", 1)
    n_model = sizes.get("model", 1)
    e_shard = round_up(-(-n_edges // (n_data * n_model)), 1024)
    v_local = round_up(-(-n_vertices // n_model), 8)
    V = n_vertices

    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    edge_spec = P((*data_axes, "model"))
    state_spec = P("model")

    def body(src, dst, w, x):
        m_idx = lax.axis_index("model")
        start = m_idx * v_local

        def one_iter(x, _):
            full = lax.all_gather(x, "model", tiled=True)
            msgs = full[jnp.clip(src, 0, full.shape[0] - 1)] * w
            local_dst = jnp.where(dst >= V, v_local,
                                  jnp.clip(dst - start, 0, v_local))
            agg = jax.ops.segment_sum(msgs, local_dst,
                                      num_segments=v_local + 1)[:v_local]
            for ax in data_axes:
                agg = lax.psum(agg, ax)
            return 0.15 / V + 0.85 * agg, None

        x, _ = lax.scan(one_iter, x, None, length=n_iters)
        return x

    total_shards = n_data * n_model
    src_sds = jax.ShapeDtypeStruct(
        (total_shards * e_shard,), jnp.int32,
        sharding=NamedSharding(mesh, edge_spec))
    w_sds = jax.ShapeDtypeStruct(
        (total_shards * e_shard,), jnp.float32,
        sharding=NamedSharding(mesh, edge_spec))
    x_sds = jax.ShapeDtypeStruct(
        (n_model * v_local,), jnp.float32,
        sharding=NamedSharding(mesh, state_spec))

    fn = shard_map(body, mesh=mesh,
                   in_specs=(edge_spec, edge_spec, edge_spec, state_spec),
                   out_specs=state_spec, check_vma=False)
    with mesh:
        lowered = jax.jit(fn).lower(src_sds, src_sds, w_sds, x_sds)
        t0 = time.time()
        compiled = lowered.compile()
        dt = time.time() - t0
    return compiled, {
        "e_shard": e_shard, "v_local": v_local, "compile_s": dt,
        "chips": n_chips(mesh),
        # analytic per-superstep terms, per chip
        "flops": 2.0 * e_shard + 5.0 * v_local,
        "bytes": e_shard * 12 + v_local * 16 + V * 4,   # edges + state + gathered x
        "coll_bytes": (V * 4 * (n_model - 1) / n_model          # all_gather x
                       + v_local * 4 * 2 * (n_data - 1) / n_data),  # psum agg
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--out", default="benchmarks/results/graph_dryrun.json")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    workloads = {
        # paper scale, MaxAdjacentNodes=uncapped edge counts
        "multi_account_30.9B": dict(n_vertices=14_890_000_000 % (2**31 - 2),
                                    n_edges=30_860_000_000),
        "connected_users_1.5B": dict(n_vertices=2_410_000_000 % (2**31 - 2),
                                     n_edges=1_500_000_000),
    }
    # NOTE: vertex ids are int32 in the engine; the 14.89B-vertex graph
    # exceeds int32 — production would use int64 ids (2x index bytes) or
    # id-compressed partitions.  We lower the int32 variant at the true
    # EDGE scale (the cost driver) and note the id-width adjustment.
    results = {}
    for name, w in workloads.items():
      import functools
      for variant, lower in [
              ("baseline_1d", lower_pagerank),
              ("grid_2d", lower_pagerank_grid),
              ("grid_2d_bf16", functools.partial(lower_pagerank_grid,
                                                 state_bf16=True))]:
        compiled, meta = lower(mesh, w["n_vertices"], w["n_edges"],
                               n_iters=args.iters)
        mem = compiled.memory_analysis()
        per_step = {
            "compute_s": meta["flops"] / RL.PEAK_FLOPS_BF16,
            "memory_s": meta["bytes"] / RL.HBM_BW,
            "collective_s": meta["coll_bytes"] / RL.LINK_BW,
        }
        dom = max(per_step, key=per_step.get)
        results[f"{name}/{variant}"] = {
            **meta, **per_step, "dominant": dom,
            "mem_per_dev_gb": (mem.temp_size_in_bytes
                               + mem.argument_size_in_bytes) / 1e9,
        }
        rr = results[f"{name}/{variant}"]
        print(f"{name}/{variant}: chips={meta['chips']} "
              f"e_shard={meta['e_shard']:,} "
              f"mem/dev={rr['mem_per_dev_gb']:.2f}GB "
              f"compile={meta['compile_s']:.1f}s dominant={dom} "
              f"superstep={max(per_step.values())*1e3:.2f}ms")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, default=str)


if __name__ == "__main__":
    main()
