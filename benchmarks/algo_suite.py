"""Algorithm-suite sweep: per-workload local-vs-distributed crossover in
the Fig. 5 style, across the full vertex-program library.

The suite is *registry-driven*: it iterates every ``AlgorithmDef`` with
an ``example_params`` entry, so a newly registered algorithm shows up in
the sweep (and in the local==distributed parity assertion) without any
edit here.  For every algorithm this measures, at each graph scale:

  * LocalEngine wall time (the Neo4j-analogue interactive path);
  * DistributedEngine wall time (edge-partitioned BSP, n_data=4 — on a
    one-device box this exposes the partitioning/launch overhead whose
    amortization is exactly the Fig. 5 story);
  * the count-only fast-path time where the algorithm has one (the
    paper's '<2 s count vs ~10 min table' pattern);
  * every registered execution *variant* where an algorithm has several
    (triangle counting's bitset vs ELL-intersect paths) — timed
    separately, asserted equal, with the planner's projected
    variant-selection crossover reported alongside;
  * the planner's projected crossover scale for a 256-chip mesh — each
    algorithm crosses at a different V because its iteration count,
    state bytes and message volume differ (triangle counting's bitset
    state crosses earliest, degree-like scans latest).

Results double as calibration input for the planner constants:
``--emit-calibration profile.json`` fits one measured/modeled wall-clock
ratio per algorithm from the sweep and writes a
``planner.CalibrationProfile`` that ``planner.load_calibration`` applies
process-wide — including the service tier thresholds, which are derived
from the measured interactive (count-path) latencies.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import time_fn, csv_row
from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.engines import LocalEngine, DistributedEngine
from repro.data import synthetic as S


def _build(n_vertices: int, symmetric: bool) -> G.GraphCOO:
    src, dst = S.user_follow_graph(n_vertices, 4.0, seed=1)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], n_vertices,
                       symmetrize=symmetric)


def _suite():
    """Registered algorithms that declared representative parameters."""
    return [(name, defn) for name, defn in R.items()
            if defn.example_params is not None]


def _assert_same(name: str, a, b) -> None:
    if isinstance(a, dict):
        assert sorted(a) == sorted(b), name
        for k in a:
            _assert_same(f"{name}[{k}]", a[k], b[k])
        return
    if isinstance(a, tuple):
        for x, y in zip(a, b):
            _assert_same(name, x, y)
        return
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, name
    if np.issubdtype(a.dtype, np.floating):
        # summation order differs across edge shards
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7, err_msg=name)
    else:
        assert (a == b).all(), name


def run(out=print, samples=None):
    """The sweep.  ``samples``, when given, is filled with calibration
    inputs: per-algorithm ``[(measured_s, modeled_s), ...]`` pairs for
    the local engine, plus measured count-path latencies under the
    ``"_count_times"`` key (the tier-threshold input)."""
    rows = []
    for n_vertices in [2_000, 20_000]:
        graphs = {sym: _build(n_vertices, sym) for sym in (False, True)}
        locals_ = {sym: LocalEngine(g) for sym, g in graphs.items()}
        dists = {sym: DistributedEngine(g, n_data=4)
                 for sym, g in graphs.items()}
        for name, defn in _suite():
            sym = defn.requires_symmetric
            params = dict(defn.example_params)
            t_local, r_local = time_fn(
                lambda: locals_[sym].run(defn, params).value)
            out(csv_row(f"algo_suite/{name}_local_v{n_vertices}", t_local))
            if samples is not None:
                # measured-vs-modeled under the *analytic* defaults so a
                # previously loaded profile never skews a re-fit
                stats = P.GraphStats.of(graphs[sym])
                spec = P.best_spec_for_engine(
                    stats, P.specs_for(name, stats, **params), "local")
                modeled = P.estimate_local_cost(
                    stats, spec, profile=P.CalibrationProfile())
                if np.isfinite(modeled):
                    samples.setdefault(name, []).append((t_local, modeled))
            var_times = {}
            for var in sorted(defn.variants or ()):
                # each execution strategy timed on its own; the bitset
                # path at 20k V is exactly the pre-ELL-intersect wall
                t_var, r_var = time_fn(
                    lambda: locals_[sym].run(defn, params,
                                             variant=var).value)
                _assert_same(f"{name}:{var}", r_local, r_var)
                out(csv_row(f"algo_suite/{name}_{var}_v{n_vertices}",
                            t_var))
                var_times[var] = t_var
            if samples is not None and {"dense", "fused",
                                        "frontier"} <= set(var_times):
                # superstep strategies: measured wall ratios vs the
                # dense oracle calibrate the per-variant edge-bytes
                # factors (`planner.superstep_specs`)
                samples.setdefault("_superstep_times", []).append(
                    var_times)
            if "distributed" in defn.engines:
                t_dist, r_dist = time_fn(
                    lambda: dists[sym].run(defn, params).value)
                _assert_same(name, r_local, r_dist)
                out(csv_row(f"algo_suite/{name}_bsp_v{n_vertices}", t_dist,
                            f"bsp_ratio={t_dist / t_local:.2f}x"))
            if defn.has_count_path:
                t_count, _ = time_fn(
                    lambda: locals_[sym].run(defn, params,
                                             count_only=True).value)
                out(csv_row(
                    f"algo_suite/{name}_count_v{n_vertices}", t_count,
                    f"count_vs_table={t_local / max(t_count, 1e-9):.2f}x"))
                if samples is not None:
                    samples.setdefault("_count_times", []).append(t_count)
            rows.append((name, n_vertices, t_local))

    # planner-projected crossover per algorithm on the production mesh —
    # the per-workload Fig. 5 family
    for name, defn in R.items():
        if "distributed" not in defn.engines:
            continue
        cross = None
        for v in [10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
            stats = P.GraphStats(v, v * 5, v * 5 * 12)
            plan = P.choose_engine(stats, P.spec_for(name, stats), 256)
            if plan.engine == "distributed":
                cross = v
                break
        out(csv_row(f"algo_suite/crossover_{name}", 0.0,
                    f"crossover_at_V={cross}"))

    # variant-selection crossovers: where the planner's cheapest
    # feasible strategy flips (bitset -> intersect for triangles), and
    # where the multi-variant plan finally leaves the local engine —
    # the headline being how far past the bitset wall intersect keeps
    # triangle queries local
    for name, defn in R.items():
        if not defn.variants:
            continue
        var_cross = eng_cross = None
        prev = None
        for v in [10**3, 10**4, 10**5, 10**6, 10**7, 10**8, 10**9]:
            stats = P.GraphStats(v, v * 5, v * 5 * 12)
            plan = P.choose_plan(stats, P.specs_for(name, stats), 256)
            if prev is not None and plan.variant != prev and not var_cross:
                var_cross = f"{prev}->{plan.variant}_at_V={v}"
            prev = plan.variant
            if plan.engine == "distributed" and eng_cross is None:
                eng_cross = v
        out(csv_row(f"algo_suite/variant_crossover_{name}", 0.0,
                    var_cross or "no_flip"))
        out(csv_row(f"algo_suite/variant_engine_crossover_{name}", 0.0,
                    f"local_until_V={eng_cross}"))
    return rows


def emit_calibration(path, samples, out=print) -> P.CalibrationProfile:
    """Fit a :class:`planner.CalibrationProfile` from sweep samples and
    write it to ``path``.

    Per algorithm, the measured per-algorithm constant is the median
    measured/modeled wall-clock ratio over the sweep's scales — the one
    multiplier that anchors that algorithm's analytic estimate to real
    executions on this box.  The interactive tier threshold is derived
    from the measured count-path latencies (the paper's interactive
    query class): generously above every observed one, so genuinely
    interactive shapes classify interactive while table-scale work
    stays batch.  Empty ``samples`` writes the analytic defaults — the
    profile round-trips regardless.
    """
    scales = {}
    for name, pairs in samples.items():
        if name.startswith("_") or not pairs:
            continue
        ratios = sorted(t / m for t, m in pairs if m > 0)
        scales[name] = float(np.median(ratios))
    kwargs = {}
    count_times = samples.get("_count_times") or []
    if count_times:
        kwargs["interactive_threshold_s"] = float(
            max(10.0 * max(count_times), 1e-3))
    superstep = samples.get("_superstep_times") or []
    if superstep:
        # per-variant edge-bytes factor anchored to the dense oracle:
        # factor_v = dense_factor * median(t_v / t_dense) across the
        # sweep — on a CPU host the frontier's scatter loop can fit
        # *above* 1.0, which is exactly the feedback that keeps the
        # planner from picking it where it does not pay off
        fitted = {"dense": P._SUPERSTEP_EDGE_BYTES["dense"]}
        for var in ("fused", "frontier"):
            ratios = sorted(vt[var] / vt["dense"] for vt in superstep
                            if vt["dense"] > 0)
            if ratios:
                fitted[var] = float(fitted["dense"]
                                    * np.median(ratios))
        kwargs["superstep_edge_bytes"] = fitted
    profile = P.CalibrationProfile(
        algo_time_scale=scales, source="benchmarks/algo_suite.py", **kwargs)
    profile.to_json(path)
    out(csv_row("algo_suite/calibration_written", 0.0,
                f"path={path} algorithms={len(scales)}"))
    return profile


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit-calibration", metavar="PATH", default=None,
                    help="write measured per-algorithm planner constants "
                         "to PATH (loadable via planner.load_calibration)")
    args = ap.parse_args(argv)
    samples: dict = {}
    run(samples=samples if args.emit_calibration else None)
    if args.emit_calibration:
        emit_calibration(args.emit_calibration, samples)


if __name__ == "__main__":
    main()
