"""Mixture-of-Experts LM (olmoe, dbrx) — capacity-based einsum dispatch.

TPU-native MoE (Mesh-TF/Switch lineage): top-k routing builds static
one-hot dispatch/combine tensors; expert FFNs run as one batched einsum
over the expert axis, which is sharded over ``model`` (EP == TP axis).
Under pjit the dispatch einsum lowers to the all-to-all that dominates
this family's collective roofline term.

Dropped tokens: capacity C = ceil(top_k * tokens/experts * capacity_factor)
per expert; overflow tokens pass through the residual (standard).  A
Switch-style load-balance auxiliary loss keeps the router honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.transformer import DenseLM


def _capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(np.ceil(top_k * tokens * factor / n_experts))
    return max(8, ((c + 7) // 8) * 8)   # pad to sublane


def moe_apply_block(p, xt, cfg, capacity: int):
    """One token block. xt [G, D] -> (y [G, D], aux_loss scalar)."""
    g, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = xt.dtype
    wire_int8 = getattr(cfg, "moe_wire_int8", False)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # [G,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # [G,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)        # [G,k,E]
    flat = onehot.reshape(g * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(g, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)               # [G,k]
    keep = pos < capacity

    # dispatch [G,E,C] / combine [G,E,C]
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity),
                            capacity, dtype=dt)                  # [G,k,C]
    disp = jnp.einsum("gke,gkc->gec", onehot.astype(dt), pos_oh)
    comb = jnp.einsum("gke,gkc,gk->gec", onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(dt)

    if wire_int8:
        # int8 wire: quantize tokens per-row BEFORE the dispatch einsum —
        # the sharding boundary (token->expert all-to-all) then moves s8
        # instead of bf16, halving the dominant MoE collective.  Scales
        # ride along through a tiny second einsum.
        scale = jnp.max(jnp.abs(xt), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        xt_q = jnp.clip(jnp.round(xt / scale), -127, 127).astype(jnp.int8)
        ein = jnp.einsum("gec,gd->ecd", disp.astype(jnp.int8), xt_q,
                         preferred_element_type=jnp.int32)
        sc_ec = jnp.einsum("gec,g->ec", disp, scale[:, 0])
        expert_in = (ein.astype(jnp.float32)
                     * sc_ec[..., None].astype(jnp.float32)).astype(dt)
    else:
        expert_in = jnp.einsum("gec,gd->ecd", disp, xt)          # [E,C,D]
    gate_w = jax.nn.silu(jnp.einsum(
        "ecd,edf->ecf", expert_in, p["w_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"].astype(dt))
    expert_out = jnp.einsum("ecf,efd->ecd", gate_w * up,
                            p["w_down"].astype(dt))
    y = jnp.einsum("gec,ecd->gd", comb, expert_out)

    # Switch load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(onehot.sum(axis=1).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens / k * frac_probs)
    return y, aux


def moe_apply(p, x, cfg, capacity: int = 0, block_tokens: int = 1024):
    """x [B,S,D] -> (y, aux).  Tokens are processed in blocks of
    ~``block_tokens`` — the one-hot dispatch einsum is O(G * E*C * D)
    with C ∝ G/E, i.e. *quadratic* in unblocked G; blocking restores
    linearity (the grouped-MoE formulation).  Capacity is per block."""
    b, s, d = x.shape
    g = b * s
    sb = max(1, min(s, block_tokens // max(b, 1)))
    nb = s // sb if s % sb == 0 else 1
    if nb <= 1:
        cap = _capacity(g, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
        y, aux = moe_apply_block(p, x.reshape(g, d), cfg, cap)
        return y.reshape(b, s, d), aux
    cap = _capacity(b * sb, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
    xb = x.reshape(b, nb, sb, d).transpose(1, 0, 2, 3).reshape(nb, b * sb, d)

    def step(aux, xt):
        y, a = moe_apply_block(p, xt, cfg, cap)
        return aux + a, y

    aux, ys = jax.lax.scan(step, jnp.float32(0), xb)
    y = ys.reshape(nb, b, sb, d).transpose(1, 0, 2, 3).reshape(b, s, d)
    return y, aux / nb


class MoELM(DenseLM):
    family = "moe"

    def _init_layers(self, key) -> dict:
        cfg = self.cfg
        ka, km = jax.random.split(key)
        lcount, d, f, e = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
        ks = jax.random.split(km, 4)
        p = {
            "ln1": jnp.zeros((lcount, d), jnp.float32),
            "ln2": jnp.zeros((lcount, d), jnp.float32),
            "attn": L.init_attn(ka, cfg, layers=lcount),
            "mlp": {
                "router": jax.random.normal(ks[0], (lcount, d, e),
                                            jnp.float32) * d ** -0.5,
                "w_gate": jax.random.normal(ks[1], (lcount, e, d, f),
                                            jnp.float32) * d ** -0.5,
                "w_up": jax.random.normal(ks[2], (lcount, e, d, f),
                                          jnp.float32) * d ** -0.5,
                "w_down": jax.random.normal(ks[3], (lcount, e, f, d),
                                            jnp.float32)
                          * (f ** -0.5) / max(lcount, 1) ** 0.5,
            },
        }
        return p

    def _ffn(self, p_l, h, *_):
        y, _aux = moe_apply(p_l["mlp"], h, self.cfg)
        return y

    def loss(self, params, batch, vocab_chunk: int = 8):
        # Wrap the dense loss; add router aux losses accumulated via a
        # functional pass (recompute with a scan carrying the aux sum).
        cfg = self.cfg
        x, qpos = self._embed_inputs(params, batch)

        def body(carry, xs):
            p_l, w_l = xs
            h, aux = carry
            h = self._constrain_act(h)
            h2 = L.rms_norm(h, p_l["ln1"])
            o, _ = self._mixer_train(p_l, w_l, h2, qpos)
            h = h + o
            hn = L.rms_norm(h, p_l["ln2"])
            y, a = moe_apply(p_l["mlp"], hn, cfg)
            return (h + y, aux + a), None

        body_fn = body
        if cfg.remat:
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                   (params["layers"], self.windows))

        targets = batch["labels"]
        b, s = targets.shape
        nc = vocab_chunk if s % vocab_chunk == 0 else 1
        xc = x.reshape(b, nc, s // nc, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, s // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_loss(carry, xs):
            xx, tt = xs
            logits = L.unembed(params, xx, cfg)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tt, 0)[..., None], axis=-1)[..., 0]
            valid = (tt >= 0)
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = jax.lax.scan(chunk_loss,
                                     (jnp.float32(0), jnp.int32(0)), (xc, tc))
        ce = tot / jnp.maximum(cnt, 1)
        aux_mean = aux / cfg.n_layers
        loss = ce + cfg.router_aux_coef * aux_mean
        return loss, {"loss": loss, "ce": ce, "aux": aux_mean, "tokens": cnt}

    def _layer_spec(self, fs) -> dict:
        s = super()._layer_spec(fs)
        s["mlp"] = {
            "router": P(None, None, None),
            "w_gate": P(None, "model", fs, None),
            "w_up": P(None, "model", fs, None),
            "w_down": P(None, "model", None, fs),
        }
        s.pop("ln1_post", None)
        s.pop("ln2_post", None)
        return s

    def param_spec(self) -> dict:
        spec = super().param_spec()
        if self.strip_tp:
            # strip_tp removes attention TP but expert parallelism stays
            # on the model axis (the experts are the point of the axis)
            fs = self._fsdp_ax()
            spec["layers"]["mlp"] = {
                "router": P(None, None, None),
                "w_gate": P(None, "model", fs, None),
                "w_up": P(None, "model", fs, None),
                "w_down": P(None, "model", None, fs),
            }
        return spec
