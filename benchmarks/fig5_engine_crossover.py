"""Fig. 5 reproduction: LocalEngine (Neo4j analogue) vs DistributedEngine
(Spark analogue) on combined connected users, sweeping graph scale and
output cardinality.

The paper's findings this must reproduce qualitatively:
  1. small/medium graphs: the local engine wins;
  2. the gap narrows as scale grows (the BSP engine's fixed per-superstep
     cost amortizes; on real multi-chip meshes it then *wins* — here both
     run on one CPU device so we report the trend + the planner's
     projected crossover for the production mesh);
  3. count-only output is dramatically cheaper than full-table output on
     the local engine ('<2 s vs ~10 min' in the paper).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import time_fn, csv_row
from repro.core import graph as G
from repro.core import planner as P
from repro.core.engines import LocalEngine, DistributedEngine
from repro.data import synthetic as S


def run(out=print):
    rows = []
    for n_vertices in [2_000, 20_000, 100_000]:
        src, dst = S.user_follow_graph(n_vertices, 4.0, seed=1)
        g = G.build_coo(src, dst, n_vertices, symmetrize=True)

        local = LocalEngine(g)
        t_local, r_local = time_fn(
            lambda: local.connected_components().value)
        dist = DistributedEngine(g, n_data=4)
        t_dist, r_dist = time_fn(
            lambda: dist.connected_components().value)
        assert (np.asarray(r_local) == np.asarray(r_dist)).all()

        # count-only on the local engine (the paper's 2s-vs-10min query)
        t_count, _ = time_fn(lambda: local.num_components().value)

        # host materialization of the full table (the output cost the
        # planner charges for table-returning queries)
        t_table, _ = time_fn(
            lambda: np.asarray(local.connected_components().value))

        stats = P.GraphStats.of(g)
        plan = P.choose_engine(
            stats, P.spec_for("connected_components", stats), 256)
        rows.append((n_vertices, t_local, t_dist, t_count, t_table,
                     plan.engine))
        out(csv_row(f"fig5/cc_local_v{n_vertices}", t_local,
                    f"ncomp_table"))
        out(csv_row(f"fig5/cc_bsp_v{n_vertices}", t_dist,
                    f"ratio={t_dist/t_local:.2f}x"))
        out(csv_row(f"fig5/cc_count_v{n_vertices}", t_count,
                    f"count_vs_table={t_table/max(t_count,1e-9):.2f}x"))

    # planner projection across the full Fig. 5 range
    flips = []
    for v in [10**4, 10**5, 10**6, 10**7, 10**8, 10**9]:
        stats = P.GraphStats(v, v * 5, v * 5 * 12)
        plan = P.choose_engine(
            stats, P.spec_for("connected_components", stats), 256)
        flips.append((v, plan.engine))
    cross = next((v for v, e in flips if e == "distributed"), None)
    out(csv_row("fig5/planner_crossover_vertices", 0.0,
                f"crossover_at_V={cross}"))
    return rows


if __name__ == "__main__":
    run()
