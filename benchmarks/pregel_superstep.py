"""Superstep-strategy benchmark: dense vs fused vs frontier, per
algorithm and scale, written to ``BENCH_pregel_superstep.json``.

Three measurements, mirroring the three execution strategies the
registry exposes for monoid vertex programs:

  * **variant sweep** — every algorithm that registered superstep
    variants, timed end-to-end through ``Engine.run`` at two scales,
    results asserted bit-identical across strategies (the variants
    contract);
  * **layout microbench** — one superstep of the dense path's
    gather -> [E] messages -> segment-combine against the fused
    ELL gather+combine (no [E] materialization), the XLA-level win the
    fused kernel packages.  This is where "fused beats dense" is
    cleanest: it isolates the memory-layout change from iteration-count
    noise;
  * **frontier scaling** — BFS on a bounded-out-degree graph at 1e6+
    vertices; per-superstep *edge work* computed analytically from the
    converged distance labels (frontier at round r == vertices reached
    at round r-1), reported as a fraction of the dense path's
    rounds x E.

Wall-clock numbers come from a CPU host.  Pallas timings use
interpret mode (a Python-loop emulator) and are labeled as such — they
validate correctness, not TPU performance; the jnp reference paths are
honest CPU timings of the same memory-access patterns.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn, csv_row
from repro.core import graph as G
from repro.core import registry as R
from repro.core.algorithms import traversal
from repro.core.engines import LocalEngine
from repro.data import synthetic as S
from repro.kernels.pregel_superstep import fused_superstep_ref

INTERPRET_NOTE = ("interpret (CPU fallback — not indicative of TPU "
                  "perf)")


def _build(n_vertices: int, symmetric: bool) -> G.GraphCOO:
    src, dst = S.user_follow_graph(n_vertices, 4.0, seed=1)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], n_vertices,
                       symmetrize=symmetric)


def _bits(x):
    return np.asarray(x).tobytes()


# ----------------------------------------------------------- variant sweep

def variant_sweep(out=print):
    recs = []
    for n_vertices in [2_000, 20_000]:
        graphs = {sym: _build(n_vertices, sym) for sym in (False, True)}
        engines = {sym: LocalEngine(g) for sym, g in graphs.items()}
        for name, defn in R.items():
            variants = sorted(defn.variants or ())
            if "frontier" not in variants:
                continue
            sym = defn.requires_symmetric
            eng, g = engines[sym], graphs[sym]
            params = dict(defn.example_params or {})
            timed, baseline = {}, None
            for var in variants:
                t, r = time_fn(lambda: eng.run(defn, params,
                                               variant=var).value,
                               warmup=1, iters=1)
                timed[var] = t
                if baseline is None:
                    baseline = r
                else:
                    assert _bits(r) == _bits(baseline), (name, var)
                out(csv_row(f"superstep/{name}_{var}_v{n_vertices}", t))
            recs.append({
                "algorithm": name, "n_vertices": n_vertices,
                "n_edges": int(g.n_edges),
                "variants": {v: {"wall_s": timed[v]} for v in timed},
                "bit_identical": True,
                "speedup_frontier_vs_dense":
                    timed["dense"] / timed["frontier"],
            })
    return recs


# ------------------------------------------------------ layout microbench

def layout_microbench(out=print):
    """One superstep, three layouts, honest CPU wall time.

    dense:   gather src state -> [E] messages -> segment-min over [E]
    fused:   ELL gather + masked combine, no [E] tensor (jnp reference
             of the Pallas kernel's access pattern)
    pallas:  same kernel under interpret mode — correctness ping only.
    """
    recs = []
    rng = np.random.default_rng(0)
    for n, deg in [(50_000, 16), (200_000, 8)]:
        # bounded out-degree keeps the ELL width ~Poisson(deg); a
        # power-law graph here would pad every row to the hub degree
        src = np.repeat(np.arange(n, dtype=np.int64), deg)
        dst = rng.integers(0, n, src.shape[0])
        keep = src != dst
        coo = G.build_coo(src[keep], dst[keep], n)
        e = coo.n_edges
        ell = G.build_ell(np.asarray(coo.src)[:e], np.asarray(coo.dst)[:e],
                          n, int(np.bincount(np.asarray(coo.dst)[:e],
                                             minlength=n).max()))
        x = jnp.asarray(rng.standard_normal(n + 1), jnp.float32)

        @jax.jit
        def dense_step(x):
            msgs = x[jnp.clip(coo.src, 0, n)] + coo.w
            return jax.ops.segment_min(msgs, coo.dst,
                                       num_segments=n + 1)[:n]

        @jax.jit
        def fused_step(x):
            return fused_superstep_ref(
                ell.nbr, ell.mask, ell.w, x, message=lambda s, w: s + w,
                op="min", identity=float("inf"))

        t_dense, y_dense = time_fn(dense_step, x)
        t_fused, y_fused = time_fn(fused_step, x)
        # dense segment_min leaves empty segments at +inf max-dtype fill
        # identical to the fused identity fill; compare where defined
        np.testing.assert_array_equal(np.asarray(y_fused),
                                      np.asarray(y_dense))
        out(csv_row(f"superstep/layout_dense_v{n}", t_dense, f"E={e}"))
        out(csv_row(f"superstep/layout_fused_v{n}", t_fused,
                    f"speedup={t_dense / t_fused:.2f}x"))
        recs.append({
            "n_vertices": n, "n_edges": int(e),
            "kmax": int(ell.nbr.shape[1]),
            "dense_segment_combine_s": t_dense,
            "fused_ell_combine_s": t_fused,
            "fused_speedup": t_dense / t_fused,
            "fused_beats_dense": bool(t_fused < t_dense),
        })
    # interpret-mode correctness ping on a tiny shape (labeled)
    nbr = jnp.asarray(rng.integers(0, 256, (256, 128)), jnp.int32)
    mask = jnp.asarray(rng.random((256, 128)) < 0.5)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    xx = jnp.asarray(rng.standard_normal(256), jnp.float32)
    from repro.kernels.pregel_superstep import fused_superstep
    got = fused_superstep(nbr, mask, w, xx, message=lambda s, w_: s + w_,
                          op="min", identity=float("inf"), use_pallas=True)
    want = fused_superstep_ref(nbr, mask, w, xx,
                               message=lambda s, w_: s + w_,
                               op="min", identity=float("inf"))
    err = float(jnp.max(jnp.abs(got - want)))
    out(csv_row("superstep/pallas_interpret_maxerr", 0.0,
                f"maxerr={err:.2e}"))
    recs.append({"pallas_mode": INTERPRET_NOTE, "max_abs_err": err})
    return recs


# ------------------------------------------------------- frontier scaling

def frontier_scaling(n_vertices=1_000_000, out_degree=8, out=print):
    """BFS at 1e6 V on a bounded-out-degree graph.

    The frontier variant touches only edges leaving vertices whose
    distance changed last round; with converged labels in hand the
    per-round frontier (and its out-edge count) is exact analytics, no
    timing noise.  Wall clocks for dense vs frontier ride along.
    """
    rng = np.random.default_rng(42)
    src = np.repeat(np.arange(n_vertices, dtype=np.int64), out_degree)
    dst = rng.integers(0, n_vertices, src.shape[0])
    keep = src != dst
    g = G.build_coo(src[keep], dst[keep], n_vertices)
    eng = LocalEngine(g)
    spec = traversal._BFS_SPEC
    init = jnp.full((eng.sharded.n_pad,), jnp.inf,
                    jnp.float32).at[0].set(0.0)
    max_iters = 64
    t_dense, (d_dense, it_dense) = time_fn(
        lambda: eng.run_superstep(spec, init, max_iters, variant="dense"))
    t_front, (d_front, it_front) = time_fn(
        lambda: eng.run_superstep(spec, init, max_iters,
                                  variant="frontier"))
    assert _bits(d_dense[:n_vertices]) == _bits(d_front[:n_vertices])
    assert int(it_dense) == int(it_front)
    iters = int(it_dense)

    dist = np.asarray(d_dense[:n_vertices])
    out_deg = np.bincount(np.asarray(g.src)[: g.n_edges],
                          minlength=n_vertices)
    finite = np.isfinite(dist)
    rounds = dist[finite].astype(np.int64)
    # frontier at round r == vertices first reached at round r-1 (the
    # sources at round 0); its message work is their out-edge total
    frontier_sizes = np.bincount(rounds, minlength=iters)
    frontier_edges = np.bincount(rounds, weights=out_deg[finite],
                                 minlength=iters)
    dense_edges = float(g.n_edges) * iters
    touched = float(frontier_edges[:iters].sum())
    out(csv_row(f"superstep/frontier_bfs_v{n_vertices}", t_front,
                f"edge_work={touched / dense_edges:.3f}x_dense"))
    out(csv_row(f"superstep/dense_bfs_v{n_vertices}", t_dense,
                f"iters={iters}"))
    return {
        "algorithm": "bfs", "n_vertices": n_vertices,
        "n_edges": int(g.n_edges), "iterations": iters,
        "bit_identical": True,
        "dense": {"wall_s": t_dense,
                  "edges_touched": dense_edges},
        "frontier": {"wall_s": t_front,
                     "edges_touched": touched,
                     "per_round_frontier":
                         frontier_sizes[:iters].astype(int).tolist(),
                     "per_round_edges":
                         frontier_edges[:iters].astype(int).tolist()},
        "frontier_edge_work_fraction": touched / dense_edges,
    }


def run(out=print):
    """benchmarks.run entry point — the cheap subset (no 1e6-V build)."""
    variant_sweep(out=out)
    layout_microbench(out=out)
    return []


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pregel_superstep.json")
    ap.add_argument("--scale", type=int, default=1_000_000,
                    help="vertex count for the frontier-scaling BFS")
    args = ap.parse_args(argv)
    report = {
        "benchmark": "pregel_superstep",
        "host": {
            "platform": jax.devices()[0].platform,
            "timing_note": (
                "jnp-reference wall clocks on a CPU host; Pallas rows "
                "are " + INTERPRET_NOTE),
        },
        "variant_sweep": variant_sweep(),
        "layout_microbench": layout_microbench(),
        "frontier_scaling": frontier_scaling(n_vertices=args.scale),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
