"""Closed-form per-chip cost model for every (arch x shape x mesh) cell.

Why this exists: XLA's ``cost_analysis()`` counts a while-loop body ONCE,
not times its trip count — under scan-over-layers (and chunked attention
/ vocab-chunked CE / recurrent scans) it underestimates FLOPs by >10x.
The dry-run still uses the compiled artifact for what it is authoritative
about (peak memory per device, the collective *schedule*, proof of
partitionability); the quantitative roofline terms come from the formulas
here, which are exact for matmul FLOPs and first-order for bytes.

Conventions:
* All returns are PER CHIP PER STEP.
* ``flops_hlo_equiv`` counts what the lowered program executes
  (full S^2 attention pairs — masked-but-computed); ``flops_ideal``
  counts the skippable-block minimum (causal 1/2, windows) that a
  block-sparse kernel (our Pallas flash) achieves — the gap between the
  two is a §Perf lever, not noise.
* Train multiplies matmul FLOPs by 3 (fwd + dgrad + wgrad) and adds a
  remat recompute factor on activation bytes.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeSpec

BF16 = 2
F32 = 4


def ring(n: int) -> float:
    return (n - 1) / n if n > 1 else 0.0


@dataclasses.dataclass
class CellCost:
    flops_hlo_equiv: float      # per chip
    flops_ideal: float          # per chip (block-sparse attention)
    hbm_bytes: float            # per chip
    coll_link_bytes: float      # per chip (ring-weighted)
    breakdown: dict

    def terms(self, peak_flops=197e12, hbm_bw=819e9, link_bw=50e9):
        return {
            "compute_s": self.flops_hlo_equiv / peak_flops,
            "compute_ideal_s": self.flops_ideal / peak_flops,
            "memory_s": self.hbm_bytes / hbm_bw,
            "collective_s": self.coll_link_bytes / link_bw,
        }


def _attn_seq_eff(cfg: ModelConfig, S: int) -> tuple[float, float]:
    """(mean kv-length full-compute, mean kv-length ideal) per query,
    averaged over layers (local/global mixes)."""
    L = cfg.n_layers
    if cfg.window and cfg.local_global_period:
        n_local = (L + cfg.local_global_period - 1) // cfg.local_global_period
        n_global = L - n_local
    elif cfg.window:
        n_global = len(cfg.global_layers)
        n_local = L - n_global
    else:
        n_local, n_global = 0, L
    w = min(cfg.window, S) if cfg.window else S
    # full-compute: the chunked impl computes every pair then masks
    full = S
    ideal_local = min(w, S / 2)       # causal+window block-skipped
    ideal_global = S / 2
    ideal = (n_local * ideal_local + n_global * ideal_global) / max(L, 1)
    return full, ideal


def cost_cell(cfg: ModelConfig, shape: ShapeSpec, mesh_sizes: dict,
              dp_used: tuple = ("data",), microbatches: int = 1,
              attn_chunk: int = 1024) -> CellCost:
    M = mesh_sizes.get("model", 1)
    Ddp = 1
    for ax in dp_used:
        Ddp *= mesh_sizes.get(ax, 1)
    n_chips = 1
    for v in mesh_sizes.values():
        n_chips *= v

    train = shape.kind == "train"
    mm = 3.0 if train else 1.0          # matmul fwd+dgrad+wgrad
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    S_q = 1 if decode else S            # query positions this step
    T = B * S_q                          # tokens computed this step
    T_loc = T / Ddp
    B_loc = B / Ddp
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cache_len = S if decode else 0

    fl = {}     # global flops by component (hlo-equivalent)
    fl_i = {}   # ideal
    by = {}     # per-chip bytes
    co = {}     # per-chip ring-weighted collective bytes

    # ---------------- projections / mlp / vocab (all matmuls) ----------
    proj = mm * 2 * T * D * Dh * (2 * Hq + 2 * Hkv) * L
    fl["proj"] = fl_i["proj"] = proj

    if decode:
        kv_len_full = kv_len_ideal = cache_len
    else:
        kv_len_full, kv_len_ideal = _attn_seq_eff(cfg, S)
    attn = mm * 4 * T * Hq * Dh * kv_len_full * L
    attn_i = mm * 4 * T * Hq * Dh * kv_len_ideal * L
    if cfg.family in ("ssm",):
        attn = attn_i = 0.0
    fl["attn"], fl_i["attn"] = attn, attn_i

    if cfg.family == "moe":
        slots = cfg.top_k * cfg.capacity_factor
        experts = mm * 6 * T * slots * D * F * L
        # blocked one-hot dispatch: per token 4*(E*C_b)*D with
        # E*C_b = slots * gb  (see models/moe.py)
        gb = min(1024, T)
        dispatch = mm * 4 * T * slots * gb * D * L
        router = mm * 2 * T * D * cfg.n_experts * L
        fl["mlp"] = fl_i["mlp"] = experts + router
        fl["moe_dispatch"] = fl_i["moe_dispatch"] = dispatch
    elif cfg.family == "ssm":
        di = cfg.ssm_expand * D
        dh_i = di // max(Hq, 1)
        mlstm = mm * (2 * T * D * 2 * di + 3 * 2 * T * di * di
                      + 2 * T * di * D) * (L / 2)
        mlstm_rec = 10 * T * di * dh_i * (L / 2) * (3 if train else 1)
        slstm = mm * (2 * T * D * 4 * di + 2 * T * di * D) * (L / 2)
        slstm_rec = 30 * T * di * (L / 2) * (3 if train else 1)
        fl["mlp"] = fl_i["mlp"] = mlstm + slstm
        fl["ssm"] = fl_i["ssm"] = mlstm_rec + slstm_rec
    else:
        mlp = mm * 6 * T * D * F * L
        fl["mlp"] = fl_i["mlp"] = mlp
        if cfg.family == "hybrid":
            di = cfg.ssm_expand * D
            n = cfg.ssm_state
            r = max(1, D // 16)
            ssm_proj = mm * (2 * T * D * 2 * di + 2 * T * di * D
                             + 2 * T * di * (2 * n + r) + 2 * T * r * di) * L
            ssm_scan = 10 * T * di * n * L * (3 if train else 1)
            fl["ssm"] = fl_i["ssm"] = ssm_proj + ssm_scan

    if cfg.family == "encdec" and not decode:
        Te = B * cfg.encoder_seq
        enc = mm * (2 * Te * D * Dh * (2 * Hq + 2 * Hkv)
                    + 4 * Te * Hq * Dh * cfg.encoder_seq
                    + 6 * Te * D * F) * cfg.n_encoder_layers
        cross = mm * (2 * T * D * D + 4 * T * D * cfg.encoder_seq
                      + 2 * Te * D * D * 2) * L
        fl["encoder"] = fl_i["encoder"] = enc
        fl["cross"] = fl_i["cross"] = cross
    elif cfg.family == "encdec" and decode:
        cross = mm * (2 * T * D * D + 4 * T * D * cfg.encoder_seq) * L
        fl["cross"] = fl_i["cross"] = cross

    fl["vocab"] = fl_i["vocab"] = mm * 2 * T * D * V

    flops_per_chip = sum(fl.values()) / n_chips
    flops_ideal_per_chip = sum(fl_i.values()) / n_chips

    # ---------------- HBM bytes per chip --------------------------------
    n_params = cfg.param_count()
    shards_opt = M * (Ddp if cfg.fsdp else 1)
    if train:
        # fwd read + bwd-recompute read + wgrad stream, per microbatch,
        # against the f32 master copy; optimizer does p/m/v read+write
        by["weights"] = 3 * F32 * (n_params / M) * microbatches
        by["optimizer"] = 28 * n_params / shards_opt
    else:
        by["weights"] = BF16 * n_params / M
    c_act = 16 * (1.7 if (train and cfg.remat) else 1.0)
    by["activations"] = c_act * T_loc * D * BF16 * L
    if not decode and cfg.family != "ssm":
        # flash/chunked kv streaming: each q block re-reads K,V
        nq = max(1, S // max(attn_chunk, 1))
        by["attn_kv"] = 2 * B_loc * nq * S * Hkv * Dh * BF16 * L \
            * (3 if train else 1)
    if decode and cfg.family != "ssm":
        # decode reads the whole (Dh-sharded) cache every step
        by["kv_cache"] = 2 * L * B_loc * cache_len * Hkv * Dh * BF16 / M
    if decode and cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * D
        n = cfg.ssm_state if cfg.family == "hybrid" else di // 4
        by["ssm_state"] = 2 * L * B_loc * di * max(n, 1) * F32 / M
    fl_bytes = sum(by.values())

    # ---------------- collective link-bytes per chip --------------------
    act_bytes = B_loc * S_q * D * BF16
    n_ar = (4 if train else 2)
    co["tp_layer"] = n_ar * act_bytes * 2 * ring(M) * L
    co["tp_vocab"] = (2 if train else 1) * act_bytes * 2 * ring(M)
    if train:
        if cfg.fsdp:
            co["fsdp"] = 3 * ring(Ddp) * F32 * n_params / M * microbatches
        else:
            co["dp_grads"] = 2 * ring(Ddp) * F32 * n_params / M
        if "pod" in mesh_sizes and "pod" not in dp_used:
            co["pod_grads"] = 2 * ring(mesh_sizes["pod"]) * F32 \
                * n_params / (M * Ddp)
    if cfg.family == "moe":
        # all-to-all traffic is uniformly spread across the torus, so it
        # drives all 4 ICI links of a v5e chip concurrently (ring
        # collectives are charged at 1 link — conservative)
        A2A_LINKS = 4.0
        slots = cfg.top_k * cfg.capacity_factor
        co["moe_a2a"] = (4 if train else 2) * slots * T_loc * D * BF16 \
            * ring(M) * L / A2A_LINKS

    return CellCost(
        flops_hlo_equiv=flops_per_chip,
        flops_ideal=flops_ideal_per_chip,
        hbm_bytes=fl_bytes,
        coll_link_bytes=sum(co.values()),
        breakdown={"flops": fl, "bytes": by, "coll": co},
    )
