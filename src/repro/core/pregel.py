"""BSP vertex-centric superstep engine — the Spark/GraphFrames analogue.

One Pregel superstep (Malewicz et al., the model GraphFrames ultimately
lowers to) maps onto a TPU mesh as::

    gather   : read source-vertex state along edges        (local gather /
               all_gather over the ``model`` axis when vertex-sharded)
    message  : per-edge compute                            (VPU)
    combine  : segment-reduce messages to destinations     (local)
    shuffle  : merge partial aggregates across edge shards (psum/pmin/pmax
               over the ``data`` axis — Spark's shuffle becomes one ring
               collective)
    apply    : per-vertex state update                     (VPU)

Everything is statically shaped: padded edges carry the sentinel vertex id
and are dropped at the segment-combine.  Convergence is decided *inside*
the jitted loop with a global ``psum`` of per-shard change counts, so a
whole multi-superstep algorithm (PageRank, hash-to-min CC) is a single
XLA program — the property that makes the distributed engine orders of
magnitude faster than a dataflow engine that materializes every round.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from collections import OrderedDict
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.partition import ShardedCOO
from repro.utils.compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PregelSpec:
    """One vertex program.

    message : (src_state[E], w[E]) -> msg[E] or msg[E, M]; with
              ``needs_dst_state`` the signature is
              (src_state, w, dst_state) — an *edge* program that can read
              both endpoints (triangle counting intersects neighborhoods
              this way).
    combine : the message monoid.  Either a single op ('sum'|'min'|'max')
              applied to the whole message, or a tuple of ``(op, width)``
              column groups for *structured* messages: the message's last
              axis is split into contiguous groups, each combined with its
              own monoid (label propagation sends C sum-combined weight
              channels next to C min-combined label channels in one
              superstep).
    apply   : (old_state[Vl], agg, vertex_ids[Vl], gval) -> new_state
    identity: identity element of the monoid — a scalar, or a tuple of
              per-group identities matching a grouped ``combine`` (fills
              vertices with no incoming message)
    halt    : optional (old, new, valid[Vl]) -> bool array (per-shard
              "locally converged"); None runs exactly ``max_iters``.
    global_value : optional (state[Vl], ids, valid) -> scalar (or small
              array) partial; summed across vertex shards and fed to
              ``apply`` as ``gval`` (PageRank uses this for the
              dangling-mass redistribution — the one pattern a pure
              message-passing model can't express).
    global_over_agg : compute ``global_value`` over the *new* combined
              aggregate instead of the pre-superstep state — the hook a
              same-superstep normalization needs (HITS divides the fresh
              hub/authority sums by their own L2 norms inside the loop,
              making the whole algorithm one XLA program).

    Execution-strategy declarations (all optional; defaults keep the
    dense gather/segment-combine path, which remains the correctness
    oracle):

    elementwise_message : the message is pure elementwise jnp code in
              ``(src_state, w)`` and shape-polymorphic — callable on
              ``[E]`` edge vectors (dense path) and ``[V, K]`` gathered
              ELL tiles (fused kernel) alike.  Prerequisite for the
              fused and frontier variants.
    frontier_mode : how sparse-active supersteps may skip inactive
              vertices.  ``'monotone'`` (min/max combines whose apply
              folds the aggregate into state with the same monoid —
              BFS/SSSP/CC): a source unchanged since round t already
              delivered its identical message then, and the fold made
              it permanent, so omitting it is a no-op.  ``'delta'``
              (sum combines with integer-valued messages — k-core): a
              running aggregate is carried and changed sources scatter
              ``msg(new) - msg(old)``.  Both are *exact* — bit-identical
              trajectories to the dense path — under those conditions.
    frontier_init : optional ``state -> bool[V]`` activity predicate
              for the first frontier (monotone mode); default is
              ``state != identity``.  Must be a module-level callable
              (it keys jit caches).
    message_dtype : reduced-precision message channel ('bfloat16' /
              'float16').  Messages are cast to this dtype right after
              the edge program, before the combine — halving message
              traffic.  min/max monoids always tolerate this (per-
              message rounding only); sum monoids reorder inexact
              accumulation and require ``allow_inexact_sum``.
    allow_inexact_sum : explicit opt-in for ``message_dtype`` on a sum
              monoid (the result is then approximate).

    Vertex state may be 1-D ``[Vl]`` or N-D ``[Vl, ...]`` (triangle
    counting keeps a packed neighborhood bitset per vertex); padding-slot
    freezing broadcasts over the trailing axes.
    """

    message: Callable[..., Array]
    combine: object
    apply: Callable[[Array, Array, Array, Array], Array]
    identity: object
    halt: Optional[Callable[[Array, Array, Array], Array]] = None
    global_value: Optional[Callable[[Array, Array, Array], Array]] = None
    needs_dst_state: bool = False
    global_over_agg: bool = False
    elementwise_message: bool = False
    frontier_mode: Optional[str] = None
    frontier_init: Optional[Callable[[Array], Array]] = None
    message_dtype: Optional[str] = None
    allow_inexact_sum: bool = False


@dataclasses.dataclass(frozen=True)
class SuperstepVariant:
    """A planner-visible execution strategy for a PregelSpec runner.

    Registered in an AlgorithmDef's ``variants`` mapping next to the
    dense spec (the triangle_count bitset-vs-intersect idiom), so the
    cost model picks dense vs fused vs frontier per graph.  Engines
    dispatch it through ``Engine.run_superstep`` — which silently falls
    back to the dense path when the strategy's preconditions don't hold
    on that engine, keeping the variants contract (identical results on
    every variant) unconditional.
    """

    spec: PregelSpec
    mode: str  # 'fused' | 'frontier'


def check_precision(spec: PregelSpec) -> None:
    """Validate the reduced-precision declaration of a spec.

    min/max monoids are always safe (rounding is per-message; the
    combine itself is exact in any order).  Inexact sums are only
    allowed behind the explicit opt-in, and structured (grouped-monoid)
    messages can't take a single channel dtype at all.
    """
    if spec.message_dtype is None:
        return
    if isinstance(spec.combine, tuple):
        raise ValueError(
            "message_dtype: structured (grouped-monoid) messages do not "
            "support a reduced-precision channel")
    if spec.combine == "sum" and not spec.allow_inexact_sum:
        raise ValueError(
            "message_dtype with a 'sum' monoid accumulates rounding "
            "error; opt in explicitly with allow_inexact_sum=True")


def reduced_precision(spec: PregelSpec, dtype,
                      allow_inexact_sum: Optional[bool] = None) -> PregelSpec:
    """Derive a spec whose message channel runs in ``dtype``."""
    s = dataclasses.replace(
        spec, message_dtype=jnp.dtype(dtype).name,
        allow_inexact_sum=(spec.allow_inexact_sum
                           if allow_inexact_sum is None
                           else allow_inexact_sum))
    check_precision(s)
    return s


def converged_halt(old, new, valid):
    """The standard fixpoint predicate: no valid vertex changed state.
    Shared by every to-convergence vertex program (CC, traversal, LPA,
    k-core peeling)."""
    return jnp.logical_not(jnp.any(jnp.logical_and(valid, new != old)))


@functools.lru_cache(maxsize=64)
def batched_spec(spec: PregelSpec) -> PregelSpec:
    """Lift a scalar vertex program onto a trailing batch axis.

    The returned spec runs K independent instances of ``spec`` as *one*
    program over state ``[Vl, K]`` — the fused-batch substrate of the
    service layer (K BFS frontiers with different sources share every
    gather, segment-combine and collective of every superstep).  Each
    column's arithmetic is the unbatched program's, element for element
    (vmap only widens the ops), and the monoid combines are exact
    per-column, so column ``k`` of the fused result is bit-identical to
    running instance ``k`` alone.  The fused ``halt`` is the AND over
    columns; converged columns sit at their fixpoint (apply is a no-op
    there) while stragglers finish.

    Memoized (bounded) so repeated fusions of the same program hit the
    jit cache.  Structured (grouped-monoid) messages split columns
    positionally and cannot carry a trailing batch axis — rejected up
    front.
    """
    if isinstance(spec.combine, tuple):
        raise ValueError(
            "batched_spec: structured (grouped-monoid) messages cannot "
            "be lifted onto a batch axis")
    msg_axes = (-1, None, -1) if spec.needs_dst_state else (-1, None)
    message = jax.vmap(spec.message, in_axes=msg_axes, out_axes=-1)
    # with a global_value the per-column scalars arrive as a trailing-K
    # vector and each column's apply reads its own entry
    gval_axis = None if spec.global_value is None else -1
    apply_ = jax.vmap(spec.apply, in_axes=(-1, -1, None, gval_axis),
                      out_axes=-1)

    halt = None
    if spec.halt is not None:
        per_col = jax.vmap(spec.halt, in_axes=(-1, -1, None))

        def halt(old, new, valid):
            return jnp.all(per_col(old, new, valid))

    gval = None
    if spec.global_value is not None:
        per_col_g = jax.vmap(spec.global_value, in_axes=(-1, None, None),
                             out_axes=-1)

        def gval(state, ids, valid):
            return per_col_g(state, ids, valid)

    # activity is per-vertex: a vertex is active if ANY column is (the
    # frontier loop reduces trailing axes with `any` after this)
    frontier_init = None
    if spec.frontier_init is not None:
        frontier_init = jax.vmap(spec.frontier_init, in_axes=-1,
                                 out_axes=-1)

    return PregelSpec(
        message=message, combine=spec.combine, apply=apply_,
        identity=spec.identity, halt=halt, global_value=gval,
        needs_dst_state=spec.needs_dst_state,
        global_over_agg=spec.global_over_agg,
        elementwise_message=spec.elementwise_message,
        frontier_mode=spec.frontier_mode,
        frontier_init=frontier_init,
        message_dtype=spec.message_dtype,
        allow_inexact_sum=spec.allow_inexact_sum)


_SEG = {
    "sum": jax.ops.segment_sum,
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
}


def _psum_like(x: Array, op: str, axis) -> Array:
    if op == "sum":
        return lax.psum(x, axis)
    if op == "min":
        return lax.pmin(x, axis)
    if op == "max":
        return lax.pmax(x, axis)
    raise ValueError(op)


def _local_combine(msgs, dst, n_vertices, v_local, start, op, identity):
    """Segment-combine messages into the locally-owned vertex range.

    Grouped ``op`` splits the message's last axis into ``(op, width)``
    column groups, each combined under its own monoid.
    """
    if isinstance(op, tuple):
        parts, c0 = [], 0
        for (o, width), ident in zip(op, identity):
            parts.append(_local_combine(msgs[..., c0:c0 + width], dst,
                                        n_vertices, v_local, start, o, ident))
            c0 += width
        return jnp.concatenate(parts, axis=-1)
    local_dst = jnp.where(dst >= n_vertices, v_local, dst - start)
    local_dst = jnp.clip(local_dst, 0, v_local)
    agg = _SEG[op](msgs, local_dst, num_segments=v_local + 1)[:v_local]
    if op in ("min", "max"):
        # segment_min/max give +/-inf (or int extremes) for empty segments;
        # normalize to the declared identity.
        no_msg = _SEG["sum"](jnp.ones_like(msgs, dtype=jnp.int32),
                             local_dst, num_segments=v_local + 1)[:v_local] == 0
        agg = jnp.where(no_msg, jnp.asarray(identity, agg.dtype), agg)
    return agg


def _shard_combine(agg, op, axis):
    """Cross-shard merge of partial aggregates (grouped ops column-wise)."""
    if isinstance(op, tuple):
        parts, c0 = [], 0
        for o, width in op:
            parts.append(_psum_like(agg[..., c0:c0 + width], o, axis))
            c0 += width
        return jnp.concatenate(parts, axis=-1)
    return _psum_like(agg, op, axis)


# Bounded LRU of jitted superstep programs.  Keys are *structural*:
# meshes enter as (axis names/types, shape, device ids), never as the
# Mesh object — unbounded Mesh-keyed entries used to pin device state
# for the life of the process.  A cached *mesh-path* program still
# closes over the mesh it was built with (shard_map needs one), so a
# dead Mesh can linger until its entry ages out of the LRU; the bound
# is what turns that from a leak into a window.
_JIT_CACHE: OrderedDict = OrderedDict()
JIT_CACHE_MAX = 64
# The service runtime executes on worker threads (one per engine); the
# LRU's get/move_to_end/popitem sequences are not atomic under free
# threading, so guard them.  Building a missed program happens outside
# the lock — two threads may race to compile the same key and the loser
# simply overwrites with an equivalent entry.
_JIT_CACHE_LOCK = threading.Lock()


def _mesh_cache_key(mesh):
    if mesh is None:
        return None
    # axis_types distinguishes semantically different meshes over the
    # same devices (Auto vs Explicit axes) on jax versions that have it
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat),
            str(getattr(mesh, "axis_types", None)))


def _jit_cache_get(key):
    """Returns (cached fn or None, hashable key or None)."""
    with _JIT_CACHE_LOCK:
        try:
            fn = _JIT_CACHE.get(key)
        except TypeError:          # unhashable spec (closure consts)
            return None, None
        if fn is not None:
            _JIT_CACHE.move_to_end(key)
        return fn, key


def _jit_cache_put(key, fn) -> None:
    if key is None:
        return
    with _JIT_CACHE_LOCK:
        _JIT_CACHE[key] = fn
        while len(_JIT_CACHE) > JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)


def run_pregel(
    spec: PregelSpec,
    sg: ShardedCOO,
    init_state: Array,
    max_iters: int,
    mesh: Optional[Mesh] = None,
    axis_data: str = "data",
    axis_model: str = "model",
):
    """Run the vertex program to convergence (or ``max_iters``).

    Returns ``(final_state [V or n_model*v_local], iterations_run)``.
    With ``mesh=None`` runs the same program on one device (the engine the
    planner picks for medium graphs still shares this code path).
    """
    check_precision(spec)
    V = sg.n_vertices
    v_local = sg.v_local
    sharded = sg.vertex_layout == "sharded"

    def body(src, dst, w, state):
        """Executes per-device under shard_map (or directly, single device)."""
        dist = mesh is not None
        if sharded:
            m_idx = lax.axis_index(axis_model) if dist else 0
            start = m_idx * v_local
        else:
            start = 0
        ids = start + jnp.arange(v_local, dtype=jnp.int32)
        valid = ids < V

        def one_iter(state):
            if sharded and dist:
                full = lax.all_gather(state, axis_model, tiled=True)
            else:
                full = state
            src_state = full[jnp.clip(src, 0, full.shape[0] - 1)]
            if spec.needs_dst_state:
                dst_state = full[jnp.clip(dst, 0, full.shape[0] - 1)]
                msgs = spec.message(src_state, w, dst_state)
            else:
                msgs = spec.message(src_state, w)
            if spec.message_dtype is not None:
                msgs = msgs.astype(spec.message_dtype)
            agg = _local_combine(msgs, dst, V, v_local, start,
                                 spec.combine, spec.identity)
            if dist:
                agg = _shard_combine(agg, spec.combine, axis_data)
            if spec.global_value is not None:
                g_src = agg if spec.global_over_agg else state
                gval = spec.global_value(g_src, ids, valid)
                if sharded and dist:
                    gval = lax.psum(gval, axis_model)
            else:
                gval = jnp.float32(0.0)
            new = spec.apply(state, agg, ids, gval)
            vmask = valid.reshape(valid.shape + (1,) * (new.ndim - 1))
            new = jnp.where(vmask, new, state)  # freeze padding slots
            return new

        if spec.halt is None:
            def fori(_, s):
                return one_iter(s)
            final = lax.fori_loop(0, max_iters, fori, state)
            return final, jnp.int32(max_iters)

        def cond(carry):
            _, i, done = carry
            return jnp.logical_and(i < max_iters, jnp.logical_not(done))

        def step(carry):
            s, i, _ = carry
            new = one_iter(s)
            conv_local = spec.halt(s, new, valid)
            not_conv = jnp.logical_not(conv_local).astype(jnp.int32)
            if dist:
                axes = (axis_data, axis_model) if sharded else (axis_data,)
                not_conv = lax.psum(not_conv, axes)
            return new, i + 1, not_conv == 0

        final, iters, _ = lax.while_loop(
            cond, step, (state, jnp.int32(0), jnp.array(False)))
        return final, iters

    # jit-cache: repeated queries on the same engine must not re-trace
    # (the 'consistent query performance' property of the local engine)
    key = (spec, max_iters, _mesh_cache_key(mesh), axis_data, axis_model,
           V, v_local, sg.n_data, sg.n_model, sg.e_shard,
           init_state.shape, str(init_state.dtype))
    fn, key = _jit_cache_get(key)
    if mesh is None:
        # Single-device: shards concatenated — treat as one big shard.
        # (2-D vertex-sharded layouts only make sense on a mesh.)
        assert not sharded, "vertex-sharded layout requires a mesh"
        if fn is None:
            fn = jax.jit(body)
            _jit_cache_put(key, fn)
        return fn(sg.src, sg.dst, sg.w, init_state)

    if fn is None:
        edge_spec = P((axis_data, axis_model)) if sharded else P(axis_data)
        state_spec = P(axis_model) if sharded else P()
        fn = jax.jit(shard_map(
            body,
            mesh=mesh,
            in_specs=(edge_spec, edge_spec, edge_spec, state_spec),
            out_specs=(state_spec, P()),
            check_vma=False,
        ))
        _jit_cache_put(key, fn)
    with mesh:
        return fn(sg.src, sg.dst, sg.w, init_state)


def _check_superstep_spec(spec: PregelSpec, what: str) -> None:
    check_precision(spec)
    if not spec.elementwise_message:
        raise ValueError(f"{what}: spec does not declare "
                         "elementwise_message")
    if spec.needs_dst_state:
        raise ValueError(f"{what}: two-endpoint edge programs are "
                         "dense-path only")
    if isinstance(spec.combine, tuple):
        raise ValueError(f"{what}: structured (grouped-monoid) messages "
                         "are dense-path only")


def run_pregel_fused(
    spec: PregelSpec,
    ell,
    init_state: Array,
    max_iters: int,
    use_pallas: bool = False,
    block_rows: int = 512,
):
    """Run the vertex program with the fused-superstep kernel.

    Same contract and return value as ``run_pregel`` on a single
    device, but each superstep is one pass over the in-neighbor ELL
    layout (``kernels/pregel_superstep``): gather src state → edge
    program → monoid combine into dst rows, with no [E] message tensor
    and no separate segment-combine launch.  Bit-identical to the dense
    path for min/max monoids and integer-valued sums (the only specs
    registered with this variant).

    ``ell`` is the uncapped ``direction='in'`` layout over the full
    graph (every edge retained; the engine builds and caches it).
    """
    from repro.kernels.pregel_superstep import ops as superstep_ops

    _check_superstep_spec(spec, "run_pregel_fused")
    V = ell.n_vertices
    if init_state.shape[0] != V:
        raise ValueError("run_pregel_fused: state must be unpadded [V]")

    def body(nbr, mask, w, state):
        ids = jnp.arange(V, dtype=jnp.int32)
        valid = ids < V        # all True; uniform halt/global signature

        def one_iter(state):
            agg = superstep_ops.fused_superstep(
                nbr, mask, w, state, message=spec.message,
                op=spec.combine, identity=spec.identity,
                message_dtype=spec.message_dtype, use_pallas=use_pallas,
                block_rows=block_rows)
            if spec.global_value is not None:
                g_src = agg if spec.global_over_agg else state
                gval = spec.global_value(g_src, ids, valid)
            else:
                gval = jnp.float32(0.0)
            return spec.apply(state, agg, ids, gval)

        if spec.halt is None:
            def fori(_, s):
                return one_iter(s)
            final = lax.fori_loop(0, max_iters, fori, state)
            return final, jnp.int32(max_iters)

        def cond(carry):
            _, i, done = carry
            return jnp.logical_and(i < max_iters, jnp.logical_not(done))

        def step(carry):
            s, i, _ = carry
            new = one_iter(s)
            return new, i + 1, spec.halt(s, new, valid)

        final, iters, _ = lax.while_loop(
            cond, step, (state, jnp.int32(0), jnp.array(False)))
        return final, iters

    key = ("fused", spec, max_iters, V, ell.nbr.shape, use_pallas,
           block_rows, init_state.shape, str(init_state.dtype))
    fn, key = _jit_cache_get(key)
    if fn is None:
        fn = jax.jit(body)
        _jit_cache_put(key, fn)
    return fn(ell.nbr, ell.mask, ell.w, init_state)


def run_pregel_frontier(
    spec: PregelSpec,
    ell,
    init_state: Array,
    max_iters: int,
    block_rows: int = 1024,
    init_active: Optional[Array] = None,
    profile: bool = False,
):
    """Run the vertex program with frontier compression.

    ``ell`` is the uncapped ``direction='out'`` layout: row ``u`` lists
    the destinations of u's out-edges, so scanning a block of frontier
    rows touches exactly the edges incident to active vertices.  A
    packed active-vertex list (static capacity, dynamic count) rides
    the ``lax.while_loop`` carry; each superstep runs an inner
    ``fori_loop`` whose trip count is ``ceil(count / block_rows)`` —
    per-superstep gather/scatter work is proportional to the *actual*
    frontier, not V.

    Exactness (the reason results are bit-identical to dense):

    * ``'monotone'`` — the aggregate is rebuilt each round from active
      sources only and folded into state by apply's own min/max.  A
      source unchanged since round t delivered the same message at
      round t and the fold made it permanent; re-delivering it is a
      no-op.  min/max are exact in any order, so trajectories (and
      therefore halt rounds) match dense exactly.
    * ``'delta'`` — the full sum aggregate is carried across rounds;
      round 1 scatters every message, later rounds scatter
      ``msg(new) - msg(old)`` for changed sources.  Exact when messages
      are integer-valued in their dtype (k-core's 0/1 aliveness).

    The apply/halt/global_value hooks run densely over the full state,
    so padding-free [V] semantics, iteration counts, and gval match the
    dense path element for element.

    ``init_active`` (monotone mode only) overrides the first frontier
    with an explicit ``bool [V]`` mask — the incremental-maintenance
    seam: a warm ``init_state`` taken from a previous fixpoint plus an
    ``init_active`` of the delta's touched vertices runs only the
    repair wavefront.  Exact under the same monotone invariant, because
    an old-fixpoint state already reflects every untouched source's
    message (the fold made it permanent last snapshot).  Ignored in
    delta mode, where round 1 must scatter the full sum regardless.

    ``profile=True`` additionally returns a ``[max_iters] int32`` array
    of per-round frontier occupancy (the packed count each executed
    superstep scattered; untaken rounds stay 0) as a third output —
    the observability counters.  The occupancy rides the while-loop
    carry, so the flag is part of the jit key: the untraced program is
    byte-for-byte the old one (zero cost when off), and the counts are
    a pure *recording* of values the loop already computes, so state
    trajectories and halt rounds are unchanged.
    """
    _check_superstep_spec(spec, "run_pregel_frontier")
    mode = spec.frontier_mode
    if mode not in ("monotone", "delta"):
        raise ValueError(f"run_pregel_frontier: spec declares no "
                         f"frontier_mode (got {mode!r})")
    if mode == "monotone" and spec.combine not in ("min", "max"):
        raise ValueError("frontier_mode='monotone' requires a min/max "
                         "combine")
    if mode == "delta" and spec.combine != "sum":
        raise ValueError("frontier_mode='delta' requires a 'sum' combine")
    V = ell.n_vertices
    K = ell.nbr.shape[1]
    if init_state.shape[0] != V:
        raise ValueError("run_pregel_frontier: state must be unpadded [V]")
    B = min(block_rows, max(V, 1))
    F = ((V + B - 1) // B) * B          # packed-frontier capacity
    trailing = init_state.shape[1:]
    delta = mode == "delta"
    seeded = init_active is not None and not delta

    def body(nbr, msk, w, state, *extra):
        ids = jnp.arange(V, dtype=jnp.int32)
        valid = ids < V
        probe = jax.eval_shape(
            spec.message,
            jax.ShapeDtypeStruct((1, 1) + trailing, state.dtype),
            jax.ShapeDtypeStruct((1, 1), w.dtype))
        agg_dtype = (jnp.dtype(spec.message_dtype)
                     if spec.message_dtype is not None else probe.dtype)
        agg_trailing = probe.shape[2:]
        fill = jnp.asarray(0 if delta else spec.identity, agg_dtype)
        scatter = {"sum": lambda a, i, v: a.at[i].add(v),
                   "min": lambda a, i, v: a.at[i].min(v),
                   "max": lambda a, i, v: a.at[i].max(v)}[spec.combine]

        def reduce_active(ch):
            while ch.ndim > 1:
                ch = jnp.any(ch, axis=-1)
            return ch

        def pack(act):
            idx = jnp.nonzero(act, size=F, fill_value=V)[0]
            return idx.astype(jnp.int32), jnp.sum(act.astype(jnp.int32))

        def scatter_frontier(acc, state, prev, frontier, count, first):
            n_blocks = (count + B - 1) // B

            def blk(j, acc):
                fb = lax.dynamic_slice(frontier, (j * B,), (B,))
                row = jnp.clip(fb, 0, V - 1)
                rn = nbr[row]                  # (B, K), sentinel V
                rm = msk[row] & (fb < V)[:, None]
                rw = w[row]
                src = jnp.broadcast_to(state[row][:, None],
                                       (B, K) + trailing)
                msgs = spec.message(src, rw)
                if delta:
                    prev_src = jnp.broadcast_to(prev[row][:, None],
                                                (B, K) + trailing)
                    pm = spec.message(prev_src, rw)
                    msgs = msgs - jnp.where(first, jnp.zeros_like(pm), pm)
                if spec.message_dtype is not None:
                    msgs = msgs.astype(spec.message_dtype)
                m = rm
                if msgs.ndim > m.ndim:
                    m = m.reshape(m.shape + (1,) * (msgs.ndim - m.ndim))
                msgs = jnp.where(m, msgs.astype(agg_dtype), fill)
                # padded/inactive slots aim at the sentinel row V
                dst_f = jnp.where(rm, rn, V).reshape(-1)
                mf = msgs.reshape((B * K,) + msgs.shape[2:])
                return scatter(acc, dst_f, mf)

            return lax.fori_loop(0, n_blocks, blk, acc)

        def one_superstep(s, agg):
            if spec.global_value is not None:
                g_src = agg if spec.global_over_agg else s
                gval = spec.global_value(g_src, ids, valid)
            else:
                gval = jnp.float32(0.0)
            return spec.apply(s, agg, ids, gval)

        def halt_of(s, new):
            if spec.halt is None:
                return jnp.array(False)
            return spec.halt(s, new, valid)

        if delta:
            act0 = jnp.ones((V,), bool)     # round 1 seeds the full sum
        elif seeded:
            act0 = extra[0]
        elif spec.frontier_init is not None:
            act0 = reduce_active(spec.frontier_init(state))
        else:
            act0 = reduce_active(
                state != jnp.asarray(spec.identity, state.dtype))
        fr0, cnt0 = pack(act0)

        def cond(carry):
            i, done = carry[-2], carry[-1]
            return jnp.logical_and(i < max_iters, jnp.logical_not(done))

        # Occupancy recording (profile mode) rides the carry *between*
        # the payload and the (i, done) tail, so ``cond``'s
        # carry[-2]/carry[-1] indexing and the payload unpack both hold
        # in either shape.
        occ0 = jnp.zeros((max_iters,), jnp.int32)

        if delta:
            acc0 = jnp.zeros((V + 1,) + agg_trailing, agg_dtype)

            def step(carry):
                if profile:
                    s, prev, acc, fr, cnt, first, occ, i, _ = carry
                else:
                    s, prev, acc, fr, cnt, first, i, _ = carry
                acc = scatter_frontier(acc, s, prev, fr, cnt, first)
                new = one_superstep(s, acc[:V])
                fr2, cnt2 = pack(reduce_active(new != s))
                tail = (i + 1, halt_of(s, new))
                if profile:
                    tail = (occ.at[i].set(cnt),) + tail
                return (new, s, acc, fr2, cnt2, jnp.array(False)) + tail

            carry0 = (state, state, acc0, fr0, cnt0, jnp.array(True))
        else:
            def step(carry):
                if profile:
                    s, fr, cnt, occ, i, _ = carry
                else:
                    s, fr, cnt, i, _ = carry
                acc0 = jnp.full((V + 1,) + agg_trailing, fill, agg_dtype)
                acc = scatter_frontier(acc0, s, None, fr, cnt,
                                       jnp.array(False))
                new = one_superstep(s, acc[:V])
                fr2, cnt2 = pack(reduce_active(new != s))
                tail = (i + 1, halt_of(s, new))
                if profile:
                    tail = (occ.at[i].set(cnt),) + tail
                return (new, fr2, cnt2) + tail

            carry0 = (state, fr0, cnt0)

        if profile:
            carry0 = carry0 + (occ0,)
        carry0 = carry0 + (jnp.int32(0), jnp.array(False))

        out = lax.while_loop(cond, step, carry0)
        if profile:
            return out[0], out[-2], out[-3]
        return out[0], out[-2]

    key = ("frontier", spec, max_iters, V, K, B,
           init_state.shape, str(init_state.dtype), seeded, profile)
    fn, key = _jit_cache_get(key)
    if fn is None:
        fn = jax.jit(body)
        _jit_cache_put(key, fn)
    args = (jnp.asarray(init_active, bool),) if seeded else ()
    return fn(ell.nbr, ell.mask, ell.w, init_state, *args)
