"""Kernel-layer microbenchmarks.

Pallas interpret-mode timings are meaningless (Python loop emulation), so
the wall-clock comparisons here are between the two *algorithmic layouts*
the platform can run on any backend:

  * ELL gather+combine (the kernel's memory-access pattern, jnp ref)
    vs COO segment_sum (the exact path) for the SpMV hot loop;
  * chunked online-softmax attention vs naive S^2 attention.

plus a correctness/roofline line for the Pallas kernels themselves
(interpret=True, tiny shapes) so `benchmarks.run` exercises them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn, csv_row
from repro.core import graph as G
from repro.data import synthetic as S
from repro.kernels.ell_combine.ops import ell_spmv, ell_spmv_ref
from repro.kernels.pregel_superstep import (fused_superstep,
                                            fused_superstep_ref)
from repro.models.layers import attn_chunked, attn_ref


def run(out=print):
    rows = []
    # --- SpMV layouts ---------------------------------------------------
    src, dst = S.user_follow_graph(50_000, 8.0, seed=5)
    n = 50_000
    coo = G.build_coo(src, dst, n)
    ell = G.build_ell(np.asarray(coo.src)[:coo.n_edges],
                      np.asarray(coo.dst)[:coo.n_edges], n, 64)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n + 1),
                    jnp.float32)

    @jax.jit
    def spmv_coo(x):
        contrib = x[jnp.clip(coo.src, 0, n - 1)] * coo.w
        return jax.ops.segment_sum(contrib, coo.dst, num_segments=n + 1)[:n]

    @jax.jit
    def spmv_ell(x):
        return ell_spmv_ref(ell.nbr, ell.mask, ell.w, x, op="sum")

    t_coo, _ = time_fn(spmv_coo, x)
    t_ell, _ = time_fn(spmv_ell, x)
    out(csv_row("kernels/spmv_coo_segsum", t_coo, f"E={coo.n_edges}"))
    out(csv_row("kernels/spmv_ell_gather", t_ell,
                f"ratio={t_coo / t_ell:.2f}x"))

    # --- attention layouts ------------------------------------------------
    rng = np.random.default_rng(1)
    b, s, hq, hkv, dh = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    pos = jnp.arange(s)
    f_ref = jax.jit(lambda q, k, v: attn_ref(q, k, v, pos, pos))
    f_chk = jax.jit(lambda q, k, v: attn_chunked(q, k, v, pos, pos,
                                                 chunk_q=256, chunk_k=256))
    t_ref, o_ref = time_fn(f_ref, q, k, v)
    t_chk, o_chk = time_fn(f_chk, q, k, v)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_chk),
                               rtol=2e-4, atol=2e-4)
    out(csv_row("kernels/attn_naive_s1024", t_ref, ""))
    out(csv_row("kernels/attn_chunked_s1024", t_chk,
                f"ratio={t_ref / t_chk:.2f}x"))

    # --- fused superstep layouts ------------------------------------------
    # dense path's superstep (gather -> [E] msgs -> segment-min) vs the
    # fused ELL gather+combine the pregel_superstep kernel packages
    @jax.jit
    def superstep_coo(x):
        msgs = x[jnp.clip(coo.src, 0, n - 1)] + coo.w
        return jax.ops.segment_min(msgs, coo.dst,
                                   num_segments=n + 1)[:n]

    @jax.jit
    def superstep_fused(x):
        return fused_superstep_ref(ell.nbr, ell.mask, ell.w, x,
                                   message=lambda s, w_: s + w_,
                                   op="min", identity=float("inf"))

    t_coo, _ = time_fn(superstep_coo, x)
    t_fus, _ = time_fn(superstep_fused, x)
    out(csv_row("kernels/superstep_coo_segmin", t_coo, f"E={coo.n_edges}"))
    out(csv_row("kernels/superstep_fused_ell", t_fus,
                f"ratio={t_coo / t_fus:.2f}x"))

    # --- Pallas kernels, interpret correctness ping -----------------------
    nbr = jnp.asarray(rng.integers(0, 256, (256, 128)), jnp.int32)
    mask = jnp.asarray(rng.random((256, 128)) < 0.5)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    xx = jnp.asarray(rng.standard_normal(256), jnp.float32)
    got = ell_spmv(nbr, mask, w, xx, op="sum")
    want = ell_spmv_ref(nbr, mask, w, xx, op="sum")
    err = float(jnp.max(jnp.abs(got - want)))
    out(csv_row("kernels/pallas_ell_interpret", 0.0, f"maxerr={err:.2e}"))
    sgot = fused_superstep(nbr, mask, w, xx,
                           message=lambda s, w_: s + w_, op="min",
                           identity=float("inf"), use_pallas=True)
    swant = fused_superstep_ref(nbr, mask, w, xx,
                                message=lambda s, w_: s + w_, op="min",
                                identity=float("inf"))
    serr = float(jnp.max(jnp.abs(sgot - swant)))
    out(csv_row("kernels/pallas_superstep_interpret", 0.0,
                f"maxerr={serr:.2e}"))
    return rows


if __name__ == "__main__":
    run()
