"""HITS hub/authority scores (Kleinberg) — the registry's one-file
extension example.

This module is the proof of the platform's extension contract: adding
it registers a new algorithm that both engines run, the planner prices
and ``GraphQuery.of("hits", ...)`` serves — with **zero edits** to
``engines.py``, ``planner.py`` or ``query.py`` (``registry.ensure_loaded``
auto-discovers it).

Formulation.  HITS iterates

    authority[v] <- sum_{(u, v) in E} hub[u]
    hub[u]       <- sum_{(u, v) in E} authority[v]

to the principal eigenvectors of ``A^T A`` / ``A A^T``.  The BSP engine
aggregates along *in*-edges only, so we run the iteration on the
**doubled role graph**: 2V vertices where vertex ``u`` is u's hub role
and vertex ``V + v`` is v's authority role, and every directed edge
``(u, v)`` becomes

    u     -> V + v      (hubs feed authorities)
    V + v -> u          (authorities feed hubs)

One superstep on this graph performs one simultaneous HITS update for
both score vectors.  Updates are unnormalized on device; the host
re-normalizes each half every ``burst`` supersteps (short enough that
float32 cannot overflow: one burst grows values by at most the role
matrix's spectral radius squared) and stops when both unit vectors are
stable to ``tol``.  Scores are returned L2-normalized.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, run_pregel

# One simultaneous (hub, authority) update: plain weighted sum along the
# doubled graph's in-edges — the whole algorithm is this spec plus
# host-side renormalization.
_HITS_SPEC = PregelSpec(
    message=lambda x, w: x * w,
    combine="sum",
    apply=lambda old, agg, ids, gval: agg,
    identity=0.0,
)

_BURST = 2    # supersteps between host renormalizations (overflow-safe)


def role_graph(g: G.GraphCOO) -> G.GraphCOO:
    """The 2V-vertex doubled graph: (u, v) -> u→(V+v) and (V+v)→u."""
    V = g.n_vertices
    src = np.asarray(g.src)[: g.n_edges]
    dst = np.asarray(g.dst)[: g.n_edges]
    w = np.asarray(g.w)[: g.n_edges]
    return G.build_coo(
        np.concatenate([src, dst + V]), np.concatenate([dst + V, src]),
        2 * V, w=np.concatenate([w, w]), dedup=False)


def _unit(x: jnp.ndarray) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x), 1e-12)


def hits(
    g: G.GraphCOO,
    max_iters: int = 50,
    tol: float = 1e-6,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``({'hubs': [V], 'authorities': [V]}, iterations)`` with
    each score vector L2-normalized (all-zero when the graph has no
    edges feeding that role)."""
    V = g.n_vertices
    if sharded is None:
        sharded = partition(role_graph(g), n_data, n_model)
    state = jnp.zeros(sharded.n_pad, jnp.float32).at[: 2 * V].set(
        1.0 / np.sqrt(max(V, 1)))
    hub = auth = None
    iters = 0
    while iters < max_iters:
        k = min(_BURST, max_iters - iters)
        state, _ = run_pregel(_HITS_SPEC, sharded, state, k, mesh=mesh)
        iters += k
        new_hub, new_auth = _unit(state[:V]), _unit(state[V: 2 * V])
        if hub is not None and \
                float(jnp.max(jnp.abs(new_hub - hub))) < tol and \
                float(jnp.max(jnp.abs(new_auth - auth))) < tol:
            hub, auth = new_hub, new_auth
            break
        hub, auth = new_hub, new_auth
        state = jnp.zeros_like(state).at[: 2 * V].set(
            jnp.concatenate([hub, auth]))
    return {"hubs": hub, "authorities": auth}, iters


def hits_reference(src, dst, n_vertices: int, max_iters: int = 50,
                   tol: float = 1e-6):
    """Numpy oracle mirroring the device schedule exactly (simultaneous
    updates, renormalization every ``_BURST`` steps)."""
    V = n_vertices
    a_mat = np.zeros((V, V))
    a_mat[np.asarray(src), np.asarray(dst)] = 1.0

    def unit(x):
        return x / max(np.linalg.norm(x), 1e-12)

    h = np.full(V, 1.0 / np.sqrt(max(V, 1)))
    a = np.full(V, 1.0 / np.sqrt(max(V, 1)))
    prev = None
    iters = 0
    while iters < max_iters:
        for _ in range(min(_BURST, max_iters - iters)):
            h, a = a_mat @ a, a_mat.T @ h
            iters += 1
        h, a = unit(h), unit(a)
        if prev is not None and \
                np.max(np.abs(h - prev[0])) < tol and \
                np.max(np.abs(a - prev[1])) < tol:
            break
        prev = (h, a)
    return {"hubs": h.astype(np.float32),
            "authorities": a.astype(np.float32)}, iters


# ------------------------------------------------------------ registration

def _engine_run(eng, max_iters, tol):
    """Registry runner: the doubled role graph's shards are derived
    state, packed once per engine and reused across queries."""
    key = "hits/sharded"
    if key not in eng.cache:
        eng.cache[key] = partition(role_graph(eng.coo), eng.n_data,
                                   eng.n_model)
    return hits(eng.coo, max_iters=max_iters, tol=tol, mesh=eng.mesh,
                sharded=eng.cache[key])


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    # power iteration on the doubled edge set; two tables out
    iters = min(30, params.get("max_iters") or 30)
    return P.QuerySpec("hits", 1 if count_only else 2 * g.n_vertices,
                       iterations=iters, state_bytes_per_vertex=8.0,
                       edge_bytes_factor=2.0)


R.register(R.AlgorithmDef(
    name="hits",
    run=_engine_run,
    params=(
        R.Param("max_iters", 50, check=lambda n: n >= 1, normalize=int),
        R.Param("tol", 1e-6, check=lambda t: t > 0.0, normalize=float),
    ),
    cost=_cost,
    example_params={},
    doc="HITS hub/authority scores via the doubled role graph.",
))
