"""Token data pipeline: deterministic synthetic corpus + sharded loader.

The synthetic stream has learnable next-token structure (per-sequence
modular arithmetic progressions) so the end-to-end training example can
show a real loss drop without external data.  The loader mirrors a
production input pipeline: per-host sharding of the global batch,
background prefetch with a bounded queue (straggler smoothing), and
deterministic resume from an arbitrary step (checkpoint restart needs
the data stream to be replayable).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticTokens:
    """Deterministic infinite stream of (tokens, labels) batches."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, start_step: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.step = start_step

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        start = rng.integers(0, self.vocab, (self.batch, 1))
        delta = rng.integers(1, min(17, self.vocab), (self.batch, 1))
        t = np.arange(self.seq + 1)[None, :]
        seqs = (start + delta * t) % self.vocab
        tokens = seqs[:, :-1].astype(np.int32)
        labels = seqs[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1
            yield b


def shard_for_host(batch: dict, n_hosts: int, host_id: int) -> dict:
    """Per-host slice of the global batch (data-parallel input sharding)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: slc(v) for k, v in batch.items()}


class Prefetcher:
    """Bounded-queue background prefetch; absorbs producer jitter so a
    slow input step doesn't stall the accelerator (input-side straggler
    mitigation)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, args=(it,),
                                        daemon=True)
        self._thread.start()

    def _run(self, it):
        try:
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
