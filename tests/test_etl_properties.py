"""Hypothesis property tests for the ETL structural invariants.

``hypothesis`` is an *optional* test dependency (declared under the
``test`` extra in pyproject.toml); the whole module skips cleanly when
it is not installed so the tier-1 suite still collects.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep: hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import graph as G  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    n_edges=st.integers(1, 300),
    n_vertices=st.integers(2, 50),
    cap=st.integers(1, 20),
    seed=st.integers(0, 10**6),
)
def test_ell_invariants(n_edges, n_vertices, cap, seed):
    """(1) retained <= total; (2) per-row degree <= cap; (3) retained =
    sum of min(indeg, cap); (4) lost_fraction in [0, 1]."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    ell = G.build_ell(src, dst, n_vertices, cap)
    assert ell.n_edges <= ell.n_edges_total == n_edges
    per_row = np.asarray(ell.mask).sum(axis=1)
    assert (per_row <= cap).all()
    indeg = np.bincount(dst, minlength=n_vertices)
    assert ell.n_edges == int(np.minimum(indeg, cap).sum())
    assert 0.0 <= ell.lost_fraction <= 1.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(2, 60))
def test_coo_symmetrize_property(seed, n):
    rng = np.random.default_rng(seed)
    e = rng.integers(1, 100)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    g = G.build_coo(src, dst, n, symmetrize=True)
    s = np.asarray(g.src)[:g.n_edges]
    d = np.asarray(g.dst)[:g.n_edges]
    fwd = set(zip(s.tolist(), d.tolist()))
    assert all((b, a) in fwd for a, b in fwd)   # symmetric closure
