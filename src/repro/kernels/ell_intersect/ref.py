"""Pure-jnp oracle for the sorted-row ELL intersection kernel.

Rows are sorted ascending with the sentinel padding value greater than
every valid id, so membership of each element of ``b`` in ``a`` is one
``searchsorted`` probe — the merge-intersection of two sorted neighbor
lists in O(K log K) instead of the O(K^2) all-pairs compare the VPU
kernel prefers.  Rows must be duplicate-free (the ``build_oriented_ell``
invariant) or matches would be over-counted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("sentinel",))
def ell_intersect_ref(a, b, sentinel: int):
    """counts[i] = |a[i] ∩ b[i]| over sorted, deduped, sentinel-padded
    rows.

    a, b: [E, K] int32, each row ascending; invalid slots == sentinel.
    Returns [E] int32 intersection sizes (sentinel slots never match).
    """
    k = a.shape[1]

    def row(ra, rb):
        idx = jnp.clip(jnp.searchsorted(ra, rb), 0, k - 1)
        hit = (ra[idx] == rb) & (rb != sentinel)
        return jnp.sum(hit.astype(jnp.int32))

    return jax.vmap(row)(a, b)
