from repro.core.algorithms.pagerank import pagerank
from repro.core.algorithms.connected_components import connected_components
from repro.core.algorithms.two_hop import (
    two_hop_pairs,
    two_hop_count_upper_bound,
    multi_account_pairs,
)
from repro.core.algorithms.degrees import degree_stats
from repro.core.algorithms.similarity import jaccard_similarity, common_neighbors
from repro.core.algorithms.traversal import bfs_distances, sssp, reachable_count
from repro.core.algorithms.community import label_propagation, num_communities
from repro.core.algorithms.triangles import triangle_count, k_core, core_size
