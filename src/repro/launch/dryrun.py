import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Multi-pod dry-run: AOT-lower + compile every (arch x shape) cell on
# the production meshes and extract the roofline terms.
#
# The XLA_FLAGS assignment above MUST precede every other import (jax
# locks the device count at first init) — which is also why this header
# is a comment rather than a docstring-after-code.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all --mesh both --out benchmarks/results/dryrun
#
# Per cell this produces a JSON record with:
#   memory_analysis (bytes/device), cost_analysis (flops, bytes),
#   collective stats parsed from post-SPMD HLO, the three roofline terms,
#   and MODEL_FLOPS/HLO_FLOPs (useful-compute ratio).
# (no `from __future__ import annotations` — the XLA_FLAGS line must be
#  the first statement of the module, which __future__ imports forbid.)

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    SHAPES, get_config, list_archs, shape_applicable, reduced_config)
from repro.models.registry import build_model
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, n_chips
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    make_train_step, init_train_state, state_spec)
from repro.utils import roofline as RL
from repro.utils.tree import flatten_with_paths


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _ns(tree_spec, mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), tree_spec,
        is_leaf=lambda x: isinstance(x, P))


def usable_dp(batch: int, mesh) -> tuple:
    """Data-parallel axes that evenly divide the batch (batch=1 cells
    replicate over dp instead of sharding unevenly)."""
    sizes = mesh_axis_sizes(mesh)
    axes = []
    rem = batch
    for ax in ("pod", "data"):
        if ax in sizes and rem % sizes[ax] == 0:
            axes.append(ax)
            rem //= sizes[ax]
    return tuple(axes)


def _retarget_batch_specs(specs: dict, dp: tuple) -> dict:
    """Rewrite the leading batch axis of input PartitionSpecs to ``dp``."""
    out = {}
    for k, s in specs.items():
        parts = list(s)
        parts[0] = dp if dp else None
        out[k] = P(*parts)
    return out


def _retarget_cache_spec(tree, dp: tuple):
    def fix(s):
        parts = list(s)
        # cache layouts put batch at index 1 (after the layer axis)
        if len(parts) >= 2:
            parts[1] = dp if dp else None
        return P(*parts)
    return jax.tree_util.tree_map(
        fix, tree, is_leaf=lambda x: isinstance(x, P))


def _sharded_sds(tree_sds, tree_spec, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    def one(sds, spec):
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(
        one, tree_sds, tree_spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _cast_float(tree_sds, dtype):
    def one(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return s
    return jax.tree_util.tree_map(
        one, tree_sds, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def model_flops_for(cfg, model, params_sds, shape) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = non-embedding params
    (active params for MoE)."""
    n = 0
    for name, leaf in flatten_with_paths(params_sds):
        if "embedding" in name or "lm_head" in name:
            continue
        sz = int(np.prod(leaf.shape))
        if cfg.family == "moe" and "/mlp/w_" in name:
            sz = sz * cfg.top_k // max(cfg.n_experts, 1)
        n += sz
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, *, reduced: bool = False,
               microbatches: int = 4, overrides: dict | None = None,
               remap_tp: bool = False, strip_attn_tp: bool = False):
    """Build + lower + compile one (arch x shape x mesh) cell.

    Returns (compiled, meta) — meta carries chips/model_flops/etc.
    ``overrides`` lets the §Perf hillclimb tweak ModelConfig fields
    (attn_chunk, attn_impl, remat, ...) without new config files.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(why)
    multi_pod = "pod" in mesh.axis_names
    model = build_model(cfg)
    if strip_attn_tp:
        # MoE variant: attention runs pure-DP (no TP collectives); the
        # model axis serves expert parallelism only
        model.strip_tp = True
    dp = usable_dp(shape.global_batch, mesh)
    if remap_tp:
        # Repurpose the model axis as extra data parallelism: batch is
        # sharded over ('data','model'); param *storage* keeps its layout
        # (sharded over 'model' where divisible), which XLA now treats as
        # ZeRO-style storage — weights are all-gathered per layer for
        # compute and gradients reduce-scattered back by the grad-spec
        # constraint.  The right config for models too small to amortize
        # 16-way tensor parallelism.
        rem = shape.global_batch
        dp = []
        for ax in ("pod", "data", "model"):
            if ax in mesh.axis_names and rem % mesh_axis_sizes(mesh)[ax] == 0:
                dp.append(ax)
                rem //= mesh_axis_sizes(mesh)[ax]
        dp = tuple(dp)

    ins = model.input_specs(shape, multi_pod=multi_pod)
    ins["specs"] = _retarget_batch_specs(
        {k: ins["specs"].get(k, P(dp if dp else None, None))
         for k in ins["arrays"]}, dp)
    batch_sds = _sharded_sds(ins["arrays"], ins["specs"], mesh)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec = model.param_spec()

    if shape.kind in ("train", "prefill") and shape.seq_len % 16 == 0 \
            and not remap_tp:
        model.act_spec = P(dp if dp else None, "model", None)
    if (overrides or {}).get("attn_impl") == "ring":
        model.ring_mesh = mesh
        model.ring_batch_axes = dp if dp else ()
    if multi_pod and cfg.fsdp and not remap_tp:
        model.fsdp_axes = ("data", "pod")

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": n_chips(mesh),
        "model_flops": model_flops_for(cfg, model, params_sds, shape),
        "kind": shape.kind,
    }

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            mb = microbatches if shape.global_batch % max(microbatches, 1) == 0 else 1
            meta["microbatches"] = mb
            step_fn = make_train_step(model, opt_cfg, microbatches=mb,
                                      dp_spec=dp if dp else None,
                                      grad_spec=model.param_spec())
            st_spec = state_spec(model)
            state_sds = jax.eval_shape(
                lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
            state_sharded = _sharded_sds(state_sds, st_spec, mesh)
            lowered = jax.jit(
                step_fn,
                in_shardings=(_ns(st_spec, mesh), _ns(ins["specs"], mesh)),
                out_shardings=(_ns(st_spec, mesh), None),
            ).lower(state_sharded, batch_sds)
        elif shape.kind == "prefill":
            params_bf16 = _cast_float(params_sds, jnp.bfloat16)
            params_sharded = _sharded_sds(params_bf16, pspec, mesh)

            def prefill_fn(params, batch):
                return model.prefill(params, batch, cache_len=shape.seq_len)

            pre_cspec = _retarget_cache_spec(
                model.cache_spec(multi_pod=multi_pod), dp)
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(_ns(pspec, mesh), _ns(ins["specs"], mesh)),
                out_shardings=(None, _ns(pre_cspec, mesh)),
            ).lower(params_sharded, batch_sds)
        else:  # decode
            params_bf16 = _cast_float(params_sds, jnp.bfloat16)
            params_sharded = _sharded_sds(params_bf16, pspec, mesh)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspec = _retarget_cache_spec(
                model.cache_spec(multi_pod=multi_pod), dp)
            cache_sharded = _sharded_sds(cache_sds, cspec, mesh)
            idx_sds = jax.ShapeDtypeStruct((), jnp.int32)

            def decode_fn(params, tokens, cache, index):
                return model.decode_step(params, tokens, cache, index)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(_ns(pspec, mesh),
                              _ns(ins["specs"]["tokens"], mesh),
                              _ns(cspec, mesh), None),
                out_shardings=(None, _ns(cspec, mesh)),
            ).lower(params_sharded, batch_sds["tokens"], cache_sharded,
                    idx_sds)
        t0 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = time.time() - t0
    return compiled, meta


class SkipCell(Exception):
    pass


def analyze_cell(compiled, meta) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    try:
        mem = compiled.memory_analysis()
        mem_bytes = (getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
        mem_detail = {
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "args": getattr(mem, "argument_size_in_bytes", 0),
            "out": getattr(mem, "output_size_in_bytes", 0),
            "alias": getattr(mem, "alias_size_in_bytes", 0),
        }
    except Exception:
        mem_bytes, mem_detail = 0, {}
    hlo = compiled.as_text()
    report = RL.analyze(
        name=f"{meta['arch']}/{meta['shape']}/{meta['mesh']}",
        cost=cost, hlo_text=hlo, chips=meta["chips"],
        model_flops_global=meta["model_flops"],
        memory_bytes=mem_bytes,
    )
    rec = dataclasses.asdict(report)
    rec.update(meta)
    rec["memory_detail"] = mem_detail
    rec["roofline_fraction"] = report.roofline_fraction
    rec["bound_s"] = report.bound_s
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             reduced: bool = False, force: bool = False,
             microbatches: int = 4, overrides: dict | None = None,
             remap_tp: bool = False, strip_attn_tp: bool = False,
             tag: str = "") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        compiled, meta = lower_cell(arch, shape_name, mesh, reduced=reduced,
                                    microbatches=microbatches,
                                    overrides=overrides, remap_tp=remap_tp,
                                    strip_attn_tp=strip_attn_tp)
        rec = analyze_cell(compiled, meta)
        rec["status"] = "ok"
        del compiled
    except SkipCell as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skip", "reason": str(e)}
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    rec["wall_s"] = time.time() - t0
    with open(fname, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale configs (CI of the dry-run itself)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               reduced=args.reduced, force=args.force,
                               microbatches=args.microbatches)
                status = rec.get("status")
                n_ok += status == "ok"
                n_skip += status == "skip"
                n_err += status == "error"
                line = f"[{status:5s}] {arch:22s} {shape:12s} {mesh_kind:6s}"
                if status == "ok":
                    line += (f" mem/dev={rec.get('memory_per_device_gb', 0):.2f}GB"
                             f" dominant={rec.get('dominant')}"
                             f" bound={rec.get('bound_s', 0):.4f}s"
                             f" compile={rec.get('compile_s', 0):.0f}s")
                elif status == "error":
                    line += " " + rec.get("error", "")[:90]
                print(line, flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error", flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
