"""Sharded, atomic, async checkpointing with elastic restore.

Layout (the HDFS/GCS stand-in is a local directory):

    ckpt_root/
      step_00000100/
        MANIFEST.json        # leaf paths, shapes, dtypes, step, time
        <leaf-path>.npy      # one file per pytree leaf

Writes go to ``tmp_step_N`` then ``os.replace`` -> atomic commit: a
crash mid-write never corrupts the latest checkpoint (the supervisor
restarts from the last committed step).  ``AsyncCheckpointer`` moves the
serialization off the training thread (device->host copy happens at
submit time so the step can keep mutating state).

Elastic restore: ``restore_checkpoint(..., shardings=...)`` places each
leaf with ``jax.device_put`` under the *new* mesh's NamedSharding — a
checkpoint written on one mesh shape restores onto any other (the
resize path real pods take after a failed slice is replaced).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.utils.tree import flatten_with_paths


def _leaf_file(name: str) -> str:
    return name.replace("/", "__") + ".npy"


def save_checkpoint(root: str, step: int, state, keep: int = 3) -> str:
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = os.path.join(root, f"tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = flatten_with_paths(state)
    manifest = {"step": step, "time": time.time(), "leaves": {}}
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, _leaf_file(name)), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit
    _gc(root, keep)
    return final


def _gc(root: str, keep: int):
    steps = list_steps(root)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and os.path.exists(
                os.path.join(root, d, "MANIFEST.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, target, step: Optional[int] = None,
                       shardings=None):
    """target: template pytree (same structure; values ignored).
    shardings: optional pytree of jax.sharding.Sharding for elastic
    placement onto a (possibly different) mesh."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in flatten_with_paths(target)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
    arrays = [np.load(os.path.join(d, _leaf_file(n))) for n in names]
    leaves_flat, tdef = jax.tree_util.tree_flatten(target)
    assert len(leaves_flat) == len(arrays)
    if shardings is not None:
        shard_flat = tdef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_flat)]
    return jax.tree_util.tree_unflatten(tdef, arrays), step


class AsyncCheckpointer:
    """Background-thread writer with at-most-one in-flight checkpoint."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def submit(self, step: int, state):
        self.wait()
        # materialize to host NOW so the trainer may mutate device state
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def work():
            self.last_path = save_checkpoint(self.root, step, host_state,
                                             keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
