"""Train step factory: loss -> grads -> (compress) -> AdamW, with
microbatch gradient accumulation and mesh-aware sharding constraints.

The returned step is a pure function suitable for jit/pjit and for the
AOT dry-run:  (train_state, batch) -> (train_state, metrics).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.compression import (
    CompressionConfig, compress_grads, init_error_state)


@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    err: Optional[dict] = None    # compression error feedback

    def tree_flatten(self):
        return (self.params, self.opt, self.err), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.err), None),
    lambda aux, c: TrainState(*c),
)


def init_train_state(model, key,
                     compression: Optional[CompressionConfig] = None):
    params = model.init(key)
    mixed = jnp.dtype(model.cfg.dtype) == jnp.bfloat16
    if mixed:
        opt = init_opt_state(params, master_copy=True)   # f32 master
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    else:
        opt = init_opt_state(params)
    err = init_error_state(params) if (compression and
                                       compression.kind != "none") else None
    return TrainState(params, opt, err)


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    microbatches: int = 1,
    compression: Optional[CompressionConfig] = None,
    dp_spec: Optional[P] = None,
    grad_spec=None,
):
    """dp_spec: PartitionSpec of the batch's leading axis; grad_spec: a
    PartitionSpec pytree (usually model.param_spec()) that gradients are
    constrained to.  Without it GSPMD may keep the (all-reduced, hence
    replicated) gradients unsharded — for a 123B model that is a 30 GB/chip
    buffer; constraining turns the DP all-reduce into reduce-scatter and
    shards the whole optimizer step (ZeRO).  Both are no-ops without a
    mesh (smoke tests)."""

    def _constrain_grads(grads):
        if grad_spec is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, sp: jax.lax.with_sharding_constraint(g, sp),
            grads, grad_spec,
            is_leaf=lambda x: isinstance(x, P))

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split batch leading dim into microbatches and scan (overlap of
        # the per-microbatch psum with the next microbatch's compute is
        # XLA's latency-hiding scheduler's job; the schedule exists once
        # the loop is explicit like this)
        def reshape(x):
            b = x.shape[0]
            y = x.reshape(microbatches, b // microbatches, *x.shape[1:])
            if dp_spec is not None:
                # keep the microbatch axis replicated and the batch axis
                # data-parallel — otherwise GSPMD may shard the scan axis
                # and the peak-memory win of microbatching evaporates
                spec = P(None, dp_spec, *([None] * (y.ndim - 2)))
                y = jax.lax.with_sharding_constraint(y, spec)
            return y
        mb = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mbatch)
            grads = _constrain_grads(grads)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = _constrain_grads(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (grads, loss_sum), _ = lax.scan(body, (zeros, jnp.float32(0)), mb)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        loss = loss_sum / microbatches
        return loss, {"loss": loss}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = accumulate(state.params, batch)
        grads = _constrain_grads(grads)
        err = state.err
        if compression and compression.kind != "none":
            grads, err, cstats = compress_grads(grads, err, compression)
            metrics = {**metrics, **cstats}
        params, opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return TrainState(params, opt, err), metrics

    return train_step


def state_spec(model, compression: Optional[CompressionConfig] = None):
    """PartitionSpec pytree for TrainState (params/opt/err share specs)."""
    pspec = model.param_spec()
    err = pspec if (compression and compression.kind != "none") else None
    opt = {"m": pspec, "v": pspec, "step": P()}
    if jnp.dtype(model.cfg.dtype) == jnp.bfloat16:
        opt["master"] = pspec
    return TrainState(params=pspec, opt=opt, err=err)
