"""Small pytree utilities used across the framework (no optax/flax on box)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over all leaves (gradient clipping)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def has_nan(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.any(jnp.stack([jnp.any(~jnp.isfinite(x.astype(jnp.float32))) for x in leaves]))


def flatten_with_paths(tree):
    """[(path_string, leaf)] — used by the checkpointer for stable naming."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_path_elem_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_elem_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)
