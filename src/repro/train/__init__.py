from repro.train.optimizer import AdamWConfig, init_opt_state, adamw_update
from repro.train.train_step import make_train_step, TrainState
from repro.train.serve_step import make_prefill_step, make_decode_step
