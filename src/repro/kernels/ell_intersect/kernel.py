"""Pallas TPU kernel: sorted-neighbor-row intersection counting.

The hot loop of degree-ordered triangle counting: for each oriented edge
``(u, v)`` the count is

    c[e] = | nbr[u] ∩ nbr[v] |

over the two *sorted, deduped* out-neighbor rows gathered for that edge.
Summed over all oriented edges this is exactly the triangle count (each
triangle surfaces once, at its lowest-rank edge).

TPU mapping
-----------
* Grid over edge tiles of ``R`` edges.  The ops wrapper gathers the two
  ``(R, K)`` row tiles per edge chunk up front (an XLA HBM gather), so
  each grid step streams two perfectly-sequential tiles into VMEM —
  the same layout-and-budget discipline as the ``ell_combine`` kernel,
  with the O(V) gather source swapped for O(E·K) streamed rows.
* Per tile the intersection is a ``fori_loop`` over the K columns of
  ``b``: one lane-broadcast equality of column ``b[:, j]`` against the
  whole ``a`` tile and a row-sum accumulate.  Rows are deduped, so each
  match contributes exactly once; sortedness is what lets the jnp
  reference use a true ``searchsorted`` merge, and what keeps rows
  canonical (one representation per neighbor set) across variants.
* Sentinel slots (``>= sentinel``) never match: ``b``'s sentinel columns
  are masked explicitly, and a sentinel in ``a`` can only equal a masked
  ``b`` value.  All-sentinel (padding-edge) rows therefore count 0.

VMEM budget per step: 2 * R * K * 4 bytes of rows + R * 4 out.  Default
R=256, K<=2048 -> ~4.2 MB < 16 MB VMEM (ops.py enforces the K bound and
lane/sublane padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _intersect_kernel(a_ref, b_ref, y_ref, *, sentinel: int, k_valid: int):
    a = a_ref[...]                        # (R, K) int32, rows sorted
    b = b_ref[...]                        # (R, K) int32, rows sorted

    def body(j, acc):
        bj = lax.dynamic_slice_in_dim(b, j, 1, axis=1)        # (R, 1)
        hit = jnp.logical_and(a == bj, bj != sentinel)
        return acc + jnp.sum(hit.astype(jnp.int32), axis=1)

    acc = jnp.zeros((a.shape[0],), jnp.int32)
    # only the first k_valid columns of b can hold real ids; the lane
    # padding beyond is all-sentinel and would contribute zero anyway
    y_ref[...] = lax.fori_loop(0, k_valid, body, acc)


@functools.partial(jax.jit, static_argnames=("sentinel", "k_valid",
                                             "block_edges", "interpret"))
def ell_intersect_pallas(a, b, *, sentinel: int, k_valid: int,
                         block_edges: int = 256, interpret: bool = False):
    """Tiled pallas_call.  Caller guarantees: E % block_edges == 0,
    K % 128 == 0 (ops.py pads), rows sorted/deduped/sentinel-padded."""
    e, k = a.shape
    grid = (e // block_edges,)
    return pl.pallas_call(
        functools.partial(_intersect_kernel, sentinel=sentinel,
                          k_valid=k_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_edges, k), lambda i: (i, 0)),   # a tile
            pl.BlockSpec((block_edges, k), lambda i: (i, 0)),   # b tile
        ],
        out_specs=pl.BlockSpec((block_edges,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b)
