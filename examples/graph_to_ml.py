"""Graph ML feature extraction -> model training: the full story the
paper's platform exists for ("reduce the iteration time of Graph ML").

Pipeline: user-follow graph -> PageRank + component features (platform)
-> feature tokens -> train a small LM-style model to predict a user's
component from its feature sequence.  Demonstrates that platform outputs
flow straight into the JAX training substrate with no format hops.

    PYTHONPATH=src python examples/graph_to_ml.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core.query import GraphQuery, GraphPlatform
from repro.data import synthetic as S
from repro.configs.base import get_config, reduced_config
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step, init_train_state

# ---- 1. Graph features from the platform --------------------------------
N = 4_000
src, dst = S.user_follow_graph(N, 5.0, seed=0)
platform = GraphPlatform(G.build_coo(src, dst, N))
ranks = np.asarray(platform.query(GraphQuery.pagerank(max_iters=40)).value)
sym = GraphPlatform(G.build_coo(src, dst, N, symmetrize=True))
comp = np.asarray(sym.query(GraphQuery.connected_components()).value)
print(f"[features] pagerank + {len(np.unique(comp))} components for {N} users")

# ---- 2. Features -> token sequences --------------------------------------
# 8 tokens per user: quantized rank bucket, degree bucket, neighbor buckets
outdeg = np.bincount(src, minlength=N)
rank_tok = np.digitize(ranks, np.quantile(ranks, np.linspace(0, 1, 30)[1:-1]))
deg_tok = np.clip(np.log2(outdeg + 1).astype(int), 0, 29) + 32
comp_ids, comp_tok = np.unique(comp, return_inverse=True)
label_tok = (comp_tok % 60) + 64                     # target vocabulary
seq = np.stack([rank_tok, deg_tok] * 3 + [rank_tok, label_tok], axis=1)
tokens = seq[:, :-1].astype(np.int32)
labels = np.full_like(seq[:, 1:], -1)
labels[:, -1] = seq[:, -1]                           # predict the label slot

# ---- 3. Train a reduced-LM head on the features --------------------------
cfg = reduced_config(get_config("smollm-360m"), vocab=128)
model = build_model(cfg)
step = jax.jit(make_train_step(model, AdamWConfig(
    peak_lr=3e-3, warmup_steps=20, total_steps=200)))
state = init_train_state(model, jax.random.PRNGKey(0))
B = 64
for i in range(200):
    idx = np.random.default_rng(i).integers(0, N, B)
    batch = {"tokens": jnp.asarray(tokens[idx]),
             "labels": jnp.asarray(labels[idx])}
    state, metrics = step(state, batch)
    if (i + 1) % 50 == 0:
        print(f"step {i+1:4d} loss {float(metrics['loss']):.4f}")
print("[done] graph features -> trained model, one process, no format hops")
