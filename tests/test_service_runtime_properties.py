"""Property-based tests for the service runtime's pure invariants.

Gated on ``hypothesis`` (installed in the CI tier-1 env, optional
locally — the module skips cleanly when absent, mirroring
``test_etl_properties.py``).

Pinned properties:

* ``RetryPolicy`` — the schedule has exactly ``max_attempts - 1``
  entries; the bound envelope is monotone non-decreasing and capped;
  every jittered sleep lies in ``[base_s, cap_s]``; a seed fully
  determines the schedule (replay determinism).
* Backpressure — under ANY interleaving of submits and drains, a
  tier's live queue depth never exceeds its budget, and a rejected
  submit leaves no ticket behind.
"""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import graph as G  # noqa: E402
from repro.core.query import GraphQuery  # noqa: E402
from repro.core.runtime import Backpressure, RetryPolicy  # noqa: E402
from repro.core.service import GraphAnalyticsService  # noqa: E402
from repro.data import synthetic as S  # noqa: E402

policies = st.builds(
    RetryPolicy,
    max_attempts=st.integers(min_value=1, max_value=8),
    base_s=st.floats(min_value=0.0, max_value=0.01,
                     allow_nan=False, allow_infinity=False),
    cap_s=st.floats(min_value=0.01, max_value=1.0,
                    allow_nan=False, allow_infinity=False),
    multiplier=st.floats(min_value=1.0, max_value=8.0,
                         allow_nan=False, allow_infinity=False),
)


@given(policy=policies, seed=st.integers(min_value=0, max_value=2**63))
@settings(max_examples=200, deadline=None)
def test_backoff_schedule_invariants(policy, seed):
    bounds = policy.bounds()
    sched = policy.schedule(seed)
    # total attempts == max_attempts -> max_attempts - 1 sleeps
    assert len(bounds) == len(sched) == policy.max_attempts - 1
    # bound envelope: monotone non-decreasing, capped
    assert all(b1 <= b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert all(policy.base_s <= b <= policy.cap_s for b in bounds)
    # jitter stays within [base, bound_k] subset of [base, cap]
    eps = 1e-12
    for s, b in zip(sched, bounds):
        assert policy.base_s - eps <= s <= b + eps
    # replay determinism: the seed fully determines the schedule
    assert policy.schedule(seed) == sched


@given(st.integers(min_value=0, max_value=2**31))
@settings(max_examples=50, deadline=None)
def test_backoff_schedule_hash_seed_independent(seed):
    """random.Random(int) — the schedule must not vary with
    PYTHONHASHSEED (the CI determinism matrix re-runs under two)."""
    pol = RetryPolicy(max_attempts=6, base_s=1e-3, cap_s=0.1)
    a = pol.schedule(seed)
    assert a == pol.schedule(seed)
    assert len(set(pol.schedule(s) for s in (seed, seed + 1, seed + 2))) \
        >= 2  # and jitter actually varies across seeds


@pytest.fixture(scope="module")
def small_graph():
    src, dst = S.user_follow_graph(64, 3.0, seed=3)
    return G.build_coo(src, dst, 64)


# an op sequence: True = submit one batch bfs ticket, False = drain
op_sequences = st.lists(st.booleans(), min_size=1, max_size=24)


@given(ops=op_sequences, budget=st.integers(min_value=1, max_value=3))
@settings(max_examples=40, deadline=None)
def test_backpressure_depth_never_exceeds_budget(small_graph, ops, budget):
    svc = GraphAnalyticsService(interactive_threshold_s=0.0,
                                tier_depth={"batch": budget},
                                cache_size=0)
    svc.add_graph("g", small_graph, force_engine="local")
    source = 0
    admitted = rejected = 0
    for do_submit in ops:
        if do_submit:
            try:
                # distinct sources: no dedup, every submit queues
                svc.submit("g", GraphQuery.bfs([source % 64]))
                admitted += 1
            except Backpressure as e:
                rejected += 1
                assert e.depth >= e.budget == budget
            source += 1
        else:
            svc.drain()
        depths = svc.metrics()["queue_depths"]
        assert all(d <= budget for d in depths.values()), depths
    m = svc.metrics()
    assert m["counters"]["submitted"] == admitted
    assert m["counters"]["backpressure"] == rejected
    svc.drain()
    assert not svc.pending()
    assert all(d == 0 for d in svc.metrics()["queue_depths"].values())
