"""Degree statistics — the cheapest library call, and the planner's input."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R


def degree_stats(g: G.GraphCOO) -> dict:
    """Host-side summary used by the planner and the ETL reports."""
    outd = G.out_degrees(g)
    ind = G.in_degrees(g)
    return {
        "n_vertices": g.n_vertices,
        "n_edges": g.n_edges,
        "max_out_degree": int(jnp.max(outd)),
        "max_in_degree": int(jnp.max(ind)),
        "mean_degree": float(g.n_edges / max(g.n_vertices, 1)),
        "dangling": int(jnp.sum(outd == 0)),
    }


# ------------------------------------------------------------ registration

R.register(R.AlgorithmDef(
    name="degree_stats",
    run=lambda eng: (degree_stats(eng.coo), None),
    cost=lambda g, params, count_only: P.QuerySpec(
        "degree_stats", 1, iterations=1),
    doc="Host-side degree summary (also the planner's input).",
))


def degree_histogram(g: G.GraphCOO, n_bins: int = 64):
    """log2-bucketed in-degree histogram (power-law diagnostics for ETL)."""
    ind = G.in_degrees(g)
    b = jnp.clip(jnp.ceil(jnp.log2(jnp.maximum(ind, 1.0))), 0, n_bins - 1)
    return jnp.bincount(b.astype(jnp.int32), length=n_bins)
