"""Hymba-1.5B [arXiv:2411.13676]: hybrid-head parallel attention + Mamba.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Backbone: every layer runs attention heads and SSM heads in parallel on
the same input and fuses (mean) the outputs.  Sliding-window attention
everywhere except three full-attention layers (first / middle / last),
which is what makes the arch sub-quadratic and long_500k-eligible.
(Meta-tokens and cross-layer KV sharing are Hymba extras outside the
assigned backbone spec.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    window=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mlp_act="silu",
    tie_embeddings=True,
    sub_quadratic=True,
)
