"""PaliGemma-style VLM backbone: patch-embedding prefix + Gemma decoder.

Frontend STUB per the assignment: ``input_specs`` supplies precomputed
SigLIP patch embeddings [B, 256, d_model] which are prepended to the
token embeddings.  Attention is prefix-LM: bidirectional over the image
prefix, causal over text (MQA, kv=1).  Loss is computed on text
positions only (labels for prefix positions are -1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.models import layers as L
from repro.models.transformer import DenseLM, dp_axes


class VLM(DenseLM):
    family = "vlm"

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tok = L.embed_tokens(params, batch["tokens"], cfg, self.dtype)
        patches = batch["patch_embeds"].astype(self.dtype)
        x = jnp.concatenate([patches, tok], axis=1)
        qpos = jnp.arange(x.shape[1], dtype=jnp.int32)
        return x, qpos

    def _mixer_train(self, p_l, window, h, qpos):
        cfg = self.cfg
        q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
        q = L.rope(q, qpos, cfg.rope_theta)
        k = L.rope(k, qpos, cfg.rope_theta)
        o = L.attention_output(q, k, v, qpos, qpos, cfg.attn_impl,
                               causal=True, window=window,
                               softcap=cfg.attn_logit_softcap,
                               chunk=cfg.attn_chunk,
                               prefix=cfg.prefix_len)
        return L.out_proj(p_l["attn"], o, h.dtype), (k, v)

    def forward(self, params, batch):
        logits = super().forward(params, batch)
        return logits[:, self.cfg.prefix_len:]      # text positions only

    def loss(self, params, batch, vocab_chunk: int = 8):
        cfg = self.cfg
        x, qpos = self._embed_inputs(params, batch)
        x, _ = self._scan_layers(params, x, qpos)
        x = x[:, cfg.prefix_len:]
        targets = batch["labels"]                   # [B, S_text]
        b, s = targets.shape
        nc = vocab_chunk if s % vocab_chunk == 0 else 1
        xc = x.reshape(b, nc, s // nc, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, s // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_loss(carry, xs):
            xx, tt = xs
            logits = L.unembed(params, xx, cfg)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tt, 0)[..., None], axis=-1)[..., 0]
            valid = (tt >= 0)
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            chunk_loss, (jnp.float32(0), jnp.int32(0)), (xc, tc))
        loss = tot / jnp.maximum(cnt, 1)
        return loss, {"loss": loss, "tokens": cnt}

    # serving: the cache covers prefix + text; prefill consumes both.
    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        b = batch["tokens"].shape[0]
        s_total = cfg.prefix_len + batch["tokens"].shape[1]
        cache_len = cache_len or s_total
        x, qpos = self._embed_inputs(params, batch)
        x, kvs = self._scan_layers(params, x, qpos, collect_kv=True)
        logits = L.unembed(params, x[:, -1:, :], cfg)
        k, v = kvs
        pad = cache_len - s_total
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, {"k": k.astype(self.dtype), "v": v.astype(self.dtype)}

    def input_specs(self, shape: ShapeSpec, multi_pod: bool = True) -> dict:
        cfg = self.cfg
        b = shape.global_batch
        dp = dp_axes(multi_pod)
        base = super().input_specs(shape, multi_pod)
        if shape.kind in ("train", "prefill"):
            # text + prefix together honor the cell's seq_len budget
            s_text = shape.seq_len - cfg.prefix_len
            base["arrays"]["tokens"] = jax.ShapeDtypeStruct(
                (b, s_text), jnp.int32)
            if shape.kind == "train":
                base["arrays"]["labels"] = jax.ShapeDtypeStruct(
                    (b, s_text), jnp.int32)
            base["arrays"]["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_len, cfg.d_model), jnp.float32)
            base["specs"]["patch_embeds"] = P(dp, None, None)
        return base
