"""Incremental snapshot deltas and warm-started fixpoints (ISSUE 9).

The acceptance bar: seeded execution must be *invisible* in the answers.
Exact algorithms (CC, BFS, SSSP, k-core) repaired from the parent's
cached result must be byte-identical to a cold recompute of the child
snapshot; warm-started fixpoints (PageRank, HITS) must land within
their convergence tolerance with strictly fewer iterations.  On top of
the parity bar, the suite pins the catalog semantics: lineage recorded
by ``apply_delta``, delta partitions in the ``SnapshotStore``, the
time-versioned catalog (``add_snapshot`` / ``as_of`` resolution), the
planner's incremental-vs-full pricing, and the ``metrics()`` counters.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core import pools as PL
from repro.core.query import GraphQuery
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S
from repro.data.etl import SnapshotDelta, SnapshotStore

N = 240


def _bits(x):
    return np.asarray(x).tobytes()


def _edges(n, m, seed):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, n, m), rng.integers(0, n, m)], axis=1)


@pytest.fixture(scope="module")
def graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    return G.build_coo(src, dst, N)


@pytest.fixture(scope="module")
def sym_graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], N, symmetrize=True)


# ---------------------------------------------------------------------------
# GraphCOO.apply_delta: canonicalization and lineage
# ---------------------------------------------------------------------------

def test_apply_delta_digest_matches_scratch_build(graph):
    """The edited graph's content digest is bit-identical to building
    the edited edge list from scratch — lineage-equal is cache-equal."""
    added = np.array([[1, 7], [7, 1], [3, 9]])
    removed = np.stack([np.asarray(graph.src)[:2],
                        np.asarray(graph.dst)[:2]], axis=1)
    child = graph.apply_delta(added=added, removed=removed)

    src = np.asarray(graph.src)[: graph.n_edges].astype(np.int64)
    dst = np.asarray(graph.dst)[: graph.n_edges].astype(np.int64)
    w = np.asarray(graph.w)[: graph.n_edges]
    key = src * (N + 1) + dst
    rem_key = removed[:, 0].astype(np.int64) * (N + 1) + removed[:, 1]
    keep = ~np.isin(key, rem_key)
    scratch = G.build_coo(
        np.concatenate([src[keep], added[:, 0]]),
        np.concatenate([dst[keep], added[:, 1]]), N,
        w=np.concatenate([w[keep], np.ones(added.shape[0], np.float32)]))
    assert child.content_digest() == scratch.content_digest()
    assert child.content_digest() != graph.content_digest()


def test_apply_delta_symmetric_edits_both_directions(sym_graph):
    child = sym_graph.apply_delta(added=[[2, 5]])
    src = np.asarray(child.src)[: child.n_edges]
    dst = np.asarray(child.dst)[: child.n_edges]
    pairs = set(zip(src.tolist(), dst.tolist()))
    assert (2, 5) in pairs and (5, 2) in pairs
    assert child.symmetric


def test_apply_delta_records_lineage(graph):
    child = graph.apply_delta(added=[[0, 5]], removed=[[1, 2]])
    assert child.parent_digest == graph.content_digest()
    d = child.delta
    assert d.n_added == 1 and d.n_removed == 1
    assert d.nbytes() > 0
    # touched: sorted unique endpoints of the edit
    assert d.touched.tolist() == sorted({0, 5, 1, 2})
    # the base graph itself carries no lineage
    assert getattr(graph, "parent_digest", None) is None


def test_apply_delta_validates(graph):
    with pytest.raises(ValueError, match="endpoints"):
        graph.apply_delta(added=[[0, N]])
    with pytest.raises(ValueError, match="endpoints"):
        graph.apply_delta(removed=[[-1, 0]])
    with pytest.raises(ValueError, match="added_w"):
        graph.apply_delta(added=[[0, 1], [1, 2]], added_w=[1.0])


def test_apply_delta_add_then_remove_roundtrips_digest(graph):
    """Removing exactly what was added returns the original digest."""
    src = np.asarray(graph.src)[: graph.n_edges].astype(np.int64)
    dst = np.asarray(graph.dst)[: graph.n_edges].astype(np.int64)
    existing = set(zip(src.tolist(), dst.tolist()))
    fresh = np.array([[u, v] for u, v in _edges(N, 40, seed=3).tolist()
                      if (u, v) not in existing][:10])
    assert fresh.shape[0] >= 3
    child = graph.apply_delta(added=fresh)
    back = child.apply_delta(removed=fresh)
    assert back.content_digest() == graph.content_digest()


# ---------------------------------------------------------------------------
# SnapshotStore delta partitions
# ---------------------------------------------------------------------------

def _delta(name, base, added, removed=None):
    removed = np.zeros((0, 2), np.int64) if removed is None else removed
    return SnapshotDelta(name, base,
                         added[:, 0], added[:, 1],
                         removed[:, 0], removed[:, 1])


def test_snapshot_store_delta_roundtrip_and_manifest(tmp_path):
    from repro.data.etl import Snapshot
    store = SnapshotStore(str(tmp_path))
    base = _edges(N, 60, seed=1)
    store.write(Snapshot("day0", base[:, 0], base[:, 1]))
    d1, d2 = _edges(N, 8, seed=2), _edges(N, 5, seed=3)
    store.write_delta(_delta("day1", "day0", d1))
    store.write_delta(_delta("day2", "day1", d2, removed=d1[:3]))

    rt = store.read_delta("day2")
    assert rt.base == "day1" and rt.n_added == 5 and rt.n_removed == 3
    man = store.manifest("day2")
    assert man == {"name": "day2", "base": "day0",
                   "deltas": ["day1", "day2"]}

    # resolve == manual replay (removals before additions, per delta)
    snap = store.resolve("day2")
    expect = np.concatenate([base, d1], axis=0)
    key = expect[:, 0] * (N + 1) + expect[:, 1]
    rem = d1[:3, 0] * (N + 1) + d1[:3, 1]
    expect = np.concatenate([expect[~np.isin(key, rem)], d2], axis=0)
    got = np.stack([snap.src, snap.dst], axis=1)
    assert np.array_equal(np.sort(got, axis=0), np.sort(expect, axis=0))

    assert store.list() == ["day0"]
    assert store.list_deltas() == ["day1", "day2"]


def test_snapshot_store_delta_errors(tmp_path):
    from repro.data.etl import Snapshot
    store = SnapshotStore(str(tmp_path))
    with pytest.raises(KeyError, match="available deltas"):
        store.read_delta("nope")
    # a dangling chain surfaces the missing partition by name
    store.write_delta(_delta("day1", "day0", _edges(N, 4, seed=4)))
    with pytest.raises(KeyError, match="day0"):
        store.manifest("day1")
    # a cyclic chain is reported, not walked forever
    store.write(Snapshot("dayA", *_edges(N, 4, seed=5).T))
    store.write_delta(_delta("c1", "c2", _edges(N, 2, seed=6)))
    store.write_delta(_delta("c2", "c1", _edges(N, 2, seed=7)))
    with pytest.raises(KeyError, match="cycle"):
        store.manifest("c1")


# ---------------------------------------------------------------------------
# Time-versioned catalog
# ---------------------------------------------------------------------------

def _versioned_service(coo, added, **kw):
    svc = GraphAnalyticsService()
    svc.add_snapshot("g", coo, as_of="2026-08-01", **kw)
    svc.add_snapshot("g", as_of="2026-08-02", added=added, **kw)
    return svc


def test_add_snapshot_versioning_rules(graph):
    svc = GraphAnalyticsService()
    with pytest.raises(ValueError, match="either a graph or a delta"):
        svc.add_snapshot("g")
    with pytest.raises(KeyError, match="no base version"):
        svc.add_snapshot("g", added=[[0, 1]])
    svc.add_snapshot("g", graph, as_of=3)
    with pytest.raises(ValueError, match="not both"):
        svc.add_snapshot("g", graph, added=[[0, 1]])
    with pytest.raises(ValueError, match="must advance"):
        svc.add_snapshot("g", graph, as_of=3)
    ctx = svc.add_snapshot("g", added=[[0, 1]])   # as_of defaults to 4
    assert svc.snapshot_versions("g") == [3, 4]
    assert svc.context("g") is ctx                # bare name = latest


def test_context_as_of_resolution(graph):
    svc = _versioned_service(graph, added=[[0, 1]])
    old = svc.context("g", as_of="2026-08-01")
    mid = svc.context("g", as_of="2026-08-01T23:59")   # newest <= as_of
    new = svc.context("g", as_of="2026-09-01")
    assert old is mid and old is not new
    assert new is svc.context("g")
    with pytest.raises(KeyError, match="no version"):
        svc.context("g", as_of="2025-01-01")
    svc.add_graph("plain", graph)
    with pytest.raises(KeyError, match="no time-versioned"):
        svc.context("plain", as_of="2026-08-01")


# ---------------------------------------------------------------------------
# Parity: seeded execution is invisible in the answers
# ---------------------------------------------------------------------------

EXACT_QUERIES = [
    ("connected_components", GraphQuery.of("connected_components")),
    ("bfs", GraphQuery.of("bfs", sources=(0,))),
    ("sssp", GraphQuery.of("sssp", source=0)),
]


@pytest.mark.parametrize("alg,q", EXACT_QUERIES,
                         ids=[a for a, _ in EXACT_QUERIES])
@pytest.mark.parametrize("force_engine", ["local", "distributed"])
def test_incremental_exact_parity(sym_graph, alg, q, force_engine):
    """Seeded repair == cold recompute, byte for byte, on both engines,
    with fewer (or equal) iterations and the mode recorded."""
    added = _edges(N, 6, seed=21)
    svc = _versioned_service(sym_graph, added,
                             force_engine=force_engine)
    parent = svc.call("g", q, as_of="2026-08-01")
    r = svc.call("g", q)
    assert r.meta.get("mode") == "incremental"
    assert r.iterations <= parent.iterations

    ctx = svc.context("g")
    cold = ctx.engine(r.meta["plan"].engine).run(
        q.algorithm, q.params, variant=r.meta["plan"].variant)
    assert _bits(r.value) == _bits(cold.value)


def test_incremental_kcore_parity_on_removal(sym_graph):
    """k-core repairs removal-only deltas (the core only shrinks)."""
    q = GraphQuery.of("k_core", k=2)
    src = np.asarray(sym_graph.src)[: sym_graph.n_edges]
    dst = np.asarray(sym_graph.dst)[: sym_graph.n_edges]
    sel = src < dst
    removed = np.stack([src[sel][:5], dst[sel][:5]], axis=1)
    svc = GraphAnalyticsService()
    svc.add_snapshot("g", sym_graph, as_of=0)
    svc.call("g", q)
    svc.add_snapshot("g", as_of=1, removed=removed)
    r = svc.call("g", q)
    assert r.meta.get("mode") == "incremental"
    ctx = svc.context("g")
    cold = ctx.engine(r.meta["plan"].engine).run(
        q.algorithm, q.params, variant=r.meta["plan"].variant)
    assert _bits(r.value) == _bits(cold.value)


def test_incremental_declines_to_cold_without_parent_result(sym_graph):
    """No cached parent answer -> no seed -> plain full execution."""
    svc = _versioned_service(sym_graph, added=[[0, 9]])
    r = svc.call("g", GraphQuery.of("connected_components"))
    assert r.meta.get("mode") is None
    assert svc.metrics()["incremental"]["incremental_runs"] == 0


@pytest.mark.parametrize("alg,q,unpack", [
    ("pagerank", GraphQuery.of("pagerank"), lambda v: [("ranks", v)]),
    ("hits", GraphQuery.of("hits"),
     lambda v: [("hubs", v["hubs"]), ("authorities", v["authorities"])]),
], ids=["pagerank", "hits"])
def test_warm_start_parity_and_fewer_iterations(graph, alg, q, unpack):
    # one-edge delta: the child fixpoint sits close to the parent's, so
    # the warm start must beat the cold run decisively, not marginally
    added = _edges(N, 1, seed=33)
    svc = _versioned_service(graph, added)
    svc.call("g", q, as_of="2026-08-01")
    r = svc.call("g", q)
    assert r.meta.get("mode") == "warm"

    ctx = svc.context("g")
    cold = ctx.engine(r.meta["plan"].engine).run(
        q.algorithm, q.params, variant=r.meta["plan"].variant)
    assert r.iterations < cold.iterations
    for name, warm_v in unpack(r.value):
        cold_v = dict(unpack(cold.value))[name]
        assert np.allclose(np.asarray(warm_v), np.asarray(cold_v),
                           atol=1e-4), name


def test_warm_start_walks_past_unanswered_versions(graph):
    """The warm seed may come from a grandparent: versions the query
    never ran on are walked through, not a dead end."""
    svc = GraphAnalyticsService()
    svc.add_snapshot("g", graph, as_of=0)
    q = GraphQuery.of("pagerank")
    svc.call("g", q)
    svc.add_snapshot("g", as_of=1, added=[[0, 3]])     # never queried
    svc.add_snapshot("g", as_of=2, added=[[1, 4]])
    r = svc.call("g", q)
    assert r.meta.get("mode") == "warm"


# ---------------------------------------------------------------------------
# Planner pricing, submit path, pools, metrics
# ---------------------------------------------------------------------------

def test_plan_mode_crossover_small_vs_huge_delta(sym_graph):
    q = GraphQuery.of("connected_components")
    svc = GraphAnalyticsService()
    svc.add_snapshot("g", sym_graph, as_of=0)
    svc.call("g", q)
    svc.add_snapshot("g", as_of=1, added=[[0, 7]])
    _, mode = svc._seed_for(svc.context("g"), q)
    assert mode == "incremental"
    plan = svc.context("g").plan(q, seed_mode=mode)
    assert plan.mode == "incremental"
    assert plan.est_s < P.plan_cost(svc.context("g").plan(q))
    assert "incremental repair" in plan.reason

    # a delta touching every vertex prices out: full recompute wins
    svc.add_snapshot("g", as_of=2,
                     added=np.stack([np.arange(N), np.roll(np.arange(N), 1)],
                                    axis=1))
    svc.call("g", q, as_of=1)          # parent answer for the seed
    _, mode = svc._seed_for(svc.context("g"), q)
    assert mode == "incremental"
    big = svc.context("g").plan(q, seed_mode=mode)
    assert big.mode == "full"
    assert "full recompute beats incremental" in big.reason


def test_price_incremental_estimate_monotone_in_touched(graph):
    stats = P.GraphStats.of(graph)
    q = P.QuerySpec("connected_components", graph.n_vertices,
                    iterations=8, state_bytes_per_vertex=4.0)
    deltas = [G.GraphDelta(added=np.zeros((0, 2), np.int64),
                           removed=np.zeros((0, 2), np.int64),
                           touched=np.arange(k, dtype=np.int32))
              for k in (2, 20, 200)]
    costs = [P.estimate_incremental_cost(stats, q, d) for d in deltas]
    assert costs == sorted(costs)
    assert costs[0] < P.full_traffic_cost(stats, q)


def test_submitted_seeded_ticket_never_fuses(sym_graph):
    q = GraphQuery.of("bfs", sources=(0,))
    svc = _versioned_service(sym_graph, added=[[0, 9]])
    parent = svc.call("g", q, as_of="2026-08-01")
    t = svc.submit("g", q)
    assert t.plan.mode == "incremental"
    assert t.fuse_key is None and t.seed is not None
    r = svc.result(t)
    assert r.meta.get("mode") == "incremental"
    cold = svc.context("g").engine(t.plan.engine).run(
        q.algorithm, q.params, variant=t.plan.variant)
    assert _bits(r.value) == _bits(cold.value)
    assert r.iterations <= parent.iterations


def test_incremental_parity_under_two_pools(sym_graph):
    ps = PL.PoolSet([PL.DevicePool("onprem"), PL.DevicePool("cloud")])
    q = GraphQuery.of("connected_components")
    svc = GraphAnalyticsService(pools=ps)
    svc.add_snapshot("g", sym_graph, as_of=0, pools=["cloud"])
    svc.call("g", q)
    svc.add_snapshot("g", as_of=1, added=[[0, 9]], pools=["cloud"])
    r = svc.call("g", q)
    assert r.meta.get("mode") == "incremental"
    ctx = svc.context("g")
    cold = ctx.engine(r.meta["plan"].engine).run(
        q.algorithm, q.params, variant=r.meta["plan"].variant)
    assert _bits(r.value) == _bits(cold.value)


def test_metrics_incremental_counters(graph, sym_graph):
    svc = GraphAnalyticsService()
    base = svc.metrics()["incremental"]
    assert base == {"warm_hits": 0, "incremental_runs": 0,
                    "iterations_saved": 0, "delta_bytes_applied": 0}

    svc.add_snapshot("cc", sym_graph, as_of=0)
    svc.add_snapshot("pr", graph, as_of=0)
    qc, qp = GraphQuery.of("connected_components"), GraphQuery.of("pagerank")
    svc.call("cc", qc)
    svc.call("pr", qp)
    svc.add_snapshot("cc", as_of=1, added=[[0, 9]])
    svc.add_snapshot("pr", as_of=1, added=[[0, 9]])
    svc.call("cc", qc)
    svc.call("pr", qp)
    m = svc.metrics()["incremental"]
    assert m["incremental_runs"] == 1 and m["warm_hits"] == 1
    assert m["iterations_saved"] > 0
    assert m["delta_bytes_applied"] > 0
