# Pallas TPU kernels for the platform's compute hot spots:
#   ell_combine      — ELL gather+combine (SpMV / hash-to-min): the inner
#                      loop of PageRank and connected components, i.e. the
#                      paper's two flagship workloads.
#   flash_attention  — online-softmax attention for the LM serving cells
#                      (prefill_32k) of the assigned architectures.
# Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# public wrapper, interpret=True on CPU), ref.py (pure-jnp oracle).
