"""Roofline machinery tests: HLO collective parser on known text, the
per-device cost_analysis convention, and the analytic cost model's
agreement with first-principles numbers.
"""
import subprocess
import sys
import textwrap

import pytest

from repro.utils.hlo import parse_collectives, _shape_bytes
from repro.utils import roofline as RL
from repro.utils.analytic import cost_cell, ring
from repro.configs.base import get_config, SHAPES


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("f32[16]{0}") == 64
    assert _shape_bytes("(f32[4,4], u32[2])") == 64 + 8
    assert _shape_bytes("pred[10]") == 10


def test_parse_collectives_ring_factors():
    hlo = textwrap.dedent("""
      %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
      %ag = bf16[64,64]{1,0} all-gather(%y), replica_groups=[4,8]<=[32]
      %cp = f32[256]{0} collective-permute(%z)
    """)
    stats = parse_collectives(hlo, default_group=4)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "collective-permute": 1}
    assert stats.raw_bytes["all-reduce"] == 4096
    # ring factor 2(n-1)/n with n=4
    assert stats.link_bytes["all-reduce"] == pytest.approx(4096 * 1.5)
    # iota groups: size 8
    assert stats.link_bytes["all-gather"] == pytest.approx(
        64 * 64 * 2 * (7 / 8))
    assert stats.link_bytes["collective-permute"] == 1024


def test_roofline_analyze_dominant_term():
    rep = RL.analyze("t", {"flops": 1e12, "bytes accessed": 1e9},
                     "", chips=4, model_flops_global=2e12)
    assert rep.compute_s == pytest.approx(1e12 / RL.PEAK_FLOPS_BF16)
    assert rep.dominant == "compute"
    assert rep.useful_ratio == pytest.approx(0.5)


def test_cost_analysis_is_per_device():
    """Verifies the convention utils/roofline.py relies on: a [N,N]x[N,N]
    matmul sharded over 8 devices reports 2N^3/8 flops."""
    script = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('x',))
        N = 256
        A = jax.ShapeDtypeStruct((N, N), jnp.float32,
                                 sharding=NamedSharding(mesh, P('x', None)))
        B = jax.ShapeDtypeStruct((N, N), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, None)))
        with mesh:
            c = jax.jit(lambda a, b: a @ b).lower(A, B).compile()
        cost = c.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        expect = 2 * N**3 / 8
        assert abs(cost['flops'] - expect) / expect < 0.01, cost['flops']
        print('PER_DEVICE_OK')
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"})
    assert "PER_DEVICE_OK" in r.stdout, r.stderr[-2000:]


def test_analytic_dense_train_flops():
    """smollm train_4k: analytic per-chip flops ~= 3 * 2*N*T / chips
    within 2x (attention & vocab add the rest)."""
    cfg = get_config("smollm_360m")
    shape = SHAPES["train_4k"]
    cost = cost_cell(cfg, shape, {"data": 16, "model": 16},
                     dp_used=("data",))
    n = cfg.param_count()
    t = shape.global_batch * shape.seq_len
    floor = 6 * n * t / 256
    assert cost.flops_hlo_equiv >= floor * 0.8
    assert cost.flops_hlo_equiv <= floor * 4
    terms = cost.terms()
    assert all(v >= 0 for v in terms.values())


def test_analytic_decode_memory_bound():
    """decode_32k on a dense arch must be memory-dominated (KV cache +
    weights streaming), matching the classic inference roofline."""
    cfg = get_config("granite_8b")
    cost = cost_cell(cfg, SHAPES["decode_32k"], {"data": 16, "model": 16},
                     dp_used=("data",))
    t = cost.terms()
    assert t["memory_s"] > t["compute_s"]


def test_analytic_moe_has_a2a():
    cfg = get_config("olmoe_1b_7b")
    cost = cost_cell(cfg, SHAPES["train_4k"], {"data": 16, "model": 16},
                     dp_used=("data",))
    assert "moe_a2a" in cost.breakdown["coll"]
    assert cost.breakdown["coll"]["moe_a2a"] > 0


def test_ring():
    assert ring(1) == 0.0
    assert ring(2) == 0.5
    assert ring(16) == pytest.approx(15 / 16)
