"""Device-ready graph representations.

The paper's platform moves graphs between a distributed dataflow engine
(Spark/GraphFrames) and an in-memory graph database (Neo4j).  On TPU every
representation must be fixed-shape, so we keep three formats:

* ``GraphCOO``  — destination-sorted edge list, padded with a sentinel
  vertex id ``V`` so ``jax.ops.segment_*`` with ``num_segments=V+1`` drops
  padding for free.  This is the *exact* format (no degree cap) and the
  unit of edge partitioning for the distributed engine.
* ``GraphCSR``  — ``indptr/indices``; the LocalEngine's native format
  (the Neo4j "index-free adjacency" analogue: pointer-chase becomes slice).
* ``GraphELL`` — per-vertex neighbor lists padded to a max degree ``K``.
  This is the paper's ``MaxAdjacentNodes`` cap (Table I) turned into the
  TPU-native layout: gather + masked row-reduce is exactly what the VPU
  wants, and skew becomes padding instead of stragglers.

All constructors take host-side ``np.ndarray`` edge lists (the ETL layer
works in numpy, like Scalding worked in Hadoop) and produce pytrees of
``jnp`` arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphCOO:
    """Destination-sorted, padded COO edge list.

    Padding edges have ``src == dst == n_vertices`` (the sentinel row) and
    ``w == 0``.
    """

    src: Array          # [E_pad] int32
    dst: Array          # [E_pad] int32, sorted ascending
    w: Array            # [E_pad] float32 (1.0 for unweighted)
    n_vertices: int     # static
    n_edges: int        # true edge count (static)
    symmetric: bool = False   # built via symmetrize=True (static metadata;
                              # set it manually if the edge list is already
                              # symmetric by construction)

    # -- pytree protocol (scalars are static aux data) ---------------------
    def tree_flatten(self):
        return (self.src, self.dst, self.w), (
            self.n_vertices, self.n_edges, self.symmetric)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, w = children
        return cls(src, dst, w, *aux)

    @property
    def e_pad(self) -> int:
        return int(self.src.shape[0])

    def nbytes(self) -> int:
        return self.e_pad * (4 + 4 + 4)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphCSR:
    """CSR adjacency: out-neighbors of v are indices[indptr[v]:indptr[v+1]]."""

    indptr: Array       # [V+1] int32
    indices: Array      # [E_pad] int32 (padded tail with sentinel V)
    w: Array            # [E_pad] float32
    n_vertices: int
    n_edges: int

    def tree_flatten(self):
        return (self.indptr, self.indices, self.w), (self.n_vertices, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indptr, indices, w = children
        return cls(indptr, indices, w, aux[0], aux[1])

    def nbytes(self) -> int:
        return int(self.indptr.shape[0]) * 4 + int(self.indices.shape[0]) * 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphELL:
    """ELLPACK: fixed-width neighbor matrix (the MaxAdjacentNodes layout).

    ``nbr[v, k]`` is the k-th in-neighbor of ``v`` (source of an edge into
    v); invalid slots have ``mask == False`` and ``nbr == n_vertices``
    (sentinel, so gathers read the identity pad row).
    """

    nbr: Array          # [V, K] int32
    mask: Array         # [V, K] bool
    w: Array            # [V, K] float32
    n_vertices: int
    n_edges: int        # edges retained after capping
    n_edges_total: int  # edges before capping (for Table I loss accounting)

    def tree_flatten(self):
        return (self.nbr, self.mask, self.w), (
            self.n_vertices, self.n_edges, self.n_edges_total)

    @classmethod
    def tree_unflatten(cls, aux, children):
        nbr, mask, w = children
        return cls(nbr, mask, w, aux[0], aux[1], aux[2])

    @property
    def max_degree(self) -> int:
        return int(self.nbr.shape[1])

    @property
    def lost_fraction(self) -> float:
        """Table I: fraction of edges dropped by the degree cap."""
        if self.n_edges_total == 0:
            return 0.0
        return 1.0 - self.n_edges / self.n_edges_total

    def nbytes(self) -> int:
        v, k = self.nbr.shape
        return int(v) * int(k) * (4 + 1 + 4)


# ---------------------------------------------------------------------------
# Host-side constructors (numpy; this is the ETL substrate's device handoff)
# ---------------------------------------------------------------------------

def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] >= n:
        return x[:n]
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def build_coo(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    w: Optional[np.ndarray] = None,
    pad_multiple: int = 1024,
    symmetrize: bool = False,
    dedup: bool = True,
) -> GraphCOO:
    """Sort edges by destination, optionally symmetrize/dedup, pad."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    if dedup and src.size:
        key = src.astype(np.int64) * np.int64(n_vertices + 1) + dst.astype(np.int64)
        _, keep = np.unique(key, return_index=True)
        src, dst, w = src[keep], dst[keep], w[keep]
    order = np.argsort(dst, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    n_edges = int(src.shape[0])
    e_pad = max(pad_multiple, round_up(n_edges, pad_multiple))
    sentinel = np.int32(n_vertices)
    return GraphCOO(
        src=jnp.asarray(_pad_to(src, e_pad, sentinel)),
        dst=jnp.asarray(_pad_to(dst, e_pad, sentinel)),
        w=jnp.asarray(_pad_to(w, e_pad, 0.0)),
        n_vertices=int(n_vertices),
        n_edges=n_edges,
        symmetric=bool(symmetrize),
    )


def build_csr(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    w: Optional[np.ndarray] = None,
    pad_multiple: int = 1024,
    symmetrize: bool = False,
) -> GraphCSR:
    """CSR over *out*-neighbors: row v lists targets of edges from v."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    counts = np.bincount(src, minlength=n_vertices).astype(np.int32)
    indptr = np.zeros(n_vertices + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    n_edges = int(src.shape[0])
    e_pad = max(pad_multiple, round_up(n_edges, pad_multiple))
    return GraphCSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(_pad_to(dst, e_pad, np.int32(n_vertices))),
        w=jnp.asarray(_pad_to(w, e_pad, 0.0)),
        n_vertices=int(n_vertices),
        n_edges=n_edges,
    )


def build_ell(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    max_degree: int,
    w: Optional[np.ndarray] = None,
    symmetrize: bool = False,
    direction: str = "in",
) -> GraphELL:
    """Pack edges into the fixed-width ELL layout, capping per-vertex degree.

    ``direction='in'``: row v holds *sources* of edges into v (what SpMV /
    message aggregation wants).  Edges beyond ``max_degree`` for a vertex
    are dropped — this is exactly the paper's ``MaxAdjacentNodes``
    restriction, and ``lost_fraction`` reproduces Table I.
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    if direction == "in":
        row, col = dst, src
    else:
        row, col = src, dst
    n_total = int(row.shape[0])
    order = np.argsort(row, kind="stable")
    row, col, w = row[order], col[order], w[order]
    counts = np.bincount(row, minlength=n_vertices)
    # slot index of each edge within its row
    starts = np.zeros(n_vertices, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    slot = np.arange(n_total, dtype=np.int64) - starts[row]
    keep = slot < max_degree
    row_k, col_k, w_k, slot_k = row[keep], col[keep], w[keep], slot[keep]
    nbr = np.full((n_vertices, max_degree), np.int32(n_vertices), dtype=np.int32)
    mask = np.zeros((n_vertices, max_degree), dtype=bool)
    wm = np.zeros((n_vertices, max_degree), dtype=np.float32)
    nbr[row_k, slot_k] = col_k
    mask[row_k, slot_k] = True
    wm[row_k, slot_k] = w_k
    return GraphELL(
        nbr=jnp.asarray(nbr),
        mask=jnp.asarray(mask),
        w=jnp.asarray(wm),
        n_vertices=int(n_vertices),
        n_edges=int(keep.sum()),
        n_edges_total=n_total,
    )


# ---------------------------------------------------------------------------
# Device-side primitives shared by engines
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_vertices", "op"))
def segment_combine(values: Array, segment_ids: Array, n_vertices: int, op: str):
    """Aggregate edge messages to destination vertices.

    ``segment_ids`` may contain the sentinel ``n_vertices`` (padding); one
    extra segment swallows it and is dropped.  ``op`` in {sum,min,max}.
    """
    n = n_vertices + 1
    if op == "sum":
        out = jax.ops.segment_sum(values, segment_ids, num_segments=n)
    elif op == "min":
        out = jax.ops.segment_min(values, segment_ids, num_segments=n)
    elif op == "max":
        out = jax.ops.segment_max(values, segment_ids, num_segments=n)
    else:
        raise ValueError(f"unknown op {op!r}")
    return out[:n_vertices]


def pad_vertex_state(x: Array, identity) -> Array:
    """Append the sentinel row so gathers through padded ids read identity."""
    pad = jnp.full((1,) + x.shape[1:], identity, dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def require_symmetric(g: GraphCOO, algorithm: str) -> None:
    """Guard for algorithms with undirected semantics — on a directed
    edge list they run fine but return silently wrong answers."""
    if not getattr(g, "symmetric", False):
        raise ValueError(
            f"{algorithm} has undirected semantics and needs a symmetrized "
            f"edge list: build with build_coo(..., symmetrize=True), or set "
            f"coo.symmetric = True if the edges are already symmetric by "
            f"construction")


def out_degrees(g: GraphCOO) -> Array:
    ones = (g.src < g.n_vertices).astype(jnp.float32)
    return segment_combine(ones, g.src, g.n_vertices, "sum")


def in_degrees(g: GraphCOO) -> Array:
    ones = (g.dst < g.n_vertices).astype(jnp.float32)
    return segment_combine(ones, g.dst, g.n_vertices, "sum")
