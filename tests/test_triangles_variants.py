"""The two triangle-counting variants and the caches they lean on.

Covers the tentpole and its satellites:

* parity of the ELL-intersect variant vs the bitset variant vs the dense
  ``trace(A^3)/6`` oracle — random, star, self-loop and empty graphs, on
  both engines;
* planner variant selection: bitset for small interactive graphs,
  intersect beyond, flipping exactly once, and large-V triangle queries
  staying *local* where bitset memory alone would have forced them
  distributed;
* the result-cache identity fix: content-digest keys can never serve a
  dead graph's results to a new graph at a recycled address, and
  byte-identical reloaded snapshots *share* entries;
* the bounded pregel jit cache with structural (Mesh-free) keys;
* the scale acceptance run: a graph whose bitset state alone exceeds
  ``LOCAL_MEM_BUDGET`` completes locally via the intersect variant and
  matches per-edge set-intersection oracles on a subsample.
"""
import gc
from collections import OrderedDict

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core import pregel
from repro.core.algorithms.triangles import (
    triangle_count_intersect, triangle_count_reference)
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.query import GraphPlatform, GraphQuery
from repro.data import synthetic as S

N = 250


def _random_graph(n=N, seed=3):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, 6 * n)
    dst = rng.integers(0, n, 6 * n)
    return G.build_coo(src, dst, n, symmetrize=True), src, dst


def _star_graph(n=64):
    leaves = np.arange(1, n)
    return (G.build_coo(np.zeros(n - 1, np.int64), leaves, n,
                        symmetrize=True),
            np.zeros(n - 1, np.int64), leaves)


def _self_loop_graph():
    src = np.array([0, 1, 2, 0, 3, 3])
    dst = np.array([1, 2, 0, 0, 3, 1])      # K3 + self-loops + pendant
    return G.build_coo(src, dst, 4, symmetrize=True), src, dst


def _empty_graph(n=5):
    e = np.array([], dtype=np.int64)
    return G.build_coo(e, e, n, symmetrize=True), e, e


GRAPHS = {
    "random": _random_graph,
    "star": _star_graph,
    "self_loop": _self_loop_graph,
    "empty": _empty_graph,
}


@pytest.mark.parametrize("kind", sorted(GRAPHS))
@pytest.mark.parametrize("engine", ["local", "distributed"])
def test_variant_parity_and_oracle(kind, engine):
    g, src, dst = GRAPHS[kind]()
    eng = (LocalEngine(g) if engine == "local"
           else DistributedEngine(g, n_data=4))
    want = triangle_count_reference(src, dst, g.n_vertices)
    r_bit = eng.run("triangle_count", variant="bitset")
    r_int = eng.run("triangle_count", variant="intersect")
    assert r_bit.value == want, f"{kind}/{engine}: bitset"
    assert r_int.value == want, f"{kind}/{engine}: intersect"
    assert r_bit.meta["variant"] == "bitset"
    assert r_int.meta["variant"] == "intersect"


def test_direct_intersect_path_matches_oracle():
    g, src, dst = _random_graph(seed=11)
    count, per_edge = triangle_count_intersect(g)
    assert count == triangle_count_reference(src, dst, g.n_vertices)
    assert per_edge.sum() == count
    assert per_edge.shape[0] == G.build_oriented_ell(
        np.asarray(g.src)[: g.n_edges], np.asarray(g.dst)[: g.n_edges],
        g.n_vertices).n_edges


def test_unknown_variant_rejected():
    g, _, _ = _self_loop_graph()
    with pytest.raises(ValueError, match="unknown variant"):
        LocalEngine(g).run("triangle_count", variant="quantum")


def test_oriented_ell_invariants():
    """Each undirected edge survives orientation exactly once, rows are
    sorted/deduped, and out-degrees stay below the sqrt(2E) bound."""
    g, _, _ = _random_graph(seed=5)
    src = np.asarray(g.src)[: g.n_edges]
    dst = np.asarray(g.dst)[: g.n_edges]
    o = G.build_oriented_ell(src, dst, g.n_vertices)
    undirected = {frozenset((int(a), int(b)))
                  for a, b in zip(src, dst) if a != b}
    eu = np.asarray(o.eu)[: o.n_edges]
    ev = np.asarray(o.ev)[: o.n_edges]
    assert o.n_edges == len(undirected)
    assert {frozenset((int(a), int(b)))
            for a, b in zip(eu, ev)} == undirected
    nbr = np.asarray(o.nbr)
    assert (np.diff(nbr, axis=1) >= 0).all()          # sorted rows
    valid = nbr < g.n_vertices
    assert (np.diff(nbr, axis=1)[valid[:, 1:] & valid[:, :-1]] > 0).all()
    assert (nbr[-1] == g.n_vertices).all()            # padding-gather row
    out_deg = (nbr < g.n_vertices).sum(axis=1)
    assert out_deg.max() <= np.sqrt(2 * o.n_edges) + 1


# ------------------------------------------------------- planner routing

def _variant_plan(v, n_chips=256):
    g = P.GraphStats(v, v * 5, v * 5 * 12)
    return P.choose_plan(g, P.specs_for("triangle_count", g), n_chips)


def test_variant_selection_flips_once_at_small_v():
    """Bitset wins the interactive regime, intersect everything beyond,
    with a single flip in the low thousands of vertices."""
    vs = [300, 1_000, 3_000, 10_000, 100_000, 1_000_000]
    variants = [_variant_plan(v).variant for v in vs]
    assert variants[0] == "bitset"
    assert variants[-1] == "intersect"
    flips = sum(a != b for a, b in zip(variants, variants[1:]))
    assert flips == 1
    flip_v = vs[variants.index("intersect")]
    assert flip_v <= 100_000


def test_intersect_keeps_large_v_local():
    """The tentpole routing claim: where bitset state alone exceeds the
    local budget (V ~ 2M: ~500 GB), the planner now keeps the query on
    the local engine via the linear-memory variant instead of forcing it
    distributed-by-memory."""
    v = 2_000_000
    g = P.GraphStats(v, v * 5, v * 5 * 12)
    specs = {s.variant: s for s in P.specs_for("triangle_count", g)}
    assert P.estimate_local_cost(g, specs["bitset"]) == float("inf")
    assert P.estimate_local_cost(g, specs["intersect"]) < float("inf")
    plan = P.choose_plan(g, list(specs.values()), 256)
    assert plan.engine == "local"
    assert plan.variant == "intersect"


def test_cost_hook_uses_ceil_words():
    """Satellite fix: the bitset cost is sized with ceil(V/32) like the
    runner, not floor — V=33 needs 2 words, not 1."""
    g = P.GraphStats(33, 100, 1200)
    spec = {s.variant: s for s in P.specs_for("triangle_count", g)}
    assert spec["bitset"].state_bytes_per_vertex == 4.0 * 2


def test_single_spec_choose_plan_matches_choose_engine():
    g = P.GraphStats(1_000_000, 5_000_000, 60_000_000)
    spec = P.spec_for("pagerank", g)
    assert P.choose_plan(g, [spec], 256) == P.choose_engine(g, spec, 256)


def test_platform_plan_carries_variant_and_runs_it():
    g, src, dst = _random_graph(seed=2)
    plat = GraphPlatform(g)
    q = GraphQuery.triangle_count()
    plan = plat.plan(q)
    assert plan.variant == "bitset"              # N=250 is interactive
    r = plat.query(q)
    assert r.value == triangle_count_reference(src, dst, g.n_vertices)
    assert r.meta["variant"] == "bitset"


def test_forced_engine_repicks_variant_for_that_engine():
    g, src, dst = _random_graph(seed=2)
    plat = GraphPlatform(g, n_data=4, force_engine="distributed")
    r = plat.query(GraphQuery.triangle_count())
    assert r.engine == "distributed"
    assert r.meta["variant"] in ("bitset", "intersect")
    assert r.value == triangle_count_reference(src, dst, g.n_vertices)


# ------------------------------------------------- result-cache identity

def test_stale_id_regression_across_graph_lifetimes():
    """Two successive platforms over *distinct* graphs, the first freed
    before the second is built, sharing one result store: the second
    must never be served the dead graph's cached result (the old
    ``id()`` key would alias them whenever CPython recycled the
    address)."""
    shared = OrderedDict()
    for round_ in range(5):
        tri = GraphQuery.triangle_count()
        n = 3 + round_               # distinct content every round
        g1 = G.build_coo(np.array([0, 1, 2]), np.array([1, 2, 0]), n,
                         symmetrize=True)
        p1 = GraphPlatform(g1, result_cache=shared)
        assert p1.query(tri).value == 1
        del p1, g1
        gc.collect()
        g2 = G.build_coo(np.array([0, 1]), np.array([1, 2]), n,
                         symmetrize=True)          # path: no triangle
        p2 = GraphPlatform(g2, result_cache=shared)
        r2 = p2.query(tri)
        assert r2.value == 0, f"stale cache hit on round {round_}"
        assert r2.meta.get("cache") != "hit"
        del p2, g2
        gc.collect()


def test_reloaded_snapshot_shares_cache_entries():
    """A byte-identical reloaded graph is a result-cache *hit* through a
    shared store — the ROADMAP snapshot-sharing item."""
    shared = OrderedDict()
    src, dst = S.user_follow_graph(200, 3.0, seed=21)
    g1 = G.build_coo(src, dst, 200, symmetrize=True)
    p1 = GraphPlatform(g1, result_cache=shared)
    q = GraphQuery.connected_components(count_only=True)
    v1 = p1.query(q).value
    assert p1.cache_stats == {"hits": 0, "misses": 1}
    # reload the same snapshot: new arrays, new objects, same bytes
    g2 = G.build_coo(src.copy(), dst.copy(), 200, symmetrize=True)
    assert g2.content_digest() == g1.content_digest()
    p2 = GraphPlatform(g2, result_cache=shared)
    r2 = p2.query(q)
    assert r2.meta.get("cache") == "hit"
    assert r2.value == v1
    assert p2.cache_stats == {"hits": 1, "misses": 0}
    assert p2.local.n_runs == 0            # engine never touched


def test_content_digest_identity():
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 0])
    a = G.build_coo(src, dst, 3, symmetrize=True)
    b = G.build_coo(src, dst, 3, symmetrize=True)
    c = G.build_coo(src, dst[::-1].copy(), 3, symmetrize=True)
    assert a.content_digest() == b.content_digest()
    assert a.content_digest() != c.content_digest()
    assert a.content_digest() is a.content_digest()      # memoized
    # padding must not matter: same edges, different pad width
    d = G.build_coo(src, dst, 3, symmetrize=True, pad_multiple=2048)
    assert d.content_digest() == a.content_digest()


# ------------------------------------------------- bounded pregel jit LRU

def test_pregel_jit_cache_bounded_and_mesh_free(monkeypatch):
    from repro.core.partition import partition
    from repro.core.pregel import PregelSpec, run_pregel
    import jax.numpy as jnp
    from jax.sharding import Mesh

    monkeypatch.setattr(pregel, "JIT_CACHE_MAX", 2)
    monkeypatch.setattr(pregel, "_JIT_CACHE", OrderedDict())
    g = G.build_coo(np.array([0, 1, 2]), np.array([1, 2, 0]), 3)
    sg = partition(g, 1, 1)
    spec = PregelSpec(message=lambda s, w: s, combine="sum",
                      apply=lambda old, agg, ids, gval: agg, identity=0.0)
    for iters in (1, 2, 3, 4):
        run_pregel(spec, sg, jnp.zeros(3), max_iters=iters)
    assert len(pregel._JIT_CACHE) == 2               # bounded, LRU
    for key in pregel._JIT_CACHE:
        assert not any(isinstance(part, Mesh) for part in key)
    # a repeat is a hit: the entry moves to MRU and nothing is evicted
    before = list(pregel._JIT_CACHE)
    run_pregel(spec, sg, jnp.zeros(3), max_iters=3)
    assert list(pregel._JIT_CACHE) == [before[1], before[0]]


# ------------------------------------------------------- scale acceptance

def test_past_the_bitset_wall_local_intersect():
    """A graph whose bitset state alone (~4*ceil(V/32)*V bytes) exceeds
    LOCAL_MEM_BUDGET must still complete *locally* via the intersect
    variant, and match per-edge set-intersection oracles on a
    subsample."""
    V = 600_000
    words = -(-V // 32)
    assert 4.0 * words * V > P.LOCAL_MEM_BUDGET      # past the wall
    src, dst = S.user_follow_graph(V, 2.0, seed=9)
    g = G.build_coo(src, dst, V, symmetrize=True)
    plat = GraphPlatform(g)
    plan = plat.plan(GraphQuery.triangle_count())
    assert plan.engine == "local"
    assert plan.variant == "intersect"
    r = plat.query(GraphQuery.triangle_count())
    assert r.engine == "local"
    assert r.meta["variant"] == "intersect"
    # subsampled oracle: per-edge counts vs numpy set intersection
    o = plat.local.oriented
    from repro.kernels.ell_intersect import ell_intersect_counts
    counts = ell_intersect_counts(o)
    assert int(counts.sum()) == r.value
    eu = np.asarray(o.eu)[: o.n_edges]
    ev = np.asarray(o.ev)[: o.n_edges]
    nbr = np.asarray(o.nbr)
    rng = np.random.default_rng(0)
    for i in rng.choice(o.n_edges, 200, replace=False):
        a, b = nbr[eu[i]], nbr[ev[i]]
        want = len(np.intersect1d(a[a < V], b[b < V]))
        assert counts[i] == want
