"""Gradient compression for the slow (cross-pod) link.

The paper's hybrid-cloud story has a slow on-prem<->GCP pipe; the TPU
analogue is the cross-pod DCI, which carries only the data-parallel
gradient reduction.  Two standard compressors, both with error feedback
(the residual is re-added next step, preserving convergence):

* int8 per-tensor quantization (8x over f32, 2x over bf16 wire format)
* top-k magnitude sparsification (k as a fraction)

Applied grad -> compress -> decompress around the pod-axis reduction;
in single-host simulation this is numerically identical to compressing
the wire format, which is what tests/test_compression.py verifies
(convergence within tolerance of the uncompressed run).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "int8"        # int8 | topk | none
    topk_fraction: float = 0.05
    error_feedback: bool = True


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _topk_mask(g, frac: float):
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(jnp.float32)


def compress_grads(grads, err_state, cfg: CompressionConfig):
    """Returns (wire_grads, new_err_state, stats).

    wire_grads are the values that would cross the slow link (already
    decompressed back to f32 — compression error is thereby applied);
    err_state accumulates what was lost for next-step feedback.
    """
    if cfg.kind == "none":
        return grads, err_state, {"compression_ratio": 1.0}

    def one(g, e):
        gf = g.astype(jnp.float32)
        if cfg.error_feedback:
            gf = gf + e
        if cfg.kind == "int8":
            q, scale = _quantize_int8(gf)
            wire = _dequantize_int8(q, scale)
        elif cfg.kind == "topk":
            mask = _topk_mask(gf, cfg.topk_fraction)
            wire = gf * mask
        else:
            raise ValueError(cfg.kind)
        new_e = (gf - wire) if cfg.error_feedback else e
        return wire.astype(g.dtype), new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    wire = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    ratio = 4.0 if cfg.kind == "int8" else 1.0 / max(cfg.topk_fraction, 1e-9)
    return wire, new_err, {"compression_ratio": ratio}
