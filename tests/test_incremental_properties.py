"""Property suite for ``GraphCOO.apply_delta``'s canonicalization.

The load-bearing invariant of the whole incremental stack is that
*lineage-equal graphs are cache-equal*: a graph reached through any
sequence of deltas has the same ``content_digest`` as the same edge
set built from scratch.  Everything else (seed lookup by parent
digest, result-cache keys, the planner's incremental pricing) leans on
that identity, so this module pins it as algebra:

* delta *composition*: applying a delta edge-by-edge, in any split,
  equals applying it as one batch;
* add/remove *inversion*: removing exactly what a delta added returns
  the original digest;
* scratch *equivalence*: the digest equals ``build_coo`` over the
  edited edge list.

The core cases run unconditionally over seeded random instances (the
suite must hold the line on boxes without hypothesis); when hypothesis
is installed the same properties run again under generated edge lists.
"""
import numpy as np
import pytest

from repro.core import graph as G

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional test dep: seeded fallbacks only
    HAVE_HYPOTHESIS = False

V = 60


def _graph(rng, n_edges=120, symmetrize=False):
    src = rng.integers(0, V, n_edges)
    dst = rng.integers(0, V, n_edges)
    return G.build_coo(src, dst, V, symmetrize=symmetrize)


def _pairs(rng, n):
    return np.stack([rng.integers(0, V, n), rng.integers(0, V, n)], axis=1)


def _present_pairs(coo):
    src = np.asarray(coo.src)[: coo.n_edges]
    dst = np.asarray(coo.dst)[: coo.n_edges]
    return set(zip(src.tolist(), dst.tolist()))


def _check_batch_equals_split(coo, added):
    """One batch == any two-way split of the same added edges."""
    batch = coo.apply_delta(added=added)
    for cut in {1, len(added) // 2, len(added) - 1}:
        split = coo.apply_delta(added=added[:cut]) \
                   .apply_delta(added=added[cut:])
        assert split.content_digest() == batch.content_digest()


def _check_add_remove_roundtrip(coo, pairs):
    """Adding fresh edges then removing them restores the digest."""
    fresh = np.array([p for p in map(tuple, pairs.tolist())
                      if p not in _present_pairs(coo)
                      and (not coo.symmetric
                           or p[::-1] not in _present_pairs(coo))])
    if fresh.shape[0] == 0:
        return
    child = coo.apply_delta(added=fresh)
    back = child.apply_delta(removed=fresh)
    assert back.content_digest() == coo.content_digest()
    assert child.content_digest() != coo.content_digest()


def _check_scratch_equivalence(coo, added, removed):
    """apply_delta == build_coo over the hand-edited edge list."""
    child = coo.apply_delta(added=added, removed=removed)
    src = np.asarray(coo.src)[: coo.n_edges].astype(np.int64)
    dst = np.asarray(coo.dst)[: coo.n_edges].astype(np.int64)
    w = np.asarray(coo.w)[: coo.n_edges]
    add_s, add_d = added[:, 0], added[:, 1]
    rem_s, rem_d = removed[:, 0], removed[:, 1]
    if coo.symmetric:
        add_s, add_d = (np.concatenate([add_s, add_d]),
                        np.concatenate([add_d, add_s]))
        rem_s, rem_d = (np.concatenate([rem_s, rem_d]),
                        np.concatenate([rem_d, rem_s]))
    stride = np.int64(V + 1)
    keep = ~np.isin(src * stride + dst, rem_s * stride + rem_d)
    scratch = G.build_coo(
        np.concatenate([src[keep], add_s]),
        np.concatenate([dst[keep], add_d]), V,
        w=np.concatenate([w[keep],
                          np.ones(add_s.shape[0], np.float32)]))
    scratch.symmetric = coo.symmetric
    assert child.content_digest() == scratch.content_digest()


# ---------------------------------------------------------------------------
# Seeded deterministic instances — always run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("symmetric", [False, True],
                         ids=["directed", "symmetric"])
def test_delta_composition_seeded(seed, symmetric):
    rng = np.random.default_rng(seed)
    _check_batch_equals_split(_graph(rng, symmetrize=symmetric),
                              _pairs(rng, 12))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("symmetric", [False, True],
                         ids=["directed", "symmetric"])
def test_add_remove_roundtrip_seeded(seed, symmetric):
    rng = np.random.default_rng(100 + seed)
    _check_add_remove_roundtrip(_graph(rng, symmetrize=symmetric),
                                _pairs(rng, 20))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("symmetric", [False, True],
                         ids=["directed", "symmetric"])
def test_scratch_equivalence_seeded(seed, symmetric):
    rng = np.random.default_rng(200 + seed)
    coo = _graph(rng, symmetrize=symmetric)
    src = np.asarray(coo.src)[: coo.n_edges]
    dst = np.asarray(coo.dst)[: coo.n_edges]
    removed = np.stack([src[:4], dst[:4]], axis=1).astype(np.int64)
    _check_scratch_equivalence(coo, _pairs(rng, 10), removed)


# ---------------------------------------------------------------------------
# Hypothesis variants — same properties, generated instances
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    edge_lists = st.lists(
        st.tuples(st.integers(0, V - 1), st.integers(0, V - 1)),
        min_size=2, max_size=24).map(lambda e: np.asarray(e, np.int64))

    @given(base=edge_lists, added=edge_lists,
           symmetric=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_delta_composition_generated(base, added, symmetric):
        coo = G.build_coo(base[:, 0], base[:, 1], V,
                          symmetrize=symmetric)
        _check_batch_equals_split(coo, added)

    @given(base=edge_lists, pairs=edge_lists,
           symmetric=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_add_remove_roundtrip_generated(base, pairs, symmetric):
        coo = G.build_coo(base[:, 0], base[:, 1], V,
                          symmetrize=symmetric)
        _check_add_remove_roundtrip(coo, pairs)

    @given(base=edge_lists, added=edge_lists,
           symmetric=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_scratch_equivalence_generated(base, added, symmetric):
        coo = G.build_coo(base[:, 0], base[:, 1], V,
                          symmetrize=symmetric)
        removed = base[: len(base) // 2]
        _check_scratch_equivalence(coo, added, removed)
