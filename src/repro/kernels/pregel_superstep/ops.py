"""Public wrapper for the fused superstep kernel.

Handles padding to TPU tile alignment (rows -> block multiple, K -> 128
lanes), routes to interpret mode on CPU hosts, and falls back to the
pure-jnp reference — which is itself fused at the XLA level (one
gather+reduce, no [E] tensor) — whenever the Pallas kernel's
preconditions don't hold:

  * the gather source exceeds the VMEM byte budget,
  * vertex state has trailing dims (fused-batch [V, B] programs),
  * the edge program is not shape-polymorphic on a probe tile.

Both paths share one signature so engines flip implementations freely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pregel_superstep.kernel import superstep_pallas
from repro.kernels.pregel_superstep.ref import superstep_ref, _fill_value

_LANE = 128
# Bytes of gather source (vertex state) the kernel keeps VMEM-resident.
VMEM_X_BUDGET_BYTES = 16 * 1024 * 1024


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _round_up(n, m):
    return ((n + m - 1) // m) * m


def _probe(message, x, w):
    """Shape/dtype of the edge program on a (1, 1) tile — checks the
    elementwise contract and determines the message dtype without
    running anything."""
    try:
        return jax.eval_shape(
            message,
            jax.ShapeDtypeStruct((1, 1) + x.shape[1:], x.dtype),
            jax.ShapeDtypeStruct((1, 1), w.dtype))
    except Exception:
        return None


def fused_superstep(nbr, mask, w, x, *, message, op: str, identity,
                    message_dtype=None, use_pallas: bool = True,
                    block_rows: int = 512, interpret=None):
    """One fused superstep: agg over masked message(x[nbr], w).

    Pallas path for 1-D state within the VMEM budget; jnp reference
    otherwise.  Bit-identical between the two for min/max monoids (and
    for integer-valued sums) — the property the frontier/fused variants
    contract relies on.
    """
    V, K = nbr.shape
    probe = _probe(message, x, w)
    pallas_ok = (
        use_pallas
        and x.ndim == 1
        and probe is not None
        and probe.shape == (1, 1)
        and x.size * x.dtype.itemsize <= VMEM_X_BUDGET_BYTES
    )
    if not pallas_ok:
        return superstep_ref(nbr, mask, w, x, message=message, op=op,
                             identity=identity,
                             message_dtype=message_dtype)
    out_dtype = message_dtype if message_dtype is not None else probe.dtype
    vp = _round_up(max(V, block_rows), block_rows)
    kp = _round_up(K, _LANE)
    if (vp, kp) != (V, K):
        nbr = jnp.pad(nbr, ((0, vp - V), (0, kp - K)))
        mask = jnp.pad(mask, ((0, vp - V), (0, kp - K)))
        w = jnp.pad(w, ((0, vp - V), (0, kp - K)))
    y = superstep_pallas(
        nbr, mask, w, x, message=message, op=op,
        fill=_fill_value(op, identity), message_dtype=message_dtype,
        out_dtype=jnp.dtype(out_dtype).name, block_rows=block_rows,
        interpret=_on_cpu() if interpret is None else interpret)
    return y[:V]


def fused_superstep_ref(nbr, mask, w, x, *, message, op: str, identity,
                        message_dtype=None, **_):
    """Reference path under the kernel signature."""
    return superstep_ref(nbr, mask, w, x, message=message, op=op,
                         identity=identity, message_dtype=message_dtype)
