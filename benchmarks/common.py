"""Shared benchmark utilities: stable timing on a busy single-core box."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall seconds; blocks on device results."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def time_host(fn, *args, warmup: int = 0, iters: int = 3, **kw):
    """Median wall seconds for host (numpy) code."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
