"""Whisper-style encoder-decoder backbone.

Frontend STUB per the assignment: ``input_specs`` supplies precomputed
audio frame embeddings [B, 1500, d_model] (the conv+mel stack is out of
scope).  Encoder: bidirectional self-attention, sinusoidal positions.
Decoder: causal self-attention (KV cache) + cross-attention into the
encoder output (cross K/V computed once at prefill and cached).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.models import layers as L
from repro.models.transformer import DenseLM, dp_axes


def _sinusoid(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


class EncDecLM(DenseLM):
    family = "encdec"

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kd, kc, kx = jax.random.split(key, 4)
        params = L.init_embed(kx, cfg)
        params["layers"] = self._init_layers(kd)          # decoder stack
        params["enc_layers"] = self._init_enc_layers(ke)
        params["cross"] = self._init_cross_layers(kc)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return params

    def _init_enc_layers(self, key) -> dict:
        cfg = self.cfg
        ka, km = jax.random.split(key)
        lc = cfg.n_encoder_layers
        return {
            "ln1": jnp.zeros((lc, cfg.d_model), jnp.float32),
            "ln2": jnp.zeros((lc, cfg.d_model), jnp.float32),
            "attn": L.init_attn(ka, cfg, layers=lc),
            "mlp": L.init_mlp(km, cfg, layers=lc),
        }

    def _init_cross_layers(self, key) -> dict:
        cfg = self.cfg
        lc = cfg.n_layers
        p = L.init_attn(key, cfg, layers=lc)
        p["ln"] = jnp.zeros((lc, cfg.d_model), jnp.float32)
        return p

    # ------------------------------------------------------------ encoder
    def encode(self, params, audio_embeds):
        cfg = self.cfg
        x = audio_embeds.astype(self.dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(self.dtype)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(carry, p_l):
            carry = self._constrain_act(carry)
            h = L.rms_norm(carry, p_l["ln1"])
            q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
            o = L.attention_output(q, k, v, pos, pos, cfg.attn_impl,
                                   causal=False, window=0,
                                   chunk=cfg.attn_chunk)
            carry = carry + L.out_proj(p_l["attn"], o, carry.dtype)
            h2 = L.rms_norm(carry, p_l["ln2"])
            carry = carry + L.mlp_apply(p_l["mlp"], h2, cfg.mlp_act)
            return carry, None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = lax.scan(body, x, params["enc_layers"])
        return L.rms_norm(x, params["enc_norm"])

    # ----------------------------------------------- decoder (train path)
    def _decoder(self, params, tokens, enc_out, collect_kv=False):
        cfg = self.cfg
        x = L.embed_tokens(params, tokens, cfg, self.dtype)
        x = x + _sinusoid(tokens.shape[1], cfg.d_model).astype(self.dtype)
        qpos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

        def body(carry, xs):
            p_l, c_l = xs
            carry = self._constrain_act(carry)
            h = L.rms_norm(carry, p_l["ln1"])
            q, k, v = L.qkv_proj(p_l["attn"], h, cfg)
            o = L.attention_output(q, k, v, qpos, qpos, cfg.attn_impl,
                                   causal=True, window=0,
                                   chunk=cfg.attn_chunk)
            carry = carry + L.out_proj(p_l["attn"], o, carry.dtype)
            # cross attention
            hc = L.rms_norm(carry, c_l["ln"])
            qc, kc, vc = (hc @ c_l["wq"].astype(carry.dtype),
                          enc_out @ c_l["wk"].astype(carry.dtype),
                          enc_out @ c_l["wv"].astype(carry.dtype))
            b, s, _ = hc.shape
            qc = qc.reshape(b, s, cfg.n_heads, cfg.d_head)
            kc = kc.reshape(b, -1, cfg.n_kv_heads, cfg.d_head)
            vc = vc.reshape(b, -1, cfg.n_kv_heads, cfg.d_head)
            oc = L.attention_output(qc, kc, vc, qpos, epos, cfg.attn_impl,
                                    causal=False, window=0,
                                    chunk=cfg.attn_chunk)
            carry = carry + L.out_proj(c_l, oc, carry.dtype)
            h2 = L.rms_norm(carry, p_l["ln2"])
            carry = carry + L.mlp_apply(p_l["mlp"], h2, cfg.mlp_act)
            return carry, ((k, v, kc, vc) if collect_kv else None)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, kvs = lax.scan(body, x, (params["layers"], params["cross"]))
        return x, kvs

    def forward(self, params, batch):
        enc_out = self.encode(params, batch["audio_embeds"])
        x, _ = self._decoder(params, batch["tokens"], enc_out)
        return L.unembed(params, x, self.cfg)

    def loss(self, params, batch, vocab_chunk: int = 8):
        enc_out = self.encode(params, batch["audio_embeds"])
        x, _ = self._decoder(params, batch["tokens"], enc_out)
        targets = batch["labels"]
        b, s = targets.shape
        nc = vocab_chunk if s % vocab_chunk == 0 else 1
        xc = x.reshape(b, nc, s // nc, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, s // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk_loss(carry, xs):
            xx, tt = xs
            logits = L.unembed(params, xx, self.cfg)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tt, 0)[..., None], axis=-1)[..., 0]
            valid = (tt >= 0)
            ce = jnp.where(valid, logz - gold, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        (tot, cnt), _ = lax.scan(chunk_loss, (jnp.float32(0), jnp.int32(0)),
                                 (xc, tc))
        loss = tot / jnp.maximum(cnt, 1)
        return loss, {"loss": loss, "tokens": cnt}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch_size: int, cache_len: int) -> dict:
        cfg = self.cfg
        base = super().init_cache(batch_size, cache_len)
        enc_s = cfg.encoder_seq
        base["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch_size, enc_s, cfg.n_kv_heads, cfg.d_head),
            self.dtype)
        base["cross_v"] = jnp.zeros_like(base["cross_k"])
        return base

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        enc_out = self.encode(params, batch["audio_embeds"])
        x, kvs = self._decoder(params, tokens, enc_out, collect_kv=True)
        k, v, ck, cv = kvs
        logits = L.unembed(params, x[:, -1:, :], cfg)
        pad = cache_len - s
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return logits, {"k": k.astype(self.dtype), "v": v.astype(self.dtype),
                        "cross_k": ck.astype(self.dtype),
                        "cross_v": cv.astype(self.dtype)}

    def decode_step(self, params, tokens, cache, index):
        cfg = self.cfg
        x = L.embed_tokens(params, tokens, cfg, self.dtype)
        x = x + _sinusoid_at(index, cfg.d_model, self.dtype)
        epos = jnp.arange(cfg.encoder_seq, dtype=jnp.int32)

        def body(carry, xs):
            p_l, c_l, k_c, v_c, ck_c, cv_c = xs
            h = L.rms_norm(carry, p_l["ln1"])
            q, k1, v1 = L.qkv_proj(p_l["attn"], h, cfg)
            k_c = lax.dynamic_update_slice_in_dim(
                k_c, k1.astype(k_c.dtype), index, axis=1)
            v_c = lax.dynamic_update_slice_in_dim(
                v_c, v1.astype(v_c.dtype), index, axis=1)
            o = L.attn_decode(q, k_c, v_c, index, causal=True)
            carry = carry + L.out_proj(p_l["attn"], o, carry.dtype)
            hc = L.rms_norm(carry, c_l["ln"])
            b = hc.shape[0]
            qc = (hc @ c_l["wq"].astype(carry.dtype)).reshape(
                b, 1, cfg.n_heads, cfg.d_head)
            oc = L.attn_decode(qc, ck_c, cv_c, cfg.encoder_seq - 1,
                               causal=False)
            carry = carry + L.out_proj(c_l, oc, carry.dtype)
            h2 = L.rms_norm(carry, p_l["ln2"])
            carry = carry + L.mlp_apply(p_l["mlp"], h2, cfg.mlp_act)
            return carry, (k_c, v_c)

        x, (k, v) = lax.scan(
            body, x, (params["layers"], params["cross"], cache["k"],
                      cache["v"], cache["cross_k"], cache["cross_v"]))
        logits = L.unembed(params, x, cfg)
        return logits, {"k": k, "v": v, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}

    # ------------------------------------------------------- shardings
    def param_spec(self) -> dict:
        spec = super().param_spec()
        fs = self._fsdp_ax()
        spec["enc_layers"] = {
            "ln1": P(None, None), "ln2": P(None, None),
            "attn": {
                "wq": P(None, fs, "model"), "wk": P(None, fs, "model"),
                "wv": P(None, fs, "model"), "wo": P(None, "model", fs),
            },
            "mlp": {
                "w_gate": P(None, fs, "model"),
                "w_up": P(None, fs, "model"),
                "w_down": P(None, "model", fs),
            },
        }
        spec["cross"] = {
            "ln": P(None, None),
            "wq": P(None, fs, "model"), "wk": P(None, fs, "model"),
            "wv": P(None, fs, "model"), "wo": P(None, "model", fs),
        }
        spec["enc_norm"] = P(None)
        return spec

    def cache_spec(self, multi_pod: bool = True) -> dict:
        dp = dp_axes(multi_pod)
        base = super().cache_spec(multi_pod)
        base["cross_k"] = P(None, dp, None, None, "model")
        base["cross_v"] = P(None, dp, None, None, "model")
        return base

    def input_specs(self, shape: ShapeSpec, multi_pod: bool = True) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dp = dp_axes(multi_pod)
        audio = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                     jnp.float32)
        a_spec = P(dp, None, None)
        base = super().input_specs(shape, multi_pod)
        if shape.kind in ("train", "prefill"):
            base["arrays"]["audio_embeds"] = audio
            base["specs"]["audio_embeds"] = a_spec
        return base


def _sinusoid_at(index, d, dtype):
    half = d // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    ang = index.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(dtype)
