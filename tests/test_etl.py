"""ETL + graph-representation tests.  The hypothesis property tests on
the structural invariants live in ``test_etl_properties.py`` (skipped
when the optional ``hypothesis`` dependency is absent).
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.data import synthetic as S
from repro.data.etl import (GraphETL, Snapshot, SnapshotStore, ResultSink,
                            max_adjacent_nodes_sweep)


def test_build_coo_sorted_dedup():
    src = np.array([3, 1, 1, 2, 3], dtype=np.int64)
    dst = np.array([0, 2, 2, 1, 0], dtype=np.int64)
    g = G.build_coo(src, dst, 4)
    assert g.n_edges == 3                      # dedup'd
    d = np.asarray(g.dst)[:g.n_edges]
    assert (np.diff(d) >= 0).all()             # dst-sorted


def test_build_ell_cap_and_loss():
    # vertex 0 has 5 in-edges; cap 3 drops 2
    src = np.array([1, 2, 3, 4, 5, 1])
    dst = np.array([0, 0, 0, 0, 0, 2])
    ell = G.build_ell(src, dst, 6, max_degree=3)
    assert ell.n_edges == 4
    assert ell.n_edges_total == 6
    assert ell.lost_fraction == pytest.approx(2 / 6)


def test_csr_neighbors():
    src = np.array([0, 0, 1, 2])
    dst = np.array([1, 2, 2, 0])
    csr = G.build_csr(src, dst, 3)
    ip = np.asarray(csr.indptr)
    idx = np.asarray(csr.indices)
    assert set(idx[ip[0]:ip[1]].tolist()) == {1, 2}
    assert set(idx[ip[1]:ip[2]].tolist()) == {2}


def test_table1_sweep_monotonic():
    """Table I reproduction: loss % decreases as the cap rises, reaching
    exactly 0 at cap >= max degree (paper: 0% at cap 10M)."""
    u, i = S.safety_bipartite_graph(2000, 500, seed=4)
    caps = [1, 4, 16, 64, 256, 100000]
    rows = max_adjacent_nodes_sweep(u, i, 500, caps)
    losses = [r["lost_percentage"] for r in rows]
    assert all(a >= b for a, b in zip(losses, losses[1:]))
    assert losses[-1] == 0.0
    assert losses[0] > 10.0                    # tight cap loses real data


def test_snapshot_store_and_etl(tmp_path):
    store = SnapshotStore(str(tmp_path / "snaps"))
    rng = np.random.default_rng(0)
    for day in ["d0", "d1"]:
        store.write(Snapshot(day, rng.integers(0, 100, 500),
                             rng.integers(0, 100, 500)))
    assert store.list() == ["d0", "d1"]
    etl = GraphETL(max_adjacent_nodes=16)
    snaps = [store.read(n) for n in store.list()]
    coo, ell, report = etl.build(snaps, n_vertices=100)
    assert report.n_edges_in == 1000
    assert report.n_edges_deduped <= 1000
    assert coo.n_vertices == 100
    assert ell is not None and ell.max_degree == 16
    assert 0.0 <= report.lost_fraction < 1.0
    assert len(report.content_hash) == 16


def test_result_sink_roundtrip(tmp_path):
    sink = ResultSink(str(tmp_path / "out"))
    sink.write("cc_labels", {"labels": np.arange(10)}, {"algo": "cc"})
    arrays, manifest = sink.read("cc_labels")
    np.testing.assert_array_equal(arrays["labels"], np.arange(10))
    assert manifest["meta"]["algo"] == "cc"


def test_degree_stats():
    from repro.core.algorithms.degrees import degree_stats
    src, dst = S.user_follow_graph(500, 4.0, seed=1)
    g = G.build_coo(src, dst, 500)
    stats = degree_stats(g)
    assert stats["n_vertices"] == 500
    assert stats["max_in_degree"] >= stats["mean_degree"]


def test_similarity():
    from repro.core.algorithms.similarity import (jaccard_similarity,
                                                  common_neighbors)
    import jax.numpy as jnp
    # triangle 0-1-2 plus pendant 3: N(0)={1,2}, N(1)={0,2}, N(2)={0,1,3}
    src = np.array([0, 0, 1, 1, 2, 2, 2, 3])
    dst = np.array([1, 2, 0, 2, 0, 1, 3, 2])
    ell = G.build_ell(src, dst, 4, max_degree=4, direction="out")
    u = jnp.array([0])
    v = jnp.array([1])
    assert int(common_neighbors(ell, u, v)[0]) == 1     # {2}
    jac = float(jaccard_similarity(ell, u, v)[0])
    assert jac == pytest.approx(1 / 3)                   # |{2}| / |{0,1,2}|


def test_local_engine_pallas_path():
    """LocalEngine with use_pallas=True routes SpMV through the Pallas
    kernel (interpret on CPU) and matches the default path."""
    from repro.core.engines import LocalEngine
    from repro.core import graph as G
    from repro.data import synthetic as S
    import numpy as np
    src, dst = S.user_follow_graph(300, 4.0, seed=8)
    g = G.build_coo(src, dst, 300, symmetrize=True)
    a = LocalEngine(g, use_pallas=False).connected_components()
    b = LocalEngine(g, use_pallas=True).connected_components()
    np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))
