"""HITS hub/authority scores (Kleinberg) — the registry's one-file
extension example.

This module is the proof of the platform's extension contract: adding
it registers a new algorithm that both engines run, the planner prices
and ``GraphQuery.of("hits", ...)`` serves — with **zero edits** to
``engines.py``, ``planner.py`` or ``query.py`` (``registry.ensure_loaded``
auto-discovers it).

Formulation.  HITS iterates

    authority[v] <- sum_{(u, v) in E} hub[u]
    hub[u]       <- sum_{(u, v) in E} authority[v]

to the principal eigenvectors of ``A^T A`` / ``A A^T``.  The BSP engine
aggregates along *in*-edges only, so we run the iteration on the
**doubled role graph**: 2V vertices where vertex ``u`` is u's hub role
and vertex ``V + v`` is v's authority role, and every directed edge
``(u, v)`` becomes

    u     -> V + v      (hubs feed authorities)
    V + v -> u          (authorities feed hubs)

One superstep on this graph performs one simultaneous HITS update for
both score vectors.  The per-half L2 renormalization runs *inside* the
superstep: ``global_value`` (computed over the **new** aggregate —
``global_over_agg``) reduces the fresh hub/authority sums to their
squared norms, and ``apply`` divides each half by its own norm.  The
whole iteration — update, normalize, convergence test — is therefore a
single XLA while-loop like every other fixpoint algorithm here, with no
host round-trips (the old formulation broke the loop every 2 supersteps
to renormalize on the host).  Scores are returned L2-normalized.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, run_pregel


# bounded: a rolling catalog of snapshot sizes must not accrete specs
# (and, transitively, distinct jit-cache keys) without limit
@lru_cache(maxsize=64)
def _hits_spec(n_vertices: int, tol: float) -> PregelSpec:
    """One simultaneous (hub, authority) update with in-loop per-half
    L2 normalization; converged when no score moved by ``tol``."""
    V = n_vertices

    def global_value(agg, ids, valid):
        # squared L2 norm of each half of the *new* aggregate
        sq = jnp.where(valid, agg * agg, 0.0)
        is_hub = ids < V
        return jnp.stack([jnp.sum(jnp.where(is_hub, sq, 0.0)),
                          jnp.sum(jnp.where(is_hub, 0.0, sq))])

    def apply(old, agg, ids, gval):
        hub_norm = jnp.maximum(jnp.sqrt(gval[0]), 1e-12)
        auth_norm = jnp.maximum(jnp.sqrt(gval[1]), 1e-12)
        return jnp.where(ids < V, agg / hub_norm, agg / auth_norm)

    def halt(old, new, valid):
        return jnp.all(jnp.where(valid, jnp.abs(new - old), 0.0) < tol)

    return PregelSpec(
        message=lambda x, w: x * w,
        combine="sum",
        apply=apply,
        identity=0.0,
        halt=halt,
        global_value=global_value,
        global_over_agg=True,
    )


def role_graph(g: G.GraphCOO) -> G.GraphCOO:
    """The 2V-vertex doubled graph: (u, v) -> u→(V+v) and (V+v)→u."""
    V = g.n_vertices
    src = np.asarray(g.src)[: g.n_edges]
    dst = np.asarray(g.dst)[: g.n_edges]
    w = np.asarray(g.w)[: g.n_edges]
    return G.build_coo(
        np.concatenate([src, dst + V]), np.concatenate([dst + V, src]),
        2 * V, w=np.concatenate([w, w]), dedup=False)


def hits(
    g: G.GraphCOO,
    max_iters: int = 50,
    tol: float = 1e-6,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``({'hubs': [V], 'authorities': [V]}, iterations)`` with
    each score vector L2-normalized (all-zero when the graph has no
    edges feeding that role).  The whole iteration — including the
    per-half renormalization and the ``tol`` convergence test — is one
    ``run_pregel`` call, i.e. one XLA program."""
    V = g.n_vertices
    if sharded is None:
        sharded = partition(role_graph(g), n_data, n_model)
    init = jnp.zeros(sharded.n_pad, jnp.float32).at[: 2 * V].set(
        1.0 / np.sqrt(max(V, 1)))
    state, iters = run_pregel(_hits_spec(V, float(tol)), sharded, init,
                              max_iters, mesh=mesh)
    return {"hubs": state[:V], "authorities": state[V: 2 * V]}, int(iters)


def hits_reference(src, dst, n_vertices: int, max_iters: int = 50,
                   tol: float = 1e-6):
    """Numpy oracle mirroring the device schedule exactly (simultaneous
    updates, per-superstep renormalization, per-superstep tol check)."""
    V = n_vertices
    a_mat = np.zeros((V, V))
    a_mat[np.asarray(src), np.asarray(dst)] = 1.0

    def unit(x):
        return x / max(np.linalg.norm(x), 1e-12)

    h = np.full(V, 1.0 / np.sqrt(max(V, 1)))
    a = np.full(V, 1.0 / np.sqrt(max(V, 1)))
    iters = 0
    while iters < max_iters:
        nh, na = unit(a_mat @ a), unit(a_mat.T @ h)
        iters += 1
        converged = (np.max(np.abs(nh - h), initial=0.0) < tol
                     and np.max(np.abs(na - a), initial=0.0) < tol)
        h, a = nh, na
        if converged:
            break
    return {"hubs": h.astype(np.float32),
            "authorities": a.astype(np.float32)}, iters


# ------------------------------------------------------------ registration

def _engine_run(eng, max_iters, tol):
    """Registry runner: the doubled role graph's shards are derived
    state, packed once per engine and reused across queries."""
    key = "hits/sharded"
    if key not in eng.cache:
        eng.cache[key] = partition(role_graph(eng.coo), eng.n_data,
                                   eng.n_model)
    return hits(eng.coo, max_iters=max_iters, tol=tol, mesh=eng.mesh,
                sharded=eng.cache[key])


def _warm_start(eng, params, seed):
    """Restart the power iteration from an ancestor snapshot's converged
    hub/authority vectors, packed into the doubled role-graph layout.
    The iteration converges to the principal eigenvectors from any
    positive start, so the answer matches the cold run within ``tol``
    with fewer iterations.  Declines on a malformed or degenerate seed
    (a near-zero half would pin the iteration at zero)."""
    val = getattr(seed, "value", seed)
    if not isinstance(val, dict) \
            or "hubs" not in val or "authorities" not in val:
        return None
    V = eng.coo.n_vertices
    h = np.asarray(val["hubs"], dtype=np.float32)
    a = np.asarray(val["authorities"], dtype=np.float32)
    if h.ndim != 1 or a.ndim != 1 or V == 0:
        return None
    key = "hits/sharded"
    if key not in eng.cache:
        eng.cache[key] = partition(role_graph(eng.coo), eng.n_data,
                                   eng.n_model)
    sharded = eng.cache[key]
    base = np.float32(1.0 / np.sqrt(max(V, 1)))
    init = np.zeros(sharded.n_pad, dtype=np.float32)
    init[: 2 * V] = base                  # new vertices: uniform prior
    n_h, n_a = min(h.shape[0], V), min(a.shape[0], V)
    init[:n_h] = h[:n_h]
    init[V: V + n_a] = a[:n_a]
    if (np.linalg.norm(init[:V]) < 1e-6
            or np.linalg.norm(init[V: 2 * V]) < 1e-6
            or not np.isfinite(init).all()):
        return None
    state, iters = run_pregel(
        _hits_spec(V, float(params["tol"])), sharded, jnp.asarray(init),
        params["max_iters"], mesh=eng.mesh)
    return ({"hubs": state[:V], "authorities": state[V: 2 * V]},
            int(iters))


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    # power iteration on the doubled edge set; two tables out
    iters = min(30, params.get("max_iters") or 30)
    return P.QuerySpec("hits", 1 if count_only else 2 * g.n_vertices,
                       iterations=iters, state_bytes_per_vertex=8.0,
                       edge_bytes_factor=2.0)


R.register(R.AlgorithmDef(
    name="hits",
    run=_engine_run,
    params=(
        R.Param("max_iters", 50, check=lambda n: n >= 1, normalize=int),
        R.Param("tol", 1e-6, check=lambda t: t > 0.0, normalize=float),
    ),
    cost=_cost,
    example_params={},
    warm_start=_warm_start,
    doc="HITS hub/authority scores via the doubled role graph.",
))
