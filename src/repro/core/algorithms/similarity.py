"""Node similarity (common-neighbors / Jaccard) on the ELL layout.

The paper lists "node similarity" and "topic similarity" among the jobs
teams kept re-implementing.  On the ELL layout a similarity query for a
batch of (u, v) pairs is two row gathers and one masked intersection —
O(K^2) per pair with K = MaxAdjacentNodes, fully vectorized.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import graph as G


@partial(jax.jit, static_argnames=())
def _row_intersection_counts(nbr_u, mask_u, nbr_v, mask_v):
    """[B, K] rows -> |N(u) ∩ N(v)| per batch element."""
    eq = (nbr_u[:, :, None] == nbr_v[:, None, :])
    eq &= mask_u[:, :, None] & mask_v[:, None, :]
    return jnp.sum(eq, axis=(1, 2))


def common_neighbors(ell: G.GraphELL, u: jax.Array, v: jax.Array):
    """Common-neighbor counts for pairs (u[i], v[i])."""
    return _row_intersection_counts(
        ell.nbr[u], ell.mask[u], ell.nbr[v], ell.mask[v])


def jaccard_similarity(ell: G.GraphELL, u: jax.Array, v: jax.Array):
    """|N(u) ∩ N(v)| / |N(u) ∪ N(v)| for pairs (u[i], v[i])."""
    inter = common_neighbors(ell, u, v).astype(jnp.float32)
    du = jnp.sum(ell.mask[u], axis=1).astype(jnp.float32)
    dv = jnp.sum(ell.mask[v], axis=1).astype(jnp.float32)
    union = du + dv - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)
