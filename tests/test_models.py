"""Per-architecture smoke tests (reduced configs of the same family):
one forward + loss + one optimizer step on CPU, asserting output shapes
and finiteness; decode/prefill consistency for every family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs, reduced_config
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step, init_train_state

ARCHS = list_archs()
S = 16


def make_batch(cfg, rng, b=2, s=S):
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.prefix_len, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)

    logits = model.forward(model.init(jax.random.PRNGKey(0)), batch)
    expect_s = S if cfg.family != "vlm" else S
    assert logits.shape[0] == 2 and logits.shape[1] == expect_s
    assert logits.shape[2] == cfg.padded_vocab
    assert bool(jnp.isfinite(
        jnp.where(jnp.isneginf(logits), 0.0, logits)).all())

    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(peak_lr=1e-3)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))), state.params, 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(reduced_config(get_config(arch)),
                              attn_impl="ref",
                              capacity_factor=100.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)
    tok = batch["tokens"]
    full = model.forward(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = tok[:, :S - 1]
    cache_len = S + (cfg.prefix_len if cfg.family == "vlm" else 0)
    last, cache = model.prefill(params, pre_batch, cache_len=cache_len)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, S - 2]),
                               rtol=2e-4, atol=2e-4)
    idx = S - 1 + (cfg.prefix_len if cfg.family == "vlm" else 0)
    lg, _ = model.decode_step(params, tok[:, S - 1:S], cache, jnp.int32(idx))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_matches_param_tree(arch):
    """Every param leaf must have a PartitionSpec of matching rank."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    spec = model.param_spec()
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = {jax.tree_util.keystr(k): v for k, v in
              jax.tree_util.tree_flatten_with_path(
                  spec, is_leaf=lambda x: isinstance(
                      x, jax.sharding.PartitionSpec))[0]}
    for key, leaf in flat_p:
        ks = jax.tree_util.keystr(key)
        assert ks in flat_s, f"missing spec for {ks}"
        sp = flat_s[ks]
        assert len(sp) <= len(leaf.shape), f"spec rank mismatch at {ks}"


def test_full_config_param_counts():
    """Full (non-reduced) configs hit their nameplate parameter counts."""
    expected = {
        "mistral_large_123b": (110e9, 135e9),
        "gemma2_2b": (2.0e9, 3.3e9),
        "smollm_360m": (0.30e9, 0.45e9),
        "granite_8b": (7e9, 9e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "dbrx_132b": (120e9, 140e9),
        "xlstm_125m": (0.1e9, 0.2e9),
        "hymba_1p5b": (1.2e9, 2.2e9),
        "whisper_large_v3": (1.2e9, 2.0e9),
        "paligemma_3b": (2.2e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}-{hi/1e9}]"


def test_gemma2_local_global_alternation():
    from repro.models.layers import layer_windows
    cfg = get_config("gemma2_2b")
    w = np.asarray(layer_windows(cfg))
    assert w[0] == 4096 and w[1] == 0 and w[2] == 4096  # local/global


def test_hymba_three_global_layers():
    from repro.models.layers import layer_windows
    cfg = get_config("hymba_1p5b")
    w = np.asarray(layer_windows(cfg))
    assert (w == 0).sum() == 3
    assert w[0] == 0 and w[15] == 0 and w[31] == 0
