"""Whisper-large-v3 backbone [arXiv:2212.04356]: encoder-decoder audio.

32L(dec) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866, plus a
32-layer encoder over 1500 audio frames.  The conv/mel frontend is a
STUB per the assignment: input_specs() supplies precomputed frame
embeddings (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab_size=51866,
    n_encoder_layers=32,
    encoder_seq=1500,
    mlp_act="gelu",
    tie_embeddings=True,
)
