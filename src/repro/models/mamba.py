"""Selective SSM (Mamba/S6) mixer — the SSM half of Hymba's hybrid heads.

Chunked selective scan: outer ``lax.scan`` over time chunks carries the
recurrent state [B, di, N]; inside a chunk the linear recurrence
``h_t = a_t * h_{t-1} + b_t`` runs as an associative scan.  Per-chunk
materialization is [B, ck, di, N] — with di sharded over the model axis
this stays inside the activation budget at train_4k, while a full-length
associative scan would not (the reason real Mamba ships a fused kernel;
the chunking is the TPU-idiomatic equivalent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def mamba_init(key, cfg, layers: int) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = max(1, d // 16)              # dt low-rank
    kw = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    shp = (lambda *s: (layers,) + s)
    return {
        "w_in": jax.random.normal(ks[0], shp(d, 2 * di), jnp.float32) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], shp(kw, di), jnp.float32) * 0.2,
        "w_b": jax.random.normal(ks[2], shp(di, n), jnp.float32) * di ** -0.5,
        "w_c": jax.random.normal(ks[3], shp(di, n), jnp.float32) * di ** -0.5,
        "w_dt1": jax.random.normal(ks[4], shp(di, r), jnp.float32) * di ** -0.5,
        "w_dt2": jax.random.normal(ks[5], shp(r, di), jnp.float32) * r ** -0.5,
        "dt_bias": jnp.zeros(shp(di), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (layers, di, n))),
        "d_skip": jnp.ones(shp(di), jnp.float32),
        "w_out": jax.random.normal(ks[6], shp(di, d), jnp.float32)
                 * di ** -0.5 / max(cfg.n_layers, 1) ** 0.5,
    }


def _causal_conv(x, conv_w, conv_state=None):
    """x [B,S,di]; conv_w [K,di] depthwise. conv_state [B,K-1,di] for
    decode continuity; returns (y, new_state)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # [B, S+K-1, di]
    y = sum(xp[:, i:i + x.shape[1], :] * conv_w[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return y, new_state


def _ssm_inputs(p, xc, dtype):
    """Per-step SSM coefficients from the (conv'd) input."""
    xf = xc.astype(jnp.float32)
    bt = xf @ p["w_b"].astype(jnp.float32)            # [B,S,N]
    ct = xf @ p["w_c"].astype(jnp.float32)            # [B,S,N]
    dt = jax.nn.softplus(
        (xf @ p["w_dt1"].astype(jnp.float32)) @ p["w_dt2"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))           # [B,S,di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))      # [di,N]
    return bt, ct, dt, a


def mamba_mixer(p, x, cfg, chunk: int = 256):
    """Training/prefill path. x [B,S,D] -> (y [B,S,D], final_state, conv_state)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_ = x.dtype
    xz = x @ p["w_in"].astype(dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(x_in, p["conv_w"].astype(dt_))
    xc = jax.nn.silu(xc)
    bt, ct, dt, a = _ssm_inputs(p, xc, dt_)

    ck = min(chunk, s)
    nck = s // ck if s % ck == 0 else 1
    ck = s // nck
    xcr = xc.astype(jnp.float32).reshape(b, nck, ck, di)
    btr = bt.reshape(b, nck, ck, n)
    ctr = ct.reshape(b, nck, ck, n)
    dtr = dt.reshape(b, nck, ck, di)

    @jax.checkpoint
    def chunk_step(h, xs):
        xck, bck, cck, dck = xs                       # [B,ck,*]
        a_bar = jnp.exp(dck[..., None] * a)           # [B,ck,di,N]
        b_bar = (dck * xck)[..., None] * bck[:, :, None, :]

        def assoc(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_all, b_all = lax.associative_scan(assoc, (a_bar, b_bar), axis=1)
        hs = a_all * h[:, None] + b_all               # [B,ck,di,N]
        y = jnp.einsum("bsdn,bsn->bsd", hs, cck)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    hT, ys = lax.scan(chunk_step, h0,
                      (xcr.transpose(1, 0, 2, 3), btr.transpose(1, 0, 2, 3),
                       ctr.transpose(1, 0, 2, 3), dtr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(dt_) * jax.nn.silu(z))
    return y @ p["w_out"].astype(dt_), hT, conv_state


def mamba_decode(p, x, cfg, ssm_state, conv_state):
    """Single-token path. x [B,1,D]; ssm_state [B,di,N]; conv_state
    [B,K-1,di] -> (y [B,1,D], new_ssm, new_conv)."""
    dt_ = x.dtype
    xz = x @ p["w_in"].astype(dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _causal_conv(x_in, p["conv_w"].astype(dt_), conv_state)
    xc = jax.nn.silu(xc)
    bt, ct, dt, a = _ssm_inputs(p, xc, dt_)
    a_bar = jnp.exp(dt[:, 0, :, None] * a)            # [B,di,N]
    b_bar = (dt[:, 0] * xc.astype(jnp.float32)[:, 0])[..., None] \
        * bt[:, 0, None, :]
    h = a_bar * ssm_state + b_bar
    y = jnp.einsum("bdn,bn->bd", h, ct[:, 0])
    y = y + xc.astype(jnp.float32)[:, 0] * p["d_skip"].astype(jnp.float32)
    y = (y[:, None].astype(dt_) * jax.nn.silu(z))
    return y @ p["w_out"].astype(dt_), h, new_conv
