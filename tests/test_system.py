"""End-to-end behaviour tests for the hybrid graph-analytics platform —
the paper's two flagship workloads, run through the unified query layer.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.query import GraphQuery, GraphPlatform
from repro.core.algorithms.two_hop import two_hop_reference
from repro.core.algorithms.connected_components import (
    connected_components_reference)
from repro.core.algorithms.legacy import (
    legacy_multi_account, legacy_connected_users)
from repro.data import synthetic as S


@pytest.fixture(scope="module")
def follow_graph():
    src, dst = S.user_follow_graph(2000, 5.0, seed=7)
    return src, dst


def test_platform_routes_and_answers_cc(follow_graph):
    src, dst = follow_graph
    g = G.build_coo(src, dst, 2000, symmetrize=True)
    plat = GraphPlatform(g, n_data=4)
    r = plat.query(GraphQuery.connected_components())
    ref = connected_components_reference(src, dst, 2000)
    assert (np.asarray(r.value) == ref).all()
    assert r.engine == "local"          # medium graph -> local engine
    assert "plan" in r.meta


def test_count_only_fast_path(follow_graph):
    src, dst = follow_graph
    g = G.build_coo(src, dst, 2000, symmetrize=True)
    plat = GraphPlatform(g, n_data=4)
    r = plat.query(GraphQuery.connected_components(count_only=True))
    ref = connected_components_reference(src, dst, 2000)
    assert r.value == len(np.unique(ref))


def test_multi_account_detection_end_to_end():
    """Paper section IV-C-1: GraphFrames-equivalent vs the legacy
    3-step Scalding join must agree at uncapped degree."""
    u, i = S.safety_bipartite_graph(400, 150, seed=11)
    maxdeg = int(np.bincount(i).max())
    ref = two_hop_reference(u, i, 400)
    legacy = legacy_multi_account(u, i, max_adjacent_nodes=maxdeg)
    assert legacy == ref

    from repro.core.algorithms.two_hop import multi_account_pairs
    pairs, valid, count, _ = multi_account_pairs(
        u, i, 400, 150, max_adjacent_nodes=maxdeg)
    got = {(int(p[0]), int(p[1]))
           for p, ok in zip(np.asarray(pairs), np.asarray(valid)) if ok}
    assert got == ref
    assert int(count) == len(ref)


def test_combined_connected_users_vs_legacy():
    """Paper section IV-C-2: unified-graph CC == per-set legacy CC + merge."""
    sets = S.identifier_edge_sets(500, n_sets=3, seed=5)
    lab_legacy = legacy_connected_users(sets, 500)
    allsrc = np.concatenate([s for s, _ in sets])
    alldst = np.concatenate([d for _, d in sets])
    g = G.build_coo(allsrc, alldst, 500, symmetrize=True)
    plat = GraphPlatform(g)
    r = plat.query(GraphQuery.connected_components())
    assert (np.asarray(r.value) == lab_legacy).all()


def test_unified_graph_merges_across_sets():
    """The unified graph merges components that per-set CC cannot (the
    mechanism behind the paper's 72.4% coverage gain)."""
    sets = S.identifier_edge_sets(500, n_sets=3, seed=9)
    allsrc = np.concatenate([s for s, _ in sets])
    alldst = np.concatenate([d for _, d in sets])
    unified = connected_components_reference(allsrc, alldst, 500)
    first_only = connected_components_reference(sets[0][0], sets[0][1], 500)
    assert len(np.unique(unified)) <= len(np.unique(first_only))


def test_pagerank_against_networkx(follow_graph):
    networkx = pytest.importorskip("networkx")
    src, dst = follow_graph
    n = 2000
    g = G.build_coo(src, dst, n)
    plat = GraphPlatform(g)
    r = plat.query(GraphQuery.pagerank(tol=1e-10, max_iters=200))
    gg = networkx.DiGraph()
    gg.add_nodes_from(range(n))
    gg.add_edges_from(zip(np.asarray(g.src)[:g.n_edges].tolist(),
                          np.asarray(g.dst)[:g.n_edges].tolist()))
    ref = networkx.pagerank(gg, alpha=0.85, tol=1e-10, max_iter=200)
    ours = np.asarray(r.value)
    refv = np.array([ref[i] for i in range(n)])
    np.testing.assert_allclose(ours, refv, atol=1e-6)
