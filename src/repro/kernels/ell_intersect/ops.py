"""Public wrappers for the sorted-row intersection kernel.

``ell_intersect_counts`` takes the ``OrientedELL`` pieces directly
(``nbr`` row matrix + oriented edge endpoints), gathers the two row
tiles per edge *chunk* (bounding host/HBM footprint to
``2 * chunk_edges * K`` ints regardless of E), and routes each chunk
through the Pallas kernel (interpret mode on CPU hosts) or the pure-jnp
``searchsorted`` reference under the same signature — engines flip
implementations exactly like ``ell_combine``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ell_intersect.kernel import ell_intersect_pallas
from repro.kernels.ell_intersect.ref import ell_intersect_ref

_LANE = 128
_SUBLANE = 8
MAX_KERNEL_K = 2048      # beyond this the (R, K) tiles outgrow VMEM; ref


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _round_up(n, m):
    return ((n + m - 1) // m) * m


def ell_intersect(a, b, sentinel: int, block_edges: int = 256):
    """Pallas path (interpret on CPU) for one pair of row tiles.

    Falls back to the reference when K exceeds the VMEM tile budget the
    kernel design assumes."""
    e, k = a.shape
    if k > MAX_KERNEL_K:
        return ell_intersect_ref(a, b, sentinel=sentinel)
    ep = _round_up(max(e, _SUBLANE), block_edges)
    kp = _round_up(k, _LANE)
    if (ep, kp) != (e, k):
        a = jnp.pad(a, ((0, ep - e), (0, kp - k)),
                    constant_values=sentinel)
        b = jnp.pad(b, ((0, ep - e), (0, kp - k)),
                    constant_values=sentinel)
    y = ell_intersect_pallas(a, b, sentinel=sentinel, k_valid=k,
                             block_edges=block_edges,
                             interpret=_on_cpu())
    return y[:e]


def ell_intersect_rows_ref(a, b, sentinel: int, block_edges: int = 256):
    """Reference path under the kernel's signature."""
    return ell_intersect_ref(a, b, sentinel=sentinel)


def ell_intersect_counts(oriented, use_pallas: bool = False,
                         chunk_edges: int = 1 << 18):
    """Per-oriented-edge intersection counts for a whole ``OrientedELL``.

    Returns an int64 numpy array of length ``oriented.n_edges`` (padding
    edges gather the all-sentinel row and are sliced off).  The total
    triangle count is its sum.
    """
    import numpy as np

    nbr = oriented.nbr
    sentinel = oriented.n_vertices
    path = ell_intersect if use_pallas else ell_intersect_rows_ref
    out = []
    n = int(oriented.eu.shape[0])
    for lo in range(0, n, chunk_edges):
        eu = jax.lax.slice(oriented.eu, (lo,), (min(lo + chunk_edges, n),))
        ev = jax.lax.slice(oriented.ev, (lo,), (min(lo + chunk_edges, n),))
        a = jnp.take(nbr, eu, axis=0)      # sentinel edges hit the
        b = jnp.take(nbr, ev, axis=0)      # all-sentinel row -> count 0
        out.append(np.asarray(path(a, b, sentinel)))
    counts = np.concatenate(out) if out else np.zeros(0, np.int32)
    return counts[: oriented.n_edges].astype(np.int64)
