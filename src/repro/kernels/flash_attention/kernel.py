"""Pallas TPU flash attention (online softmax), GQA + window + softcap.

Grid: (batch*kv_head_group, q_blocks, kv_blocks) with the kv dimension
'arbitrary' (sequential) so the online-softmax accumulators live in VMEM
scratch across kv steps.  Block sizes default to (512 q x 512 kv) —
with D=128 and f32 accumulation that is

    q tile 512*128*4 = 256 KB, k/v tiles 2*256 KB, acc 256 KB,
    m/l 2*2 KB  ->  ~1 MB of VMEM, leaving headroom for double buffering.

Causal + sliding-window masking is applied per (q_blk, kv_blk) tile.
Fully-masked tiles reduce to a no-op through the mask; the causal-skip
optimization (shrinking the kv loop per q block) is a §Perf hillclimb
item and is controlled by ``block_triangular``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, softcap: float, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (block_q, d)
    k = k_ref[0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(d))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                        # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                # (block_q, block_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           block_q=512, block_k=512, interpret=False):
    """q/k/v: [BH, S, D] (GQA head-groups pre-folded by ops.py)."""
    bh, s, d = q.shape
    n_q = s // block_q
    n_k = s // block_k
    grid = (bh, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, softcap=softcap, n_kv_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
