"""Concurrent service runtime harness: fault injection, retry and
dead-letter, backpressure, metrics, and the deterministic concurrency
stress test.

The acceptance bar (ISSUE 6): a concurrent ``drain(workers=N>=2)`` over
a seeded ~100-ticket mixed-tier workload spanning both engines produces
byte-identical per-ticket results to the serial reference drain, and
every failure path — retry→success, dead-letter after ``max_attempts``,
backpressure at the depth budget — is driven deterministically through
registry fault policies and asserted in ``metrics()``.

CI runs this module twice under different ``PYTHONHASHSEED`` values and
diffs the stress digests (set ``RUNTIME_DIGEST_OUT`` to a path to emit
them) to catch hash-order nondeterminism leaking into results.
"""
import dataclasses
import hashlib
import os
import threading

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import registry as R
from repro.core.query import GraphQuery
from repro.core.runtime import Backpressure, RetryPolicy
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

N = 300


@pytest.fixture(scope="module")
def graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=7)
    return G.build_coo(src, dst, N)


@pytest.fixture(scope="module")
def graph2():
    src, dst = S.user_follow_graph(N, 3.0, seed=13)
    return G.build_coo(src, dst, N)


FLAKY = "_rt_flaky"


@pytest.fixture()
def flaky_algorithm():
    """A throwaway registry entry the fault policies hook into — the
    runtime's failure paths are exercised through the same registration
    seam production algorithms use."""
    R.register(R.AlgorithmDef(
        name=FLAKY,
        run=lambda eng, tag=0: (np.arange(8, dtype=np.float64) + tag, None),
        params=(R.Param("tag", default=0),),
        engines=("local",),
        doc="runtime-harness flaky algorithm",
    ), replace=True)
    yield FLAKY
    R.uninstall_fault(None)
    R.unregister(FLAKY)


def _service(graph, **kw):
    kw.setdefault("interactive_threshold_s", 0.0)   # everything batch
    kw.setdefault("retry", RetryPolicy(max_attempts=3, base_s=1e-4,
                                       cap_s=1e-3))
    svc = GraphAnalyticsService(**kw)
    svc.add_graph("g", graph, force_engine="local")
    return svc


def _bits(v):
    """Canonical bytes of any query result value (arrays, scalars,
    dicts, tuples) — the per-ticket identity the stress test compares."""
    if isinstance(v, dict):
        return b"{" + b";".join(
            str(k).encode() + b"=" + _bits(v[k]) for k in sorted(v)) + b"}"
    if isinstance(v, (tuple, list)):
        return b"(" + b";".join(_bits(x) for x in v) + b")"
    return np.asarray(v).tobytes()


# ---------------------------------------------------------- fault injection

def test_retry_then_success_after_n_failures(graph, flaky_algorithm):
    svc = _service(graph)
    R.install_fault(FLAKY, R.FailNTimes(2))
    t = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t.status == "done"
    assert t.attempts == 3                  # 2 failures + the success
    r = svc.result(t)
    np.testing.assert_array_equal(np.asarray(r.value), np.arange(8.0))
    m = svc.metrics()
    assert m["counters"]["retries"] == 2
    assert m["counters"]["dead_letters"] == 0
    assert m["retry"]["max_attempts"] == 3


def test_dead_letter_after_max_attempts(graph, flaky_algorithm):
    svc = _service(graph)
    R.install_fault(FLAKY, R.FailAlways())
    bad = svc.submit("g", GraphQuery.of(FLAKY))
    good = svc.submit("g", GraphQuery.bfs([1]))
    finished = svc.drain()                  # drain continues past the DL
    assert {t.ticket_id for t in finished} == {bad.ticket_id,
                                               good.ticket_id}
    assert bad.status == "dead-letter" and bad.attempts == 3
    assert good.status == "done"
    m = svc.metrics()
    assert m["counters"]["retries"] == 2    # retried before giving up
    assert m["counters"]["dead_letters"] == 1
    assert m["counters"]["failed"] == 1
    assert not svc.pending()


def test_exception_chain_preserved_through_result(graph, flaky_algorithm):
    svc = _service(graph)
    R.install_fault(FLAKY, R.FailAlways())
    t = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    with pytest.raises(R.FaultInjected) as exc:
        svc.result(t)
    # three attempts -> a three-deep __cause__ chain, oldest at the end
    chain, e = [], exc.value
    while e is not None:
        chain.append(e)
        e = e.__cause__
    assert len(chain) == 3
    assert all(isinstance(e, R.FaultInjected) for e in chain)


def test_flaky_success_is_cached_not_retried(graph, flaky_algorithm):
    """A retried-to-success result enters the shared result cache: the
    same query resubmitted is a hit and never touches the fault again."""
    svc = _service(graph)
    R.install_fault(FLAKY, R.FailNTimes(1))
    t1 = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t1.status == "done" and t1.attempts == 2
    R.install_fault(FLAKY, R.FailAlways())   # would dead-letter a rerun
    t2 = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t2.status == "done"               # cache hit: fault never ran
    assert svc.result(t2).meta.get("cache") == "hit"


def test_permanent_error_dead_letters_without_retry(graph):
    """Schema-class errors are deterministic functions of the query:
    burning max_attempts identical failures would just slow the drain."""
    svc = _service(graph)
    t = svc.submit("g", GraphQuery("bfs", params={}))   # missing required
    svc.drain()
    assert t.status == "dead-letter" and t.attempts == 1
    assert svc.metrics()["counters"]["retries"] == 0
    with pytest.raises(ValueError, match="missing required"):
        svc.result(t)


def test_backoff_sleeps_follow_seeded_schedule(graph, flaky_algorithm,
                                               monkeypatch):
    """The runtime's actual sleeps are exactly RetryPolicy.schedule for
    the (service seed, ticket id) pair — the replay-determinism the
    stress harness relies on."""
    import repro.core.service as service_mod
    slept = []
    monkeypatch.setattr(service_mod.time, "sleep",
                        lambda s: slept.append(s))
    pol = RetryPolicy(max_attempts=4, base_s=1e-3, cap_s=8e-3)
    svc = _service(graph, retry=pol, seed=42)
    R.install_fault(FLAKY, R.FailAlways())
    t = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t.status == "dead-letter"
    want = pol.schedule(42 * 1_000_003 + t.ticket_id)
    assert tuple(slept) == want
    assert len(slept) == pol.max_attempts - 1


def test_fused_group_dead_letters_as_a_unit(graph):
    """A failing fused execution retries and dead-letters the whole
    group: every ticket shares the attempt chain, none is stranded."""
    calls = {"n": 0}

    def exploding_batch(eng, params_list):
        calls["n"] += 1
        raise RuntimeError("batch runner down")

    defn = R.get("bfs")
    patched = dataclasses.replace(defn, batch_runner=exploding_batch)
    R.register(patched, replace=True)
    try:
        svc = _service(graph)
        ts = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 1, 2)]
        svc.drain()
        assert calls["n"] == svc.retry.max_attempts    # retried as a unit
        assert all(t.status == "dead-letter" for t in ts)
        assert all(t.error is ts[0].error for t in ts)  # shared chain
        assert svc.metrics()["counters"]["dead_letters"] == 3
    finally:
        R.register(defn, replace=True)


# ------------------------------------------------------------- backpressure

def test_backpressure_typed_rejection_at_depth_budget(graph):
    svc = _service(graph, tier_depth={"batch": 2})
    svc.submit("g", GraphQuery.bfs([0]))
    svc.submit("g", GraphQuery.bfs([1]))
    with pytest.raises(Backpressure) as exc:
        svc.submit("g", GraphQuery.bfs([2]))
    e = exc.value
    assert (e.tier, e.depth, e.budget) == ("batch", 2, 2)
    assert e.query.algorithm == "bfs"
    m = svc.metrics()
    assert m["counters"]["backpressure"] == 1
    assert m["counters"]["submitted"] == 2      # rejected ticket not queued
    svc.drain()                                  # frees the queue...
    t = svc.submit("g", GraphQuery.bfs([2]))     # ...so the retry admits
    svc.drain()
    assert t.status == "done"


def test_backpressure_budget_is_per_tier(graph):
    svc = GraphAnalyticsService(
        interactive_threshold_s=1e9,             # everything interactive
        tier_depth={"batch": 0})                 # batch fully closed
    svc.add_graph("g", graph)
    t = svc.submit("g", GraphQuery.degree_stats())   # interactive: admitted
    assert t.tier == "interactive"
    svc.drain()
    assert t.status == "done"


# ------------------------------------------------------------------ metrics

def test_metrics_snapshot_fields(graph):
    svc = _service(graph)
    tickets = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 1, 2, 3)]
    m = svc.metrics()
    assert m["queue_depths"]["local.batch"] == 4
    svc.drain()
    m = svc.metrics()
    assert all(d == 0 for d in m["queue_depths"].values())
    assert m["fusion"]["batches"] == 1
    assert m["fusion"]["tickets"] == 4
    assert m["fusion"]["max_width"] == 4
    lat = m["tier_latency_s"]["batch"]
    assert lat["count"] == len(tickets)
    assert lat["p50_s"] is not None and lat["p50_s"] <= lat["p99_s"]
    assert lat["buckets"]["le_inf"] == len(tickets)
    # a resubmit is a cache hit and moves the hit rate
    svc.submit("g", GraphQuery.bfs([0]))
    svc.drain()
    assert svc.metrics()["cache"]["hits"] >= 1
    assert svc.metrics()["cache"]["hit_rate"] > 0


# ------------------------------------------- deterministic concurrency

def _trace_kwargs() -> dict:
    """Service kwargs for the CI observability gate: setting
    ``RUNTIME_TRACE_DEPTH=<n>`` re-runs the digest-emitting stress
    tests with tracing *enabled*, and the digest diff against the
    untraced run proves tracing never perturbs results."""
    depth = int(os.environ.get("RUNTIME_TRACE_DEPTH", "0"))
    return {"trace_depth": depth} if depth > 0 else {}


def _stress_services(graph, graph2, **kw):
    """Fresh service over two snapshots pinned to different engines, so
    the workload provably spans both."""
    svc = GraphAnalyticsService(cache_size=64, **_trace_kwargs(), **kw)
    svc.add_graph("local_g", graph, force_engine="local")
    svc.add_graph("dist_g", graph2, n_data=4, force_engine="distributed")
    return svc


def _stress_workload(n_tickets=100, seed=1234):
    """Seeded mixed workload: traversal (fusable), fixpoints, counts."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_tickets):
        name = ("local_g", "dist_g")[int(rng.integers(0, 2))]
        kind = int(rng.integers(0, 5))
        if kind == 0:
            q = GraphQuery.bfs([int(rng.integers(0, N))])
        elif kind == 1:
            q = GraphQuery.sssp(int(rng.integers(0, N)))
        elif kind == 2:
            q = GraphQuery.pagerank(max_iters=int(rng.integers(3, 8)))
        elif kind == 3:
            q = GraphQuery.degree_stats()
        else:
            q = GraphQuery.bfs([int(rng.integers(0, N))], count_only=True)
        out.append((name, q))
    return out


def _median_estimate(svc, workload):
    ests = [svc.context(name).plan(q) for name, q in workload]
    import repro.core.planner as P
    return float(np.median([P.plan_cost(p) for p in ests]))


def _run_stress(graph, graph2, workers, threshold):
    svc = _stress_services(graph, graph2,
                           interactive_threshold_s=threshold)
    tickets = [svc.submit(name, q) for name, q in _stress_workload()]
    tiers = {t.tier for t in tickets}
    svc.drain(workers=workers)
    per_ticket = {}
    for t in tickets:
        assert t.status == "done", (t.status, t.error)
        per_ticket[t.ticket_id] = _bits(svc.result(t).value)
    return per_ticket, tiers, svc


def test_stress_concurrent_drain_matches_serial(graph, graph2):
    """~100 seeded mixed-tier tickets across both engines: concurrent
    drain (N=4) per-ticket results are byte-identical to the serial
    reference drain."""
    probe = _stress_services(graph, graph2)
    threshold = _median_estimate(probe, _stress_workload())
    serial, tiers_s, _ = _run_stress(graph, graph2, 1, threshold)
    conc, tiers_c, svc = _run_stress(graph, graph2, 4, threshold)
    assert tiers_s == tiers_c == {"interactive", "batch"}  # a real mix
    assert serial.keys() == conc.keys()
    assert serial == conc                    # byte-identical, per ticket
    assert svc.metrics()["counters"]["executed"] > 0
    assert svc.metrics()["fusion"]["batches"] >= 1

    digest = hashlib.blake2b(
        b"|".join(serial[k] for k in sorted(serial)),
        digest_size=16).hexdigest()
    out = os.environ.get("RUNTIME_DIGEST_OUT")
    if out:                                  # CI nondeterminism probe
        with open(out, "a") as f:
            f.write(f"stress_digest {digest}\n")


def test_interactive_p50_beats_batch_under_slow_batch(graph, graph2):
    """The tiering story under load: with a slow batch ticket injected
    (Delay fault on pagerank), interactive submit→resolution p50 stays
    well under batch p50 — workers preempt for interactive at dequeue."""
    R.install_fault("pagerank", R.Delay(0.05))
    try:
        slow_qs = [GraphQuery.pagerank(max_iters=m) for m in (50, 60, 70)]
        quick_qs = [GraphQuery.bfs([s], count_only=True) for s in range(6)]
        # split the tiers exactly between these queries' estimates (on a
        # small graph the planner's deltas are tiny against its constant
        # overhead term, so a workload-level median is too coarse)
        probe = _stress_services(graph, graph2).context("local_g")
        import repro.core.planner as P
        hi = max(P.plan_cost(probe.plan(q)) for q in quick_qs)
        lo = min(P.plan_cost(probe.plan(q)) for q in slow_qs)
        assert hi < lo                       # the classes are separable
        svc = _stress_services(graph, graph2,
                               interactive_threshold_s=(hi + lo) / 2.0)
        slow = [svc.submit("local_g", q) for q in slow_qs]
        quick = [svc.submit("local_g", q) for q in quick_qs]
        assert all(t.tier == "batch" for t in slow)
        assert all(t.tier == "interactive" for t in quick)
        svc.drain(workers=2)
        m = svc.metrics()["tier_latency_s"]
        assert m["interactive"]["p50_s"] < m["batch"]["p50_s"]
    finally:
        R.uninstall_fault("pagerank")


def test_concurrent_drain_overlaps_engines(graph, graph2):
    """Two workers genuinely overlap: a Delay fault on sssp (routed to
    one engine's context) does not serialize behind the other engine's
    tickets — the drain takes ~one delay, not the serial sum."""
    svc = _stress_services(graph, graph2, interactive_threshold_s=0.0)
    # warm both contexts (compile + derived state) before installing the
    # fault, so the timed region is delay-dominated
    svc.call("local_g", GraphQuery.sssp(1))
    svc.call("dist_g", GraphQuery.sssp(1))
    R.install_fault("sssp", R.Delay(0.25))
    try:
        svc.submit("local_g", GraphQuery.sssp(0))
        svc.submit("dist_g", GraphQuery.sssp(0))
        import time as _time
        t0 = _time.perf_counter()
        svc.drain(workers=2)
        wall = _time.perf_counter() - t0
        assert wall < 0.45, wall             # < 2 stacked 0.25s delays
    finally:
        R.uninstall_fault("sssp")


def test_result_awaits_inflight_ticket(graph, flaky_algorithm):
    """result() on a ticket another thread is executing awaits that
    execution instead of re-running it."""
    R.install_fault(FLAKY, R.Delay(0.1))
    svc = _service(graph)
    t = svc.submit("g", GraphQuery.of(FLAKY))
    worker = threading.Thread(target=svc.drain)
    worker.start()
    r = svc.result(t)                        # joins the in-flight run
    worker.join()
    assert t.status == "done"
    assert svc.context("g").local.n_runs == 1    # executed exactly once
    np.testing.assert_array_equal(np.asarray(r.value), np.arange(8.0))


def test_superstep_variant_digest_parity(graph):
    """Frontier-vs-dense determinism bar, mirroring the stress digest:
    every superstep strategy must produce byte-identical results for
    every algorithm that registered variants, and the combined digest is
    emitted to ``RUNTIME_DIGEST_OUT`` so CI diffs it across
    ``PYTHONHASHSEED`` values alongside the scheduler digest."""
    from repro.core.engines import LocalEngine
    import repro.core.algorithms.traversal            # noqa: F401
    import repro.core.algorithms.connected_components  # noqa: F401
    import repro.core.algorithms.triangles             # noqa: F401

    sym = G.build_coo(np.asarray(graph.src)[: graph.n_edges],
                      np.asarray(graph.dst)[: graph.n_edges],
                      graph.n_vertices, symmetrize=True)
    engines = {False: LocalEngine(graph), True: LocalEngine(sym)}
    chunks = []
    for name, defn in sorted(R.items()):
        variants = sorted(defn.variants or ())
        if "frontier" not in variants:
            continue
        eng = engines[defn.requires_symmetric]
        params = dict(defn.example_params or {})
        outs = {v: np.asarray(eng.run(defn, params, variant=v).value)
                for v in variants}
        ref = outs["dense"]
        for v, arr in outs.items():
            assert arr.tobytes() == ref.tobytes(), (name, v)
        chunks.append(name.encode() + b":" + ref.tobytes())
    assert chunks                            # the variant family exists
    digest = hashlib.blake2b(b"|".join(chunks),
                             digest_size=16).hexdigest()
    out = os.environ.get("RUNTIME_DIGEST_OUT")
    if out:                                  # CI nondeterminism probe
        with open(out, "a") as f:
            f.write(f"superstep_digest {digest}\n")


def test_federation_spill_stress_digest(graph, graph2):
    """Federation determinism bar, folded into the digest diff: a
    two-pool service under batch capacity pressure — so a fixed subset
    of the workload spills to the other pool — drains to byte-identical
    per-ticket results serial vs ``workers=4``, and the combined digest
    lands in ``RUNTIME_DIGEST_OUT`` for CI's PYTHONHASHSEED diff."""
    from repro.core import pools as PL

    def run(workers):
        svc = GraphAnalyticsService(
            pools=PL.PoolSet([
                PL.DevicePool("onprem", capacity=2, max_inflight=2),
                PL.DevicePool("cloud", capacity=32, compute_scale=1.0),
            ]),
            interactive_threshold_s=0.0,   # everything batches
            cache_size=64, **_trace_kwargs())
        svc.add_graph("g", graph)
        svc.add_graph("h", graph2)
        workload = _stress_workload(n_tickets=60, seed=99)
        tickets = [svc.submit(("g", "h")[name == "dist_g"], q)
                   for name, q in workload]
        spilled = svc.stats["spilled"]
        svc.drain(workers=workers)
        per = {}
        for t in tickets:
            assert t.status == "done", (t.status, t.error)
            per[t.ticket_id] = _bits(svc.result(t).value)
        return per, spilled, {t.pool for t in tickets}

    serial, spill_s, pools_s = run(1)
    conc, spill_c, pools_c = run(4)
    assert spill_s == spill_c > 0            # pressure really spilled
    assert pools_s == pools_c == {"onprem", "cloud"}
    assert serial == conc                    # byte-identical, per ticket

    digest = hashlib.blake2b(
        b"|".join(serial[k] for k in sorted(serial)),
        digest_size=16).hexdigest()
    out = os.environ.get("RUNTIME_DIGEST_OUT")
    if out:                                  # CI nondeterminism probe
        with open(out, "a") as f:
            f.write(f"federation_digest {digest}\n")


def test_incremental_lineage_stress_digest(graph):
    """Lineage determinism bar, folded into the digest diff: a
    two-version snapshot chain whose second version is served by seeded
    executions (incremental CC/BFS repairs, warm PageRank/HITS
    restarts) drains to byte-identical per-ticket results serial vs
    ``workers=4``, and the combined digest lands in
    ``RUNTIME_DIGEST_OUT`` for CI's PYTHONHASHSEED diff."""
    import repro.core.algorithms.connected_components  # noqa: F401
    import repro.core.algorithms.hits                  # noqa: F401
    import repro.core.algorithms.pagerank              # noqa: F401
    import repro.core.algorithms.traversal             # noqa: F401

    sym = G.build_coo(np.asarray(graph.src)[: graph.n_edges],
                      np.asarray(graph.dst)[: graph.n_edges],
                      graph.n_vertices, symmetrize=True)
    rng = np.random.default_rng(17)
    added = np.stack([rng.integers(0, N, 5), rng.integers(0, N, 5)],
                     axis=1)
    queries = [GraphQuery.of("connected_components"),
               GraphQuery.of("bfs", sources=(0,)),
               GraphQuery.of("pagerank"),
               GraphQuery.of("hits")]

    def run(workers):
        svc = GraphAnalyticsService(cache_size=64, **_trace_kwargs())
        svc.add_snapshot("g", sym, as_of=0)
        for q in queries:                    # parent answers = the seeds
            svc.call("g", q, as_of=0)
        svc.add_snapshot("g", as_of=1, added=added)
        tickets = [svc.submit("g", q) for q in queries for _ in range(2)]
        seeded = sum(t.plan.mode != "full" for t in tickets)
        svc.drain(workers=workers)
        per = {}
        for t in tickets:
            assert t.status == "done", (t.status, t.error)
            per[t.ticket_id] = _bits(svc.result(t).value)
        return per, seeded, svc.metrics()["incremental"]

    serial, seeded_s, meter_s = run(1)
    conc, seeded_c, meter_c = run(4)
    assert seeded_s == seeded_c == len(serial)   # every ticket seeded
    # duplicates resolve from the result cache: one seeded execution
    # per distinct query, counted identically serial vs concurrent
    assert meter_s == meter_c
    assert meter_s["incremental_runs"] == 2 and meter_s["warm_hits"] == 2
    assert serial == conc                    # byte-identical, per ticket

    digest = hashlib.blake2b(
        b"|".join(serial[k] for k in sorted(serial)),
        digest_size=16).hexdigest()
    out = os.environ.get("RUNTIME_DIGEST_OUT")
    if out:                                  # CI nondeterminism probe
        with open(out, "a") as f:
            f.write(f"incremental_digest {digest}\n")
