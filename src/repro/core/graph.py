"""Device-ready graph representations.

The paper's platform moves graphs between a distributed dataflow engine
(Spark/GraphFrames) and an in-memory graph database (Neo4j).  On TPU every
representation must be fixed-shape, so we keep three formats:

* ``GraphCOO``  — destination-sorted edge list, padded with a sentinel
  vertex id ``V`` so ``jax.ops.segment_*`` with ``num_segments=V+1`` drops
  padding for free.  This is the *exact* format (no degree cap) and the
  unit of edge partitioning for the distributed engine.
* ``GraphCSR``  — ``indptr/indices``; the LocalEngine's native format
  (the Neo4j "index-free adjacency" analogue: pointer-chase becomes slice).
* ``GraphELL`` — per-vertex neighbor lists padded to a max degree ``K``.
  This is the paper's ``MaxAdjacentNodes`` cap (Table I) turned into the
  TPU-native layout: gather + masked row-reduce is exactly what the VPU
  wants, and skew becomes padding instead of stragglers.
* ``OrientedELL`` — degree-ordered orientation of an undirected graph:
  each edge {u, v} kept once, directed from the lower-(degree, id) rank
  endpoint to the higher, with per-vertex *sorted* out-neighbor rows.
  Out-degrees under this orientation are bounded by O(sqrt(E)) (hubs
  rank last, so they receive rather than emit), which makes neighborhood
  intersection — triangle counting — linear in memory instead of the
  O(V^2/32)-bit bitset formulation.  Unlike ``GraphELL`` this is *exact*:
  the row width is the achieved max out-degree, not a lossy cap.

All constructors take host-side ``np.ndarray`` edge lists (the ETL layer
works in numpy, like Scalding worked in Hadoop) and produce pytrees of
``jnp`` arrays.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class GraphDelta:
    """One snapshot-to-snapshot edge edit, as applied by
    :meth:`GraphCOO.apply_delta`.

    ``added``/``removed`` are ``[n, 2]`` int64 host arrays of logical
    (src, dst) pairs — *before* symmetrization, so an undirected graph's
    delta records each touched undirected edge once.  ``touched`` is the
    sorted unique endpoint set of every changed edge: the seed frontier
    for incremental algorithm maintenance, and the planner's estimate of
    how much of the graph an incremental recompute must visit.
    """

    added: np.ndarray      # [n_added, 2] int64
    removed: np.ndarray    # [n_removed, 2] int64
    touched: np.ndarray    # [n_touched] int32, sorted unique endpoints

    @property
    def n_added(self) -> int:
        return int(self.added.shape[0])

    @property
    def n_removed(self) -> int:
        return int(self.removed.shape[0])

    @property
    def n_touched(self) -> int:
        return int(self.touched.shape[0])

    def nbytes(self) -> int:
        """Bytes a consumer must ingest to apply this delta — the
        planner's incremental-path transfer term."""
        return int(self.added.nbytes + self.removed.nbytes
                   + self.touched.nbytes)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphCOO:
    """Destination-sorted, padded COO edge list.

    Padding edges have ``src == dst == n_vertices`` (the sentinel row) and
    ``w == 0``.
    """

    src: Array          # [E_pad] int32
    dst: Array          # [E_pad] int32, sorted ascending
    w: Array            # [E_pad] float32 (1.0 for unweighted)
    n_vertices: int     # static
    n_edges: int        # true edge count (static)
    symmetric: bool = False   # built via symmetrize=True (static metadata;
                              # set it manually if the edge list is already
                              # symmetric by construction)

    # -- pytree protocol (scalars are static aux data) ---------------------
    def tree_flatten(self):
        return (self.src, self.dst, self.w), (
            self.n_vertices, self.n_edges, self.symmetric)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, w = children
        return cls(src, dst, w, *aux)

    @property
    def e_pad(self) -> int:
        return int(self.src.shape[0])

    def nbytes(self) -> int:
        return self.e_pad * (4 + 4 + 4)

    def content_digest(self) -> str:
        """Content identity of this graph: a digest over the true (un-padded)
        edge buffers plus the structural metadata.  Two byte-identical
        graphs — e.g. the same snapshot reloaded — share one digest, and
        distinct graphs can never collide the way recycled ``id()``
        values can.  Computed once (one device->host transfer) and
        memoized on the instance; the memo is a plain attribute, not a
        pytree leaf, so tracing never sees it."""
        d = getattr(self, "_content_digest", None)
        if d is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{self.n_vertices}|{self.n_edges}|"
                     f"{self.symmetric}".encode())
            for buf in (self.src, self.dst, self.w):
                h.update(np.ascontiguousarray(
                    np.asarray(buf)[: self.n_edges]).tobytes())
            d = h.hexdigest()
            self._content_digest = d
        return d

    def apply_delta(
        self,
        added=None,
        removed=None,
        added_w: Optional[np.ndarray] = None,
        pad_multiple: int = 1024,
    ) -> "GraphCOO":
        """Edit the edge set without re-landing the snapshot: returns a
        new canonical :class:`GraphCOO` with recorded lineage.

        ``added``/``removed`` are iterables of logical (src, dst) pairs
        (anything ``np.asarray`` reshapes to ``[n, 2]``).  On a
        symmetric graph each logical pair stands for the undirected
        edge — both directions are edited.  Removals apply before
        additions, so remove+add of the same pair is a weight update;
        adding an edge that already exists is a no-op (the existing
        weight wins, matching ``build_coo``'s first-occurrence dedup).

        Because the result routes through ``build_coo``'s
        canonicalization (dedup + destination sort), its
        ``content_digest`` is **bit-identical** to building the edited
        edge list from scratch — lineage-equal graphs are cache-equal.
        The new graph carries ``parent_digest`` (this graph's digest)
        and ``delta`` (a :class:`GraphDelta`) as plain host attributes
        for the catalog's lineage chain and the planner's
        incremental-vs-full pricing.
        """
        def _pairs(edges) -> np.ndarray:
            if edges is None:
                return np.zeros((0, 2), dtype=np.int64)
            e = np.asarray(edges, dtype=np.int64)
            return e.reshape(-1, 2) if e.size else np.zeros((0, 2),
                                                            dtype=np.int64)

        add = _pairs(added)
        rem = _pairs(removed)
        V = self.n_vertices
        for name, e in (("added", add), ("removed", rem)):
            if e.size and (e.min() < 0 or e.max() >= V):
                raise ValueError(
                    f"apply_delta: {name} edge endpoints must lie in "
                    f"[0, {V}); got range [{e.min()}, {e.max()}]")
        touched = np.unique(
            np.concatenate([add.ravel(), rem.ravel()])).astype(np.int32)

        if added_w is None:
            add_w = np.ones(add.shape[0], dtype=np.float32)
        else:
            add_w = np.asarray(added_w, dtype=np.float32).reshape(-1)
            if add_w.shape[0] != add.shape[0]:
                raise ValueError("apply_delta: added_w length mismatch")
        add_s, add_d = add[:, 0], add[:, 1]
        rem_s, rem_d = rem[:, 0], rem[:, 1]
        if self.symmetric:
            add_s, add_d = (np.concatenate([add_s, add_d]),
                            np.concatenate([add_d, add_s]))
            add_w = np.concatenate([add_w, add_w])
            rem_s, rem_d = (np.concatenate([rem_s, rem_d]),
                            np.concatenate([rem_d, rem_s]))

        src = np.asarray(self.src)[: self.n_edges].astype(np.int64)
        dst = np.asarray(self.dst)[: self.n_edges].astype(np.int64)
        w = np.asarray(self.w)[: self.n_edges]
        stride = np.int64(V + 1)
        if rem_s.size:
            keep = ~np.isin(src * stride + dst, rem_s * stride + rem_d)
            src, dst, w = src[keep], dst[keep], w[keep]
        new = build_coo(
            np.concatenate([src, add_s]), np.concatenate([dst, add_d]), V,
            w=np.concatenate([w, add_w]), pad_multiple=pad_multiple,
            symmetrize=False, dedup=True)
        # symmetric is digest-header metadata: restore it before any
        # digest is computed so lineage-equal graphs stay cache-equal
        new.symmetric = self.symmetric
        new.parent_digest = self.content_digest()
        new.delta = GraphDelta(added=add, removed=rem, touched=touched)
        return new


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphCSR:
    """CSR adjacency: out-neighbors of v are indices[indptr[v]:indptr[v+1]]."""

    indptr: Array       # [V+1] int32
    indices: Array      # [E_pad] int32 (padded tail with sentinel V)
    w: Array            # [E_pad] float32
    n_vertices: int
    n_edges: int

    def tree_flatten(self):
        return (self.indptr, self.indices, self.w), (self.n_vertices, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indptr, indices, w = children
        return cls(indptr, indices, w, aux[0], aux[1])

    def nbytes(self) -> int:
        return int(self.indptr.shape[0]) * 4 + int(self.indices.shape[0]) * 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphELL:
    """ELLPACK: fixed-width neighbor matrix (the MaxAdjacentNodes layout).

    ``nbr[v, k]`` is the k-th in-neighbor of ``v`` (source of an edge into
    v); invalid slots have ``mask == False`` and ``nbr == n_vertices``
    (sentinel, so gathers read the identity pad row).
    """

    nbr: Array          # [V, K] int32
    mask: Array         # [V, K] bool
    w: Array            # [V, K] float32
    n_vertices: int
    n_edges: int        # edges retained after capping
    n_edges_total: int  # edges before capping (for Table I loss accounting)

    def tree_flatten(self):
        return (self.nbr, self.mask, self.w), (
            self.n_vertices, self.n_edges, self.n_edges_total)

    @classmethod
    def tree_unflatten(cls, aux, children):
        nbr, mask, w = children
        return cls(nbr, mask, w, aux[0], aux[1], aux[2])

    @property
    def max_degree(self) -> int:
        return int(self.nbr.shape[1])

    @property
    def lost_fraction(self) -> float:
        """Table I: fraction of edges dropped by the degree cap."""
        if self.n_edges_total == 0:
            return 0.0
        return 1.0 - self.n_edges / self.n_edges_total

    def nbytes(self) -> int:
        v, k = self.nbr.shape
        return int(v) * int(k) * (4 + 1 + 4)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OrientedELL:
    """Degree-ordered orientation with sorted out-neighbor rows.

    Every undirected edge {u, v} appears exactly once as the oriented
    pair ``(eu[i], ev[i])`` where ``rank(u) < rank(v)`` under the
    lexicographic ``(degree, id)`` order (self-loops drop out — no
    vertex out-ranks itself).  ``nbr[v]`` holds v's oriented
    out-neighbors sorted ascending by id; invalid slots carry the
    sentinel ``n_vertices``, and one extra all-sentinel row at index
    ``n_vertices`` lets padded edge slots gather an empty row.

    The number of triangles is ``sum_i |nbr[eu[i]] ∩ nbr[ev[i]]|`` —
    each triangle counted exactly once, at its lowest-rank edge.
    """

    nbr: Array          # [V + 1, K] int32, rows sorted, sentinel-padded
    eu: Array           # [E_pad] int32 oriented edge tails (sentinel pad)
    ev: Array           # [E_pad] int32 oriented edge heads (sentinel pad)
    n_vertices: int
    n_edges: int        # true oriented (== undirected) edge count

    def tree_flatten(self):
        return (self.nbr, self.eu, self.ev), (self.n_vertices, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        nbr, eu, ev = children
        return cls(nbr, eu, ev, aux[0], aux[1])

    @property
    def max_out_degree(self) -> int:
        return int(self.nbr.shape[1])

    def nbytes(self) -> int:
        return (int(self.nbr.shape[0]) * int(self.nbr.shape[1])
                + 2 * int(self.eu.shape[0])) * 4


# ---------------------------------------------------------------------------
# Host-side constructors (numpy; this is the ETL substrate's device handoff)
# ---------------------------------------------------------------------------

def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] >= n:
        return x[:n]
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def build_coo(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    w: Optional[np.ndarray] = None,
    pad_multiple: int = 1024,
    symmetrize: bool = False,
    dedup: bool = True,
) -> GraphCOO:
    """Sort edges by destination, optionally symmetrize/dedup, pad."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    if dedup and src.size:
        key = src.astype(np.int64) * np.int64(n_vertices + 1) + dst.astype(np.int64)
        _, keep = np.unique(key, return_index=True)
        src, dst, w = src[keep], dst[keep], w[keep]
    order = np.argsort(dst, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    n_edges = int(src.shape[0])
    e_pad = max(pad_multiple, round_up(n_edges, pad_multiple))
    sentinel = np.int32(n_vertices)
    return GraphCOO(
        src=jnp.asarray(_pad_to(src, e_pad, sentinel)),
        dst=jnp.asarray(_pad_to(dst, e_pad, sentinel)),
        w=jnp.asarray(_pad_to(w, e_pad, 0.0)),
        n_vertices=int(n_vertices),
        n_edges=n_edges,
        symmetric=bool(symmetrize),
    )


def build_csr(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    w: Optional[np.ndarray] = None,
    pad_multiple: int = 1024,
    symmetrize: bool = False,
) -> GraphCSR:
    """CSR over *out*-neighbors: row v lists targets of edges from v."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    counts = np.bincount(src, minlength=n_vertices).astype(np.int32)
    indptr = np.zeros(n_vertices + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    n_edges = int(src.shape[0])
    e_pad = max(pad_multiple, round_up(n_edges, pad_multiple))
    return GraphCSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(_pad_to(dst, e_pad, np.int32(n_vertices))),
        w=jnp.asarray(_pad_to(w, e_pad, 0.0)),
        n_vertices=int(n_vertices),
        n_edges=n_edges,
    )


def build_ell(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    max_degree: int,
    w: Optional[np.ndarray] = None,
    symmetrize: bool = False,
    direction: str = "in",
) -> GraphELL:
    """Pack edges into the fixed-width ELL layout, capping per-vertex degree.

    ``direction='in'``: row v holds *sources* of edges into v (what SpMV /
    message aggregation wants).  Edges beyond ``max_degree`` for a vertex
    are dropped — this is exactly the paper's ``MaxAdjacentNodes``
    restriction, and ``lost_fraction`` reproduces Table I.
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if w is None:
        w = np.ones_like(src, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    if direction == "in":
        row, col = dst, src
    else:
        row, col = src, dst
    n_total = int(row.shape[0])
    order = np.argsort(row, kind="stable")
    row, col, w = row[order], col[order], w[order]
    counts = np.bincount(row, minlength=n_vertices)
    # slot index of each edge within its row
    starts = np.zeros(n_vertices, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    slot = np.arange(n_total, dtype=np.int64) - starts[row]
    keep = slot < max_degree
    row_k, col_k, w_k, slot_k = row[keep], col[keep], w[keep], slot[keep]
    nbr = np.full((n_vertices, max_degree), np.int32(n_vertices), dtype=np.int32)
    mask = np.zeros((n_vertices, max_degree), dtype=bool)
    wm = np.zeros((n_vertices, max_degree), dtype=np.float32)
    nbr[row_k, slot_k] = col_k
    mask[row_k, slot_k] = True
    wm[row_k, slot_k] = w_k
    return GraphELL(
        nbr=jnp.asarray(nbr),
        mask=jnp.asarray(mask),
        w=jnp.asarray(wm),
        n_vertices=int(n_vertices),
        n_edges=int(keep.sum()),
        n_edges_total=n_total,
    )


def build_oriented_ell(
    src: np.ndarray,
    dst: np.ndarray,
    n_vertices: int,
    pad_multiple: int = 1024,
) -> OrientedELL:
    """Degree-order and orient a symmetrized, deduped edge list.

    Input must contain both directions of every undirected edge (the
    ``build_coo(..., symmetrize=True)`` invariant); exactly one survives
    orientation.  Self-loops and sentinel padding rows are dropped.  The
    achieved row width is the orientation's max out-degree — O(sqrt(E))
    even on heavy-tailed graphs, because high-degree hubs rank last and
    therefore *receive* nearly all their edges.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    real = (src < n_vertices) & (dst < n_vertices) & (src != dst)
    src, dst = src[real], dst[real]
    deg = np.bincount(dst, minlength=n_vertices)
    # keep (u, v) iff (deg[u], u) < (deg[v], v) — the degree-ordered
    # orientation; ties broken by id so every edge survives exactly once
    keep = (deg[src] < deg[dst]) | ((deg[src] == deg[dst]) & (src < dst))
    eu, ev = src[keep], dst[keep]
    order = np.lexsort((ev, eu))          # rows grouped, sorted by head id
    eu, ev = eu[order], ev[order]
    n_edges = int(eu.shape[0])
    counts = np.bincount(eu, minlength=n_vertices)
    k = max(int(counts.max()) if n_edges else 1, 1)
    starts = np.zeros(n_vertices, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    slot = np.arange(n_edges, dtype=np.int64) - starts[eu]
    sentinel = np.int32(n_vertices)
    nbr = np.full((n_vertices + 1, k), sentinel, dtype=np.int32)
    nbr[eu, slot] = ev.astype(np.int32)
    e_pad = max(pad_multiple, round_up(max(n_edges, 1), pad_multiple))
    return OrientedELL(
        nbr=jnp.asarray(nbr),
        eu=jnp.asarray(_pad_to(eu.astype(np.int32), e_pad, sentinel)),
        ev=jnp.asarray(_pad_to(ev.astype(np.int32), e_pad, sentinel)),
        n_vertices=int(n_vertices),
        n_edges=n_edges,
    )


# ---------------------------------------------------------------------------
# Device-side primitives shared by engines
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_vertices", "op"))
def segment_combine(values: Array, segment_ids: Array, n_vertices: int, op: str):
    """Aggregate edge messages to destination vertices.

    ``segment_ids`` may contain the sentinel ``n_vertices`` (padding); one
    extra segment swallows it and is dropped.  ``op`` in {sum,min,max}.
    """
    n = n_vertices + 1
    if op == "sum":
        out = jax.ops.segment_sum(values, segment_ids, num_segments=n)
    elif op == "min":
        out = jax.ops.segment_min(values, segment_ids, num_segments=n)
    elif op == "max":
        out = jax.ops.segment_max(values, segment_ids, num_segments=n)
    else:
        raise ValueError(f"unknown op {op!r}")
    return out[:n_vertices]


def pad_vertex_state(x: Array, identity) -> Array:
    """Append the sentinel row so gathers through padded ids read identity."""
    pad = jnp.full((1,) + x.shape[1:], identity, dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def require_symmetric(g: GraphCOO, algorithm: str) -> None:
    """Guard for algorithms with undirected semantics — on a directed
    edge list they run fine but return silently wrong answers."""
    if not getattr(g, "symmetric", False):
        raise ValueError(
            f"{algorithm} has undirected semantics and needs a symmetrized "
            f"edge list: build with build_coo(..., symmetrize=True), or set "
            f"coo.symmetric = True if the edges are already symmetric by "
            f"construction")


def out_degrees(g: GraphCOO) -> Array:
    ones = (g.src < g.n_vertices).astype(jnp.float32)
    return segment_combine(ones, g.src, g.n_vertices, "sum")


def in_degrees(g: GraphCOO) -> Array:
    ones = (g.dst < g.n_vertices).astype(jnp.float32)
    return segment_combine(ones, g.dst, g.n_vertices, "sum")
