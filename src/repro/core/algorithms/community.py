"""Community detection via synchronous weighted label propagation.

Classic LPA (Raghavan et al.) has every vertex adopt the label carried
by the plurality of its neighbors — a *mode* over neighbor labels, which
a single sum/min/max monoid cannot express (GraphX's LabelPropagation
merges hash-maps per message for exactly this reason; maps are not a
fixed-shape TPU type).  We express the mode with the pregel engine's
*structured messages*: each edge emits ``2C`` columns —

    columns [0, C)   : edge weight one-hot on ``hash(label_src) % C``
                       (combine **sum**  -> per-channel neighbor mass)
    columns [C, 2C)  : label value on the same channel, +inf elsewhere
                       (combine **min**  -> per-channel representative)

so one superstep delivers, per vertex, the weighted frequency histogram
of neighbor labels over C hash channels plus the smallest label in each
channel.  ``apply`` adopts the smallest label among maximal-mass
channels; a unit self-weight on the current label's channel breaks the
2-cycle oscillation synchronous LPA is known for.  Hash collisions merge
label mass within a channel (the representative is the channel min) —
with C default 64 and social-graph mean degrees ~10, collisions inside a
single neighborhood are rare, and the fixpoint iteration self-corrects.

Labels are vertex ids carried in float32 channels, exact for
V < 2^24 — document-and-assert rather than silently lose precision.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, converged_halt, run_pregel

_HASH_MULT = np.uint32(2654435761)          # Knuth multiplicative hash
_MAX_EXACT_LABEL = 1 << 24                  # float32 integer-exact range


def _channel(labels, n_channels: int):
    h = labels.astype(jnp.uint32) * _HASH_MULT
    return (h % jnp.uint32(n_channels)).astype(jnp.int32)


@lru_cache(maxsize=None)
def _lpa_spec(n_channels: int, self_weight: float) -> PregelSpec:
    C = n_channels
    ch_ids = jnp.arange(C, dtype=jnp.int32)

    def message(lbl_src, w):
        onehot = _channel(lbl_src, C)[:, None] == ch_ids[None, :]
        mass = jnp.where(onehot, w[:, None], 0.0)
        rep = jnp.where(onehot, lbl_src.astype(jnp.float32)[:, None],
                        jnp.inf)
        return jnp.concatenate([mass, rep], axis=-1)

    def apply(lbl, agg, ids, gval):
        mass, rep = agg[:, :C], agg[:, C:]
        best_w = jnp.max(mass, axis=-1)
        # smallest label among maximal-mass channels (deterministic
        # tie-break, independent of channel/hash order)
        cand_f = jnp.min(jnp.where(mass == best_w[:, None], rep, jnp.inf),
                         axis=-1)
        has_cand = jnp.isfinite(cand_f)
        cand = jnp.where(has_cand, cand_f, 0.0).astype(jnp.int32)
        # mass already backing the current label, plus the self-vote that
        # prevents synchronous 2-cycles (e.g. a two-vertex component
        # swapping labels forever)
        rows = jnp.arange(lbl.shape[0])
        cur_w = mass[rows, _channel(lbl, C)] + self_weight
        adopt = has_cand & ((best_w > cur_w)
                            | ((best_w == cur_w) & (cand < lbl)))
        return jnp.where(adopt, cand, lbl)

    # Superstep-strategy declaration: LPA opts *out* of every fast
    # path.  The message gathers a [E, 2C] structured tensor (not
    # elementwise), the (sum ⊕ min) grouped monoid has no single
    # scatter op (no fused/frontier variant), and the mass channels are
    # an inexact float sum, so a reduced-precision message channel is
    # rejected by ``check_precision`` (allow_inexact_sum stays False).
    # Label adoption is also not a monotone fold of the aggregate —
    # dense is the only exact execution.
    return PregelSpec(
        message=message,
        combine=(("sum", C), ("min", C)),
        apply=apply,
        identity=(0.0, float("inf")),
        halt=converged_halt,
        elementwise_message=False,
        frontier_mode=None,
        allow_inexact_sum=False,
    )


def label_propagation(
    g: G.GraphCOO,
    max_iters: int = 30,
    n_channels: int = 64,
    self_weight: float = 1.0,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns ``(labels [V] int32, iters)`` — one label per community.

    ``g`` should be symmetrized (community membership is undirected, like
    connected components).  Labels are vertex ids; two vertices share a
    community iff they share a label.  Synchronous LPA may not reach a
    global fixpoint on adversarial structures — ``max_iters`` bounds the
    loop and the result is deterministic either way (no RNG: ties break
    toward the smallest label).
    """
    if g.n_vertices >= _MAX_EXACT_LABEL:
        raise ValueError(
            f"label_propagation carries labels in float32 channels; "
            f"V={g.n_vertices} exceeds the exact-integer range 2^24")
    G.require_symmetric(g, "label_propagation")
    V = g.n_vertices
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    init = jnp.arange(sharded.n_pad, dtype=jnp.int32)
    spec = _lpa_spec(n_channels, float(self_weight))
    labels, iters = run_pregel(spec, sharded, init, max_iters, mesh=mesh)
    return labels[:V], iters


def num_communities(labels) -> int:
    """Count-only fast path: number of distinct labels, computed on
    device with one scatter — no host-side unique over the table."""
    V = labels.shape[0]
    present = jnp.zeros(V, jnp.int32).at[jnp.clip(labels, 0, V - 1)].set(1)
    return int(jnp.sum(present))


# ------------------------------------------------------------ registration

def _engine_run(eng, max_iters, n_channels, self_weight):
    return label_propagation(
        eng.coo, max_iters=max_iters, n_channels=n_channels,
        self_weight=self_weight, mesh=eng.mesh, sharded=eng.sharded)


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    # structured messages: 2C channels of 4 bytes vs 12-byte edges
    n_channels = params.get("n_channels") or 64
    iters = min(15, params.get("max_iters") or 15)
    return P.QuerySpec("label_propagation",
                       1 if count_only else g.n_vertices,
                       iterations=iters, state_bytes_per_vertex=4.0,
                       edge_bytes_factor=2 * n_channels * 4 / 12)


R.register(R.AlgorithmDef(
    name="label_propagation",
    run=_engine_run,
    params=(
        R.Param("max_iters", 30, check=lambda n: n >= 1, normalize=int),
        R.Param("n_channels", 64, check=lambda c: c >= 1, normalize=int),
        R.Param("self_weight", 1.0, check=lambda w: w >= 0.0,
                normalize=float),
    ),
    count=num_communities,
    count_method="num_communities",
    cost=_cost,
    requires_symmetric=True,
    example_params={"max_iters": 15},
    doc="Synchronous weighted label propagation over hash channels.",
))


def communities_reference(src, dst, n_vertices: int) -> np.ndarray:
    """Union-find oracle: on graphs whose ground-truth communities are
    the connected components (e.g. disjoint cliques), LPA must agree."""
    from repro.core.algorithms.connected_components import (
        connected_components_reference)
    return connected_components_reference(src, dst, n_vertices)
