"""Hybrid-cloud federation: DevicePool/PoolSet semantics, pool-aware
placement, residency + transfer accounting, batch spill, cache
invalidation on topology changes, cross-pool result parity, and the
checked-in reference calibration roundtrip.

The acceptance story this file pins (ISSUE 8): a query over a snapshot
resident only on pool B is planned onto B when the transfer cost
dominates and onto A when A's compute advantage dominates; batch spill
engages under per-pool capacity pressure; and per-ticket results are
``tobytes()``-identical across pools and to the pre-federation
single-pool path.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import planner as P
from repro.core import pools as PL
from repro.core import registry as R
from repro.core import runtime as RT
from repro.core.engines import DistributedEngine, LocalEngine
from repro.core.query import GraphPlatform, GraphQuery
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

N = 240


def _bits(v):
    """Recursive byte view of a result value (dict/tuple/array)."""
    if isinstance(v, dict):
        return tuple((k, _bits(v[k])) for k in sorted(v))
    if isinstance(v, (tuple, list)):
        return tuple(_bits(x) for x in v)
    return np.asarray(v).tobytes()


@pytest.fixture(scope="module")
def graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    return G.build_coo(src, dst, N)


@pytest.fixture(scope="module")
def sym_graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=11)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], N, symmetrize=True)


def _two_pools(link_bandwidth=PL.DEFAULT_LINK_BANDWIDTH,
               cloud_scale=1.0, **kw):
    return PL.PoolSet([
        PL.DevicePool("onprem", link_bandwidth=link_bandwidth, **kw),
        PL.DevicePool("cloud", link_bandwidth=link_bandwidth,
                      compute_scale=cloud_scale, **kw),
    ])


# ---------------------------------------------------------------------------
# DevicePool / PoolSet semantics
# ---------------------------------------------------------------------------

def test_devicepool_validates_fields():
    with pytest.raises(ValueError):
        PL.DevicePool("")
    with pytest.raises(ValueError):
        PL.DevicePool("p", link_bandwidth=0.0)
    with pytest.raises(ValueError):
        PL.DevicePool("p", compute_scale=0.0)
    with pytest.raises(ValueError):
        PL.DevicePool("p", capacity=-1)
    with pytest.raises(ValueError):
        PL.DevicePool("p", max_inflight=0)


def test_poolset_names_order_and_lookup():
    ps = _two_pools()
    assert ps.names() == ("onprem", "cloud")
    assert "cloud" in ps and "gpu" not in ps
    assert ps.default.name == "onprem"
    with pytest.raises(KeyError):
        ps.get("gpu")
    with pytest.raises(ValueError):
        PL.PoolSet([PL.DevicePool("a"), PL.DevicePool("a")])
    with pytest.raises(ValueError):
        PL.PoolSet([])


def test_poolset_trivial_only_for_one_unit_scale_healthy_pool():
    assert PL.single_pool().trivial
    assert not _two_pools().trivial
    assert not PL.single_pool(compute_scale=0.5).trivial
    ps = PL.single_pool()
    ps.set_health("default", False)
    assert not ps.trivial


def test_poolset_health_generation_bumps_only_on_change():
    ps = _two_pools()
    g0 = ps.generation
    ps.set_health("cloud", True)          # no-op: already healthy
    assert ps.generation == g0
    ps.set_health("cloud", False)
    assert ps.generation == g0 + 1
    assert ps.healthy_pools() == (ps.get("onprem"),)
    ps.set_health("cloud", True)
    assert ps.generation == g0 + 2


def test_default_pools_partitions_devices():
    fake = ("dev0", "dev1", "dev2", "dev3")
    ps = PL.default_pools(devices=fake)
    assert ps.get("onprem").devices == ("dev0", "dev1")
    assert ps.get("cloud").devices == ("dev2", "dev3")
    assert ps.get("onprem").n_chips == 2
    one = PL.default_pools(devices=("solo",))
    assert one.get("onprem").devices == one.get("cloud").devices


# ---------------------------------------------------------------------------
# Runtime primitives
# ---------------------------------------------------------------------------

def test_pool_gate_caps_and_release():
    gate = RT.PoolGate({"a": 1, "b": None})
    assert gate.try_acquire("a")
    assert not gate.try_acquire("a")      # at cap
    assert gate.try_acquire("b") and gate.try_acquire("b")  # unbounded
    assert gate.try_acquire(None)         # legacy plans always pass
    gate.release("a")
    assert gate.inflight("a") == 0
    assert gate.try_acquire("a")
    with pytest.raises(RuntimeError):
        gate.release("unknown")


def test_transfer_ledger_accumulates():
    led = RT.TransferLedger()
    led.record("cloud", 100)
    led.record("cloud", 50)
    assert led.bytes_for("cloud") == 150
    assert led.transfers_for("cloud") == 2
    assert led.snapshot() == {
        "cloud": {"transfer_bytes": 150, "transfers": 2}}


# ---------------------------------------------------------------------------
# Placement: both acceptance directions
# ---------------------------------------------------------------------------

def test_placement_follows_data_when_transfer_dominates(graph):
    """Snapshot resident only on pool B (cloud), slow link: the query
    must be planned onto B even though A is listed first."""
    svc = GraphAnalyticsService(pools=_two_pools(link_bandwidth=1.0))
    svc.add_graph("g", graph, pools=["cloud"])
    plan = svc.context("g").plan(GraphQuery("pagerank"))
    assert plan.pool == "cloud"
    assert plan.transfer_s == 0.0
    assert "resident" in plan.reason


def test_placement_follows_compute_when_transfer_is_cheap(graph):
    """Same residency-on-B setup, but now A (onprem) advertises a large
    compute advantage and the link is fast: the query moves to A and
    the plan carries the (tiny) transfer term."""
    ps = PL.PoolSet([
        PL.DevicePool("onprem", link_bandwidth=1e15, compute_scale=0.01),
        PL.DevicePool("cloud", link_bandwidth=1e15),
    ])
    svc = GraphAnalyticsService(pools=ps)
    svc.add_graph("g", graph, pools=["cloud"])
    plan = svc.context("g").plan(GraphQuery("pagerank"))
    assert plan.pool == "onprem"
    assert plan.transfer_s > 0.0
    assert plan.est_s is not None and np.isfinite(plan.est_s)


def test_trivial_poolset_reproduces_prepool_plans(graph):
    """The default single pool takes the legacy planning path exactly:
    ``pool=None``, same engine/variant/estimates as ``choose_plan``."""
    svc = GraphAnalyticsService()
    svc.add_graph("g", graph)
    q = GraphQuery("pagerank")
    plan = svc.context("g").plan(q)
    stats = svc.context("g").current_stats()
    legacy = P.choose_plan(stats, P.specs_for("pagerank", stats), 1)
    assert plan.pool is None
    assert plan.engine == legacy.engine
    assert plan.variant == legacy.variant
    assert P.plan_cost(plan) == P.plan_cost(legacy)


def test_pool_plans_price_scale_and_transfer(graph):
    """est_s must be compute_scale * engine_estimate + transfer, and
    plan_cost must report it (the admission/tier input)."""
    bw = 1e6
    ps = _two_pools(link_bandwidth=bw, cloud_scale=0.5)
    svc = GraphAnalyticsService(pools=ps)
    svc.add_graph("g", graph, pools=["onprem"])
    plan = svc.context("g").plan(GraphQuery("pagerank"))
    stats = svc.context("g").current_stats()
    spec = P.best_spec_for_engine(
        stats, P.specs_for("pagerank", stats), plan.engine)
    base = (P.estimate_local_cost(stats, spec) if plan.engine == "local"
            else P.estimate_dist_cost(stats, spec, 1))
    scale = 0.5 if plan.pool == "cloud" else 1.0
    transfer = 0.0 if plan.pool == "onprem" else stats.bytes_coo / bw
    assert plan.est_s == pytest.approx(scale * base + transfer)
    assert P.plan_cost(plan) == plan.est_s


# ---------------------------------------------------------------------------
# Residency, transfers, materialization
# ---------------------------------------------------------------------------

def test_execution_materializes_pool_and_charges_ledger(graph):
    ps = PL.PoolSet([
        PL.DevicePool("onprem", link_bandwidth=1e15),
        PL.DevicePool("cloud", link_bandwidth=1e15, compute_scale=0.01),
    ])
    svc = GraphAnalyticsService(pools=ps, cache_size=0)
    svc.add_graph("g", graph, pools=["onprem"])
    ctx = svc.context("g")
    plan = ctx.plan(GraphQuery("pagerank"))
    assert plan.pool == "cloud" and plan.transfer_s > 0
    gen0 = ctx.residency_generation
    svc.call("g", GraphQuery("pagerank"))
    pm = svc.metrics()["pools"]
    assert pm["cloud"]["transfers"] == 1
    assert pm["cloud"]["transfer_bytes"] == ctx.stats.bytes_coo
    assert "cloud" in ctx.residency
    assert ctx.residency_generation == gen0 + 1
    # second execution: the pool is now resident — no new transfer, and
    # the re-costed plan prices it as such
    svc.call("g", GraphQuery("pagerank"))
    assert svc.metrics()["pools"]["cloud"]["transfers"] == 1
    assert ctx.plan(GraphQuery("pagerank")).transfer_s == 0.0


def test_replica_names_merge_residency(graph):
    svc = GraphAnalyticsService(pools=_two_pools())
    c1 = svc.add_graph("a", graph, pools=["onprem"])
    c2 = svc.add_graph("b", graph, pools=["cloud"])
    assert c1 is c2                     # content-digest dedup
    assert c1.residency == frozenset({"onprem", "cloud"})


def test_remove_replica_shrinks_residency_and_invalidates_plans(graph):
    """The ISSUE-8 bugfix: cached plans that referenced a replica's
    pool must not survive ``remove_graph`` of that replica."""
    svc = GraphAnalyticsService(
        pools=_two_pools(link_bandwidth=1.0, cloud_scale=0.5))
    svc.add_graph("a", graph, pools=["onprem"])
    svc.add_graph("b", graph, pools=["cloud"])
    ctx = svc.context("a")
    q = GraphQuery("pagerank")
    plan = ctx.plan(q)
    assert plan.pool == "cloud"         # resident + compute advantage
    assert ctx.plan(q) is plan          # cached
    svc.remove_graph("b")               # the cloud replica goes away
    replan = ctx.plan(q)
    assert replan is not plan
    assert replan.pool == "onprem"      # 1 B/s link: transfer dominates
    assert ctx.residency == frozenset({"onprem"})


def test_pool_health_flip_invalidates_cached_plans(graph):
    svc = GraphAnalyticsService(
        pools=_two_pools(link_bandwidth=1.0, cloud_scale=0.5))
    svc.add_graph("g", graph)           # resident everywhere
    ctx = svc.context("g")
    q = GraphQuery("pagerank")
    assert ctx.plan(q).pool == "cloud"  # compute advantage, no transfer
    svc.set_pool_health("cloud", False)
    assert ctx.plan(q).pool == "onprem"
    svc.set_pool_health("cloud", True)
    assert ctx.plan(q).pool == "cloud"
    svc.set_pool_health("onprem", False)
    svc.set_pool_health("cloud", False)
    with pytest.raises(ValueError):     # nowhere healthy to place
        ctx.plan(GraphQuery("bfs", params={"sources": (0,)}))


def test_topology_change_rekeys_result_cache(graph):
    """A health flip must not replay results admitted under the old
    topology — but the re-executed answer is byte-identical."""
    svc = GraphAnalyticsService(pools=_two_pools())
    svc.add_graph("g", graph)
    q = GraphQuery("pagerank")
    r1 = svc.call("g", q)
    r2 = svc.call("g", q)
    assert r2.meta.get("cache") == "hit"
    svc.set_pool_health("cloud", False)
    r3 = svc.call("g", q)
    assert r3.meta.get("cache") != "hit"
    assert _bits(r1.value) == _bits(r3.value)


# ---------------------------------------------------------------------------
# Spill
# ---------------------------------------------------------------------------

def _batch_two_pool_service(graph, **pool_kw):
    svc = GraphAnalyticsService(
        pools=PL.PoolSet([
            PL.DevicePool("onprem", **pool_kw),
            PL.DevicePool("cloud", capacity=16),
        ]),
        interactive_threshold_s=0.0)    # everything lands in batch
    svc.add_graph("g", graph)
    return svc


def test_batch_spill_engages_under_capacity_pressure(graph):
    svc = _batch_two_pool_service(graph, capacity=1)
    ts = [svc.submit("g", GraphQuery("bfs", params={"sources": (i,)}))
          for i in range(4)]
    assert [t.pool for t in ts] == ["onprem", "cloud", "cloud", "cloud"]
    assert svc.stats["spilled"] == 3
    assert ts[1].tier == "batch"        # spill never changes the tier
    assert "spilled from onprem" in ts[1].plan.reason
    pm = svc.metrics()["pools"]
    assert pm["onprem"]["spilled_away"] == 3
    assert pm["onprem"]["queue_depths"]["local.batch"] == 1
    assert pm["cloud"]["queue_depths"]["local.batch"] == 3
    svc.drain()
    assert all(t.status == "done" for t in ts)
    vals = [_bits(svc.result(t).value) for t in ts]
    solo = GraphAnalyticsService()
    solo.add_graph("g", graph)
    for i, v in enumerate(vals):
        assert v == _bits(
            solo.call("g", GraphQuery("bfs", params={"sources": (i,)}))
            .value)


def test_spill_requires_residency(graph):
    """No resident alternative -> the ticket stays on its pool (spill
    sheds load, it never forces a transfer)."""
    svc = GraphAnalyticsService(
        pools=PL.PoolSet([
            PL.DevicePool("onprem", capacity=1, link_bandwidth=1.0),
            PL.DevicePool("cloud", capacity=16, link_bandwidth=1.0),
        ]),
        interactive_threshold_s=0.0)
    svc.add_graph("g", graph, pools=["onprem"])
    ts = [svc.submit("g", GraphQuery("bfs", params={"sources": (i,)}))
          for i in range(3)]
    assert [t.pool for t in ts] == ["onprem"] * 3
    assert svc.stats["spilled"] == 0


def test_spill_skips_unhealthy_pools(graph):
    svc = _batch_two_pool_service(graph, capacity=1)
    svc.set_pool_health("cloud", False)
    ts = [svc.submit("g", GraphQuery("bfs", params={"sources": (i,)}))
          for i in range(3)]
    assert [t.pool for t in ts] == ["onprem"] * 3
    assert svc.stats["spilled"] == 0


def test_concurrent_drain_matches_serial_with_spill(graph):
    def run(workers):
        svc = _batch_two_pool_service(graph, capacity=1)
        ts = [svc.submit("g", GraphQuery("bfs", params={"sources": (i,)}))
              for i in range(6)]
        svc.drain(workers=workers)
        return [_bits(svc.result(t).value) for t in ts]
    assert run(1) == run(4)


def test_pool_gate_limits_inflight(graph):
    """max_inflight=1 per pool: a 4-worker drain never runs two units
    on one pool at once (asserted via the gate's own accounting —
    release raising on over-release would catch an imbalance)."""
    svc = GraphAnalyticsService(
        pools=PL.PoolSet([
            PL.DevicePool("onprem", max_inflight=1),
            PL.DevicePool("cloud", max_inflight=1, capacity=16),
        ]),
        interactive_threshold_s=0.0, cache_size=0)
    svc.add_graph("g", graph)
    ts = [svc.submit("g", GraphQuery("pagerank",
                                     params={"max_iters": 5 + i}))
          for i in range(5)]
    svc.drain(workers=4)
    assert all(t.status == "done" for t in ts)
    pm = svc.metrics()["pools"]
    assert pm["onprem"]["inflight"] == 0
    assert pm["cloud"]["inflight"] == 0


# ---------------------------------------------------------------------------
# Cross-pool parity: every algorithm x variant
# ---------------------------------------------------------------------------

def _example_suite():
    return [(name, defn) for name, defn in R.items()
            if defn.example_params is not None]


def test_every_algorithm_and_variant_identical_across_pools(graph,
                                                            sym_graph):
    """The federation contract at the engine seam: a pool twin returns
    ``tobytes()``-identical values to the base engine and to the other
    pool's twin, for every registered algorithm and execution variant,
    on both engines."""
    pools = _two_pools().pools()
    engines = {
        False: (LocalEngine(graph), DistributedEngine(graph, n_data=4)),
        True: (LocalEngine(sym_graph),
               DistributedEngine(sym_graph, n_data=4)),
    }
    checked = 0
    for name, defn in _example_suite():
        params = dict(defn.example_params)
        for base in engines[defn.requires_symmetric]:
            if base.name not in defn.engines:
                continue
            variants = (None,) + tuple(sorted(defn.variants or ()))
            for var in variants:
                ref = _bits(base.run(name, params, variant=var).value)
                for pool in pools:
                    twin = base.for_pool(pool)
                    assert twin is not base
                    got = _bits(twin.run(name, params, variant=var).value)
                    assert got == ref, \
                        f"{name}/{var} differs on pool {pool.name}"
                checked += 1
    assert checked >= len(_example_suite())


def test_for_pool_twins_are_cached_and_share_nothing(graph):
    pools = _two_pools()
    eng = LocalEngine(graph)
    a = eng.for_pool(pools.get("onprem"))
    b = eng.for_pool(pools.get("cloud"))
    assert a is eng.for_pool(pools.get("onprem"))   # cached
    assert a is not b and a is not eng
    assert a.pool.name == "onprem" and b.pool.name == "cloud"
    assert set(eng.pool_twins()) == {"onprem", "cloud"}
    # a twin asked for its own pool is itself, not a twin-of-a-twin
    assert a.for_pool(pools.get("onprem")) is a


def test_service_results_identical_to_prepool_platform(graph):
    """End-to-end: the same queries through a two-pool service (each
    residency direction) and through the pre-federation single-pool
    platform return identical bytes."""
    queries = [GraphQuery("pagerank"),
               GraphQuery("bfs", params={"sources": (3,)}),
               GraphQuery("degree_stats")]
    plat = GraphPlatform(graph)
    for home in ("onprem", "cloud"):
        svc = GraphAnalyticsService(
            pools=_two_pools(link_bandwidth=1.0))
        svc.add_graph("g", graph, pools=[home])
        for q in queries:
            assert svc.context("g").plan(q).pool == home
            assert _bits(svc.call("g", q).value) == \
                _bits(plat.query(q).value)


# ---------------------------------------------------------------------------
# Metrics surface
# ---------------------------------------------------------------------------

def test_metrics_pools_section_shape(graph):
    svc = _batch_two_pool_service(graph, capacity=1)
    svc.submit("g", GraphQuery("pagerank"))
    m = svc.metrics()
    assert set(m["pools"]) == {"onprem", "cloud"}
    row = m["pools"]["onprem"]
    assert {"healthy", "capacity", "max_inflight", "inflight",
            "queue_depths", "transfer_bytes", "transfers",
            "spilled_away"} <= set(row)
    assert m["counters"]["spilled"] == 0
    # the aggregate engine.tier view is preserved for pre-pool callers
    assert m["queue_depths"]["local.batch"] == 1
    assert row["queue_depths"]["local.batch"] == 1


def test_trivial_pool_metrics_mirror_aggregate_depths(graph):
    svc = GraphAnalyticsService(interactive_threshold_s=0.0)
    svc.add_graph("g", graph)
    svc.submit("g", GraphQuery("pagerank"))
    m = svc.metrics()
    assert m["queue_depths"]["local.batch"] == 1
    assert m["pools"]["default"]["queue_depths"]["local.batch"] == 1


# ---------------------------------------------------------------------------
# Reference calibration roundtrip (the ROADMAP calibration residue)
# ---------------------------------------------------------------------------

def test_reference_profile_is_checked_in_and_autoloads():
    assert P.AUTO_LOADED_REFERENCE, \
        "reference_profile.json missing or unparseable at import"
    ref = P.CalibrationProfile.from_json(P.reference_profile_path())
    assert ref.source != "analytic-defaults"
    assert ref.algo_time_scale            # fitted, non-empty


def test_load_reference_calibration_bumps_generation_and_applies():
    gen0 = P.calibration_generation()
    ref = P.load_reference_calibration()
    assert P.calibration_generation() == gen0 + 1
    assert P.active_calibration() is ref
    # live services follow the active profile's tier thresholds
    svc = GraphAnalyticsService()
    assert svc.interactive_threshold_s == ref.interactive_threshold_s
    P.set_calibration(None)
    assert P.calibration_generation() == gen0 + 2
    assert P.active_calibration().source == "analytic-defaults"


def test_reference_profile_roundtrips_through_json(tmp_path):
    ref = P.CalibrationProfile.from_json(P.reference_profile_path())
    out = tmp_path / "copy.json"
    ref.to_json(out)
    again = P.CalibrationProfile.from_json(out)
    assert again == ref
