"""Architecture registry: config.family -> Model class."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family == "dense":
        from repro.models.transformer import DenseLM
        return DenseLM(cfg)
    if cfg.family == "moe":
        from repro.models.moe import MoELM
        return MoELM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM
        return HybridLM(cfg)
    if cfg.family == "ssm":
        from repro.models.xlstm import XLSTMLM
        return XLSTMLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLM
        return VLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
