"""Hypothesis property tests for federation placement monotonicity.

The invariant the planner's data-locality term must satisfy: making a
remote pool *less* attractive — lowering its link bandwidth (raising
the transfer cost) or revoking the snapshot's residency there — can
never flip placement *toward* that pool.  Whatever graph shape,
variant set, or compute scales are in play, the cost model is monotone
in the transfer term.

``hypothesis`` is an *optional* test dependency (declared under the
``test`` extra in pyproject.toml); the whole module skips cleanly when
it is not installed so the tier-1 suite still collects.
"""
import pytest

pytest.importorskip("hypothesis", reason="optional test dep: hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import planner as P  # noqa: E402
from repro.core import pools as PL  # noqa: E402


def _stats(n_vertices, degree):
    n_edges = n_vertices * degree
    return P.GraphStats(n_vertices, n_edges, n_edges * 12)


def _plan(stats, onprem_bw, cloud_bw, cloud_scale, resident):
    ps = PL.PoolSet([
        PL.DevicePool("onprem", link_bandwidth=onprem_bw),
        PL.DevicePool("cloud", link_bandwidth=cloud_bw,
                      compute_scale=cloud_scale),
    ])
    specs = P.specs_for("pagerank", stats)
    return P.choose_plan(stats, specs, 4, pools=ps.pools(),
                         resident=resident)


@settings(max_examples=60, deadline=None)
@given(
    n_vertices=st.integers(100, 10_000_000),
    degree=st.integers(1, 64),
    cloud_scale=st.floats(0.01, 2.0),
    bw=st.floats(1.0, 1e12),
    shrink=st.floats(1.5, 1e6),
)
def test_raising_remote_transfer_cost_never_attracts_work(
        n_vertices, degree, cloud_scale, bw, shrink):
    """Snapshot resident on-prem only.  If the planner keeps work on
    onprem at link bandwidth ``bw``, it must still keep it there at
    ``bw / shrink`` (a strictly more expensive transfer)."""
    stats = _stats(n_vertices, degree)
    before = _plan(stats, bw, bw, cloud_scale, resident={"onprem"})
    after = _plan(stats, bw / shrink, bw / shrink, cloud_scale,
                  resident={"onprem"})
    if before.pool == "onprem":
        assert after.pool == "onprem"
    # and the contrapositive: work only ever moves *back* toward the
    # resident pool as the link degrades
    if after.pool == "cloud":
        assert before.pool == "cloud"


@settings(max_examples=60, deadline=None)
@given(
    n_vertices=st.integers(100, 10_000_000),
    degree=st.integers(1, 64),
    cloud_scale=st.floats(0.01, 2.0),
    bw=st.floats(1.0, 1e12),
)
def test_revoking_residency_never_attracts_work(
        n_vertices, degree, cloud_scale, bw):
    """If the planner avoids the cloud pool while the snapshot is
    resident there (zero transfer), it must still avoid it once the
    replica is gone and the same placement costs a transfer."""
    stats = _stats(n_vertices, degree)
    both = _plan(stats, bw, bw, cloud_scale,
                 resident={"onprem", "cloud"})
    revoked = _plan(stats, bw, bw, cloud_scale, resident={"onprem"})
    if both.pool == "onprem":
        assert revoked.pool == "onprem"
    if revoked.pool == "cloud":
        assert both.pool == "cloud"


@settings(max_examples=60, deadline=None)
@given(
    n_vertices=st.integers(100, 10_000_000),
    degree=st.integers(1, 64),
    cloud_scale=st.floats(0.01, 2.0),
    bw=st.floats(1.0, 1e12),
)
def test_pool_costs_are_what_the_plan_says(
        n_vertices, degree, cloud_scale, bw):
    """est_s is exactly scale * engine_estimate + transfer for the
    chosen placement, and plan_cost reports it."""
    stats = _stats(n_vertices, degree)
    plan = _plan(stats, bw, bw, cloud_scale, resident={"onprem"})
    specs = [s for s in P.specs_for("pagerank", stats)
             if s.variant == plan.variant]
    assert len(specs) == 1
    base = (P.estimate_local_cost(stats, specs[0])
            if plan.engine == "local"
            else P.estimate_dist_cost(stats, specs[0], 4))
    scale = cloud_scale if plan.pool == "cloud" else 1.0
    expect = scale * base + plan.transfer_s
    assert plan.est_s == pytest.approx(expect, rel=1e-9)
    assert P.plan_cost(plan) == plan.est_s
