"""The two engines of the hybrid platform.

``LocalEngine``        — the Neo4j analogue: one device, CSR/ELL resident
                         in HBM, every query jit-compiled, count-only fast
                         paths that never materialize results.
``DistributedEngine``  — the Spark/GraphFrames analogue: edge-partitioned
                         BSP supersteps over a device mesh (shard_map),
                         scales to graphs and outputs that cannot live on
                         one device.

Both implement the same ``Engine`` protocol so the planner can route a
query to either — the paper's central architectural claim is that a
production platform needs *both* (Section IV-B / Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core.partition import ShardedCOO, partition
# NOTE: algorithms/__init__ re-exports functions under the submodule
# names, so import through the full dotted path (sys.modules-safe).
import importlib
_pr = importlib.import_module("repro.core.algorithms.pagerank")
_cc = importlib.import_module("repro.core.algorithms.connected_components")
_th = importlib.import_module("repro.core.algorithms.two_hop")
_deg = importlib.import_module("repro.core.algorithms.degrees")
_sim = importlib.import_module("repro.core.algorithms.similarity")
_tr = importlib.import_module("repro.core.algorithms.traversal")
_cm = importlib.import_module("repro.core.algorithms.community")
_tg = importlib.import_module("repro.core.algorithms.triangles")
from repro.kernels.ell_combine import ops as ell_ops


@dataclasses.dataclass
class QueryResult:
    value: object                 # scalar, array, or (pairs, valid)
    engine: str                   # 'local' | 'distributed'
    iterations: Optional[int] = None
    meta: dict = dataclasses.field(default_factory=dict)


class LocalEngine:
    """Single-device in-memory engine (Neo4j analogue).

    Holds the graph in ELL (+ the exact COO for uncapped queries).  All
    algorithm loops run through the Pallas ``ell_combine`` kernel path
    when shapes are TPU-tileable, else the jnp reference — same numerics.
    """

    name = "local"

    def __init__(self, coo: G.GraphCOO, max_degree: int = 128,
                 use_pallas: bool = False):
        self.coo = coo
        src = np.asarray(coo.src)[: coo.n_edges]
        dst = np.asarray(coo.dst)[: coo.n_edges]
        w = np.asarray(coo.w)[: coo.n_edges]
        self.ell = G.build_ell(src, dst, coo.n_vertices, max_degree, w=w,
                               direction="in")
        self.use_pallas = use_pallas
        self._spmv = ell_ops.ell_spmv if use_pallas else ell_ops.ell_spmv_ref
        self._sharded_cache = None

    @property
    def _sharded(self) -> ShardedCOO:
        """One-shard edge layout, packed once — repeated interactive
        queries must not repay the O(E) host-side partition."""
        if self._sharded_cache is None:
            self._sharded_cache = partition(self.coo, 1, 1)
        return self._sharded_cache

    # -- algorithms --------------------------------------------------------
    def pagerank(self, alpha=0.85, tol=1e-8, max_iters=100) -> QueryResult:
        ranks, iters = _pr.pagerank(self.coo, alpha=alpha, tol=tol,
                                    max_iters=max_iters)
        return QueryResult(ranks, self.name, int(iters))

    def connected_components(self, max_iters=200) -> QueryResult:
        labels, iters = _cc.connected_components(self.coo, max_iters=max_iters,
                                                 sharded=self._sharded)
        return QueryResult(labels, self.name, int(iters))

    def num_components(self, max_iters=200) -> QueryResult:
        """Count-only fast path — the '2 seconds vs 10 minutes' query."""
        labels, iters = _cc.connected_components(self.coo, max_iters=max_iters,
                                                 sharded=self._sharded)
        return QueryResult(_cc.num_components(labels), self.name, int(iters))

    def two_hop_pairs(self, n_users: int, dedup=True) -> QueryResult:
        pairs, valid, count = _th.two_hop_pairs(self.ell, n_users, dedup=dedup)
        return QueryResult((pairs, valid, int(count)), self.name)

    def two_hop_count(self) -> QueryResult:
        deg = jnp.sum(self.ell.mask, axis=1)
        return QueryResult(int(_th.two_hop_count_upper_bound(deg)), self.name)

    def degree_stats(self) -> QueryResult:
        return QueryResult(_deg.degree_stats(self.coo), self.name)

    def jaccard(self, u, v) -> QueryResult:
        return QueryResult(_sim.jaccard_similarity(self.ell, u, v), self.name)

    def bfs(self, sources, max_iters=None) -> QueryResult:
        dist, iters = _tr.bfs_distances(self.coo, sources,
                                        max_iters=max_iters,
                                        sharded=self._sharded)
        return QueryResult(dist, self.name, int(iters))

    def reachable_count(self, sources, max_iters=None) -> QueryResult:
        """Count-only fast path: |reachable set| without the table."""
        dist, iters = _tr.bfs_distances(self.coo, sources,
                                        max_iters=max_iters,
                                        sharded=self._sharded)
        return QueryResult(_tr.reachable_count(dist), self.name, int(iters))

    def sssp(self, source, max_iters=None) -> QueryResult:
        dist, iters = _tr.sssp(self.coo, source, max_iters=max_iters,
                               sharded=self._sharded)
        return QueryResult(dist, self.name, int(iters))

    def label_propagation(self, max_iters=30, n_channels=64) -> QueryResult:
        labels, iters = _cm.label_propagation(
            self.coo, max_iters=max_iters, n_channels=n_channels,
            sharded=self._sharded)
        return QueryResult(labels, self.name, int(iters))

    def num_communities(self, max_iters=30, n_channels=64) -> QueryResult:
        """Count-only fast path — the paper's '2 s vs 10 min' pattern."""
        labels, iters = _cm.label_propagation(
            self.coo, max_iters=max_iters, n_channels=n_channels,
            sharded=self._sharded)
        return QueryResult(_cm.num_communities(labels), self.name, int(iters))

    def triangle_count(self) -> QueryResult:
        count, _ = _tg.triangle_count(self.coo, sharded=self._sharded)
        return QueryResult(count, self.name, 2)

    def k_core(self, k, max_iters=None) -> QueryResult:
        members, iters = _tg.k_core(self.coo, k, max_iters=max_iters,
                                    sharded=self._sharded)
        return QueryResult(members, self.name, int(iters))

    def k_core_size(self, k, max_iters=None) -> QueryResult:
        members, iters = _tg.k_core(self.coo, k, max_iters=max_iters,
                                    sharded=self._sharded)
        return QueryResult(_tg.core_size(members), self.name, int(iters))


class DistributedEngine:
    """Edge-partitioned BSP engine over a device mesh (Spark analogue)."""

    name = "distributed"

    def __init__(self, coo: G.GraphCOO, mesh=None,
                 n_data: Optional[int] = None, n_model: int = 1):
        self.coo = coo
        self.mesh = mesh
        if mesh is not None:
            axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self.n_data = axis_sizes.get("data", 1)
            self.n_model = axis_sizes.get("model", 1) if n_model > 1 else 1
        else:
            self.n_data = n_data or 1
            self.n_model = n_model
        self.sharded: ShardedCOO = partition(coo, self.n_data, self.n_model)
        self._pr_cache = None

    def pagerank(self, alpha=0.85, tol=1e-8, max_iters=100) -> QueryResult:
        if self._pr_cache is None:
            self._pr_cache = _pr._normalize_and_partition(
                self.coo, self.n_data, self.n_model)
        sharded, dangling = self._pr_cache
        ranks, iters = _pr.pagerank(
            self.coo, alpha=alpha, tol=tol, max_iters=max_iters,
            mesh=self.mesh, sharded=sharded, dangling=dangling)
        return QueryResult(ranks, self.name, int(iters))

    def connected_components(self, max_iters=200) -> QueryResult:
        labels, iters = _cc.connected_components(
            self.coo, max_iters=max_iters, mesh=self.mesh,
            sharded=self.sharded, accelerated=self.n_model == 1)
        return QueryResult(labels, self.name, int(iters))

    def num_components(self, max_iters=200) -> QueryResult:
        labels, iters = _cc.connected_components(
            self.coo, max_iters=max_iters, mesh=self.mesh,
            sharded=self.sharded, accelerated=self.n_model == 1)
        return QueryResult(_cc.num_components(labels), self.name, int(iters))

    def two_hop_pairs(self, n_users: int, max_degree: int = 128,
                      dedup=True) -> QueryResult:
        # Motif expansion shards trivially over identifier rows; on a mesh
        # each data shard expands its rows and dedup runs on the gathered
        # keys (output large => parallel expansion is the win, cf Fig. 5).
        src = np.asarray(self.coo.src)[: self.coo.n_edges]
        dst = np.asarray(self.coo.dst)[: self.coo.n_edges]
        ell = G.build_ell(src, dst, self.coo.n_vertices, max_degree,
                          direction="in")
        nbr = jnp.where(ell.mask, ell.nbr, n_users)
        ell = G.GraphELL(nbr, ell.mask, ell.w, ell.n_vertices,
                         ell.n_edges, ell.n_edges_total)
        pairs, valid, count = _th.two_hop_pairs(ell, n_users, dedup=dedup)
        return QueryResult((pairs, valid, int(count)), self.name)

    def two_hop_count(self, max_degree: int = 128) -> QueryResult:
        deg = G.in_degrees(self.coo)
        return QueryResult(int(_th.two_hop_count_upper_bound(deg)), self.name)

    def degree_stats(self) -> QueryResult:
        return QueryResult(_deg.degree_stats(self.coo), self.name)

    def bfs(self, sources, max_iters=None) -> QueryResult:
        dist, iters = _tr.bfs_distances(
            self.coo, sources, max_iters=max_iters, mesh=self.mesh,
            sharded=self.sharded)
        return QueryResult(dist, self.name, int(iters))

    def reachable_count(self, sources, max_iters=None) -> QueryResult:
        dist, iters = _tr.bfs_distances(
            self.coo, sources, max_iters=max_iters, mesh=self.mesh,
            sharded=self.sharded)
        return QueryResult(_tr.reachable_count(dist), self.name, int(iters))

    def sssp(self, source, max_iters=None) -> QueryResult:
        dist, iters = _tr.sssp(
            self.coo, source, max_iters=max_iters, mesh=self.mesh,
            sharded=self.sharded)
        return QueryResult(dist, self.name, int(iters))

    def label_propagation(self, max_iters=30, n_channels=64) -> QueryResult:
        labels, iters = _cm.label_propagation(
            self.coo, max_iters=max_iters, n_channels=n_channels,
            mesh=self.mesh, sharded=self.sharded)
        return QueryResult(labels, self.name, int(iters))

    def num_communities(self, max_iters=30, n_channels=64) -> QueryResult:
        labels, iters = _cm.label_propagation(
            self.coo, max_iters=max_iters, n_channels=n_channels,
            mesh=self.mesh, sharded=self.sharded)
        return QueryResult(_cm.num_communities(labels), self.name, int(iters))

    def triangle_count(self) -> QueryResult:
        count, _ = _tg.triangle_count(self.coo, mesh=self.mesh,
                                      sharded=self.sharded)
        return QueryResult(count, self.name, 2)

    def k_core(self, k, max_iters=None) -> QueryResult:
        members, iters = _tg.k_core(self.coo, k, max_iters=max_iters,
                                    mesh=self.mesh, sharded=self.sharded)
        return QueryResult(members, self.name, int(iters))

    def k_core_size(self, k, max_iters=None) -> QueryResult:
        members, iters = _tg.k_core(self.coo, k, max_iters=max_iters,
                                    mesh=self.mesh, sharded=self.sharded)
        return QueryResult(_tg.core_size(members), self.name, int(iters))
