from repro.kernels.ell_intersect.ops import (
    ell_intersect, ell_intersect_counts, ell_intersect_rows_ref)
