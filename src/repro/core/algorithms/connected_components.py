"""Combined connected users == connected components on the unified graph.

The paper's second flagship workload: the legacy Scalding pipeline ran CC
*separately per identifier edge-set* then merged (17-29 h); GraphFrames
builds ONE graph over all identifiers and runs CC directly (40 min, 37x).
We implement that unified formulation as hash-to-min label propagation:

    label[v] <- min(label[v], min_{u in N(v)} label[u])

on the symmetrized edge list, iterated to fixpoint inside one XLA while
loop.  ``accelerated=True`` adds pointer-jumping (label <- label[label])
each round — O(log d) instead of O(d) rounds (beyond-paper optimization;
GraphFrames' large-star/small-star needs dynamic edge mutation, which a
static-shape TPU program cannot do, pointer jumping gets the same
asymptotics with a pure gather).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, converged_halt, run_pregel


def _cc_message(lbl_src, w):
    return lbl_src


def _cc_apply(lbl, agg, ids, gval):
    return jnp.minimum(lbl, agg)


def _cc_apply_jump(lbl, agg, ids, gval):
    # pointer jumping: labels are vertex ids, chase one hop
    new = jnp.minimum(lbl, agg)
    return jnp.minimum(new, new[jnp.clip(new, 0, new.shape[0] - 1)])


# Hash-to-min is a monotone min fold, so frontier compression is exact;
# the default activity predicate (state != identity) marks every vertex
# active in round 1, as labels start at their own vertex id.  Pointer
# jumping lives in ``apply``, which every superstep strategy runs
# densely — the frontier only prunes *message* work.
_CC_SPEC = PregelSpec(message=_cc_message, combine="min", apply=_cc_apply,
                      identity=np.iinfo(np.int32).max, halt=converged_halt,
                      elementwise_message=True, frontier_mode="monotone")
_CC_SPEC_JUMP = PregelSpec(message=_cc_message, combine="min",
                           apply=_cc_apply_jump,
                           identity=np.iinfo(np.int32).max,
                           halt=converged_halt, elementwise_message=True,
                           frontier_mode="monotone")


def connected_components(
    g: G.GraphCOO,
    max_iters: int = 200,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    accelerated: bool = True,
    sharded: Optional[ShardedCOO] = None,
):
    """Returns (labels [V] int32 — min vertex id per component, iters).

    ``g`` must already be symmetrized (``build_coo(..., symmetrize=True)``);
    isolated vertices keep their own id.
    """
    V = g.n_vertices
    if sharded is None:
        sharded = partition(g, n_data, n_model)
    v_local = sharded.v_local
    replicated = sharded.n_model == 1
    spec = _CC_SPEC_JUMP if (accelerated and replicated) else _CC_SPEC
    if replicated:
        init = jnp.arange(V, dtype=jnp.int32)
    else:
        n_pad = sharded.n_model * v_local
        init = jnp.arange(n_pad, dtype=jnp.int32)
    labels, iters = run_pregel(spec, sharded, init, max_iters, mesh=mesh)
    return labels[:V], iters


def num_components(labels) -> int:
    """Count-only fast path (the query where the paper's Neo4j wins 300x:
    'Neo4j takes <2 s to return the count, Spark spends ~10 min')."""
    V = labels.shape[0]
    is_root = labels == jnp.arange(V, dtype=labels.dtype)
    return int(jnp.sum(is_root))


# ------------------------------------------------------------ registration

def _engine_run(eng, max_iters):
    return connected_components(
        eng.coo, max_iters=max_iters, mesh=eng.mesh, sharded=eng.sharded,
        accelerated=eng.n_model == 1)


def _cc_variant(mode):
    """Superstep-variant runner: same spec/init choices as
    ``connected_components``, dispatched through the engine's superstep
    choke point (which falls back to dense when unsupported)."""
    def run(eng, max_iters):
        sharded = eng.sharded
        replicated = sharded.n_model == 1
        spec = _CC_SPEC_JUMP if replicated else _CC_SPEC
        init = jnp.arange(sharded.n_pad, dtype=jnp.int32)
        labels, iters = eng.run_superstep(spec, init, max_iters,
                                          variant=mode)
        return labels[: eng.coo.n_vertices], int(iters)
    return run


def _cc_incremental(eng, params, seed, delta):
    """Localized repair for *add-only* deltas.

    The previous snapshot's labels are min-ids of old components; on an
    add-only delta every old label is an elementwise upper bound on the
    new fixpoint, and for every old edge ``u -> v`` the old fixpoint
    already satisfies ``label[v] <= label[u]`` — untouched sources'
    messages are no-ops.  Seeding the state with the old labels and the
    frontier with the delta's touched endpoints therefore runs exactly
    the repair wavefront and converges to the cold answer's canonical
    min-id labels, byte for byte.  Removals can split components
    (labels would need to *rise*), so those decline to a cold run.
    """
    if delta is None or delta.n_removed:
        return None
    prev = np.asarray(getattr(seed, "value", seed))
    V = eng.coo.n_vertices
    if prev.ndim != 1 or prev.shape[0] > V or prev.dtype.kind not in "iu":
        return None
    sharded = eng.sharded
    init = np.arange(sharded.n_pad, dtype=np.int32)
    init[: prev.shape[0]] = prev
    act = np.zeros(V, dtype=bool)
    touched = np.asarray(delta.touched)
    act[touched[touched < V]] = True
    spec = _CC_SPEC_JUMP if sharded.n_model == 1 else _CC_SPEC
    labels, iters = eng.run_superstep(
        spec, jnp.asarray(init), params["max_iters"], variant="auto",
        init_active=jnp.asarray(act))
    if int(iters) >= params["max_iters"]:
        return None          # budget exhausted before the fixpoint
    return labels[:V], int(iters)


def _cost(g: P.GraphStats, params: dict, count_only: bool):
    # pointer-jumping converges in O(log d) rounds; honour a tighter
    # user-supplied cap (the planner must not cost a 4-superstep query
    # at the analytic 16)
    iters = min(16, params.get("max_iters") or 16)
    return P.superstep_specs("connected_components",
                             output_rows=1 if count_only else g.n_vertices,
                             iterations=iters)


R.register(R.AlgorithmDef(
    name="connected_components",
    run=_engine_run,
    params=(
        R.Param("max_iters", 200, check=lambda n: n >= 1, normalize=int),
    ),
    count=num_components,
    count_method="num_components",
    cost=_cost,
    variants={"dense": _cc_variant("dense"),
              "fused": _cc_variant("fused"),
              "frontier": _cc_variant("frontier")},
    requires_symmetric=True,
    incremental=_cc_incremental,
    doc="Hash-to-min label propagation with pointer-jumping acceleration.",
))


def connected_components_reference(src, dst, n_vertices):
    """Union-find oracle (numpy, host) for tests."""
    parent = np.arange(n_vertices, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for s, d in zip(np.asarray(src), np.asarray(dst)):
        rs, rd = find(int(s)), find(int(d))
        if rs != rd:
            if rs < rd:
                parent[rd] = rs
            else:
                parent[rs] = rd
    return np.array([find(i) for i in range(n_vertices)], dtype=np.int32)
