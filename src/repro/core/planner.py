"""Cost-based engine router — the paper's Fig. 5 finding made executable.

The paper's empirical law:

* small/medium graph, small output  -> local engine (Neo4j) wins
  ("Neo4j takes <2 s to return the count, Spark spends ~10 min");
* very large graph OR very large output -> distributed engine (Spark)
  wins; beyond single-instance memory it is the only option;
* the crossover sits around ~10M vertices for per-vertex outputs on their
  hardware (Fig. 5) and "less than 100 million edges and vertices" is the
  paper's rule of thumb for Neo4j.

Instead of a hard-coded threshold we keep an analytic cost model over the
TPU substrate (HBM bandwidth for the local engine, per-superstep launch +
collective volume for the distributed engine, host egress for outputs)
whose constants are calibrated by ``benchmarks/fig5_engine_crossover.py``.
The model intentionally has few terms — it must be explainable to the
user in the query plan, like the paper's rule of thumb was.

Two feedback loops replace analytic guesses with measurements:

* ``GraphStats`` carries optional *measured* fields (observed max
  in-degree, the built ``OrientedELL`` row width) that engines feed back
  from derived state they have already paid to build — cost hooks prefer
  them over their analytic stand-ins.
* The model constants live in a :class:`CalibrationProfile` that
  ``benchmarks/algo_suite.py --emit-calibration`` writes from wall-clock
  measurements and :func:`load_calibration` applies process-wide —
  including the service tier thresholds (interactive-vs-batch
  classification and the admission budget).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Mapping, Optional, Sequence

from repro.core import registry

# TPU v5e-flavored constants (per chip) — the analytic defaults that seed
# CalibrationProfile; estimates read the *active profile*, so
# load_calibration overrides these without touching module globals.
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link
HOST_EGRESS_BW = 4e9      # B/s device->host for result materialization
LOCAL_DISPATCH_S = 2e-4   # jitted query launch
DIST_STEP_S = 1.5e-3      # per-superstep launch + sync on a mesh
LOCAL_MEM_BUDGET = 12e9   # usable HBM for the local engine's graph

# Analytic per-superstep edge-traffic multipliers for the superstep
# execution variants (relative to the dense gather/segment-combine
# path's raw edge bytes).  The fused kernel streams the same edges but
# skips the [E] message materialization and the segment-sort; the
# frontier path touches only edges incident to active vertices —
# averaged over a BFS-like run the active fraction is small.  A fitted
# CalibrationProfile (``superstep_edge_bytes``) overrides these.
_SUPERSTEP_EDGE_BYTES = {"dense": 1.0, "fused": 0.75, "frontier": 0.15}


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Static graph shape plus optional *measured* structure.

    ``max_degree`` (observed max in-degree) and ``oriented_width`` (the
    built ``OrientedELL`` row width) default to ``None`` — unknown until
    an engine has built the corresponding derived state and fed it back
    (``Engine.measurements``).  Cost hooks fall back to analytic
    estimates when a field is ``None``.
    """

    n_vertices: int
    n_edges: int
    bytes_coo: int
    max_degree: Optional[int] = None
    oriented_width: Optional[int] = None
    max_out_degree: Optional[int] = None

    @classmethod
    def of(cls, graph) -> "GraphStats":
        return cls(graph.n_vertices, graph.n_edges, graph.nbytes())

    def with_measurements(self, meas: Mapping[str, int]) -> "GraphStats":
        """Stats with measured fields merged in (unknown keys rejected,
        ``None`` values ignored)."""
        fields = {"max_degree", "oriented_width", "max_out_degree"}
        unknown = sorted(set(meas) - fields)
        if unknown:
            raise ValueError(f"unknown measurement(s) {unknown}")
        updates = {k: int(v) for k, v in meas.items() if v is not None}
        return dataclasses.replace(self, **updates) if updates else self


# ---------------------------------------------------------------------------
# Calibration profile — the model constants as loadable data
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Every constant the cost model and the service tiering consume.

    ``algo_time_scale`` maps an algorithm name to a measured/modeled
    wall-clock ratio: ``benchmarks/algo_suite.py --emit-calibration``
    fits one multiplier per algorithm from its timing sweep, so the
    planner's relative estimates are anchored to real executions instead
    of the analytic bandwidth terms alone.  ``interactive_threshold_s``
    and ``admission_budget_s`` are the service tier thresholds
    (interactive tickets bypass the batch queue; queries estimated above
    the budget are rejected at submit with the plan attached).
    """

    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    host_egress_bw: float = HOST_EGRESS_BW
    local_dispatch_s: float = LOCAL_DISPATCH_S
    dist_step_s: float = DIST_STEP_S
    local_mem_budget: float = LOCAL_MEM_BUDGET
    interactive_threshold_s: float = 0.05
    admission_budget_s: float = float("inf")
    algo_time_scale: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    # Per-superstep edge-traffic multipliers for the superstep execution
    # variants (overrides of _SUPERSTEP_EDGE_BYTES; fitted by
    # ``benchmarks/algo_suite.py --emit-calibration`` from per-variant
    # timings).
    superstep_edge_bytes: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    source: str = "analytic-defaults"

    def scale(self, algorithm: str) -> float:
        return float(self.algo_time_scale.get(algorithm, 1.0))

    def superstep_factor(self, variant: str) -> float:
        """Edge-bytes multiplier for a superstep variant."""
        base = _SUPERSTEP_EDGE_BYTES.get(variant, 1.0)
        return float(self.superstep_edge_bytes.get(variant, base))

    def to_json(self, path) -> None:
        d = dataclasses.asdict(self)
        d["algo_time_scale"] = dict(self.algo_time_scale)
        d["superstep_edge_bytes"] = dict(self.superstep_edge_bytes)
        if d["admission_budget_s"] == float("inf"):
            d["admission_budget_s"] = None        # JSON has no inf
        with open(path, "w") as f:
            json.dump(d, f, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, path) -> "CalibrationProfile":
        with open(path) as f:
            d = json.load(f)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"calibration profile {path}: unknown "
                             f"field(s) {unknown}")
        if d.get("admission_budget_s") is None:
            d["admission_budget_s"] = float("inf")
        d["algo_time_scale"] = {
            str(k): float(v)
            for k, v in (d.get("algo_time_scale") or {}).items()}
        d["superstep_edge_bytes"] = {
            str(k): float(v)
            for k, v in (d.get("superstep_edge_bytes") or {}).items()}
        return cls(**d)


#: The checked-in calibration residue: a reference profile emitted by
#: ``benchmarks/algo_suite.py --emit-calibration`` on a real box.  It is
#: auto-loaded at import so production callers start from measured
#: constants; tests pin the analytic defaults (``set_calibration(None)``
#: in ``tests/conftest.py``) because the fitted values are
#: box-specific.
_REFERENCE_PROFILE = os.path.join(os.path.dirname(__file__),
                                  "calibration", "reference_profile.json")


def reference_profile_path() -> str:
    return _REFERENCE_PROFILE


def _load_reference() -> Optional["CalibrationProfile"]:
    try:
        return CalibrationProfile.from_json(_REFERENCE_PROFILE)
    except (OSError, ValueError, TypeError):
        return None


_REFERENCE = _load_reference()
#: True when the checked-in reference profile parsed and became the
#: import-time default (the calibration-residue contract).
AUTO_LOADED_REFERENCE = _REFERENCE is not None

_ACTIVE_PROFILE = _REFERENCE if _REFERENCE is not None \
    else CalibrationProfile()
_PROFILE_GENERATION = 0    # bumped on every swap; plan caches key on it


def active_calibration() -> CalibrationProfile:
    return _ACTIVE_PROFILE


def calibration_generation() -> int:
    """Monotone counter of profile swaps — cached plans costed under an
    older generation are stale and must be re-costed."""
    return _PROFILE_GENERATION


def set_calibration(profile: Optional[CalibrationProfile]) \
        -> CalibrationProfile:
    """Install ``profile`` process-wide (``None`` restores the analytic
    defaults).  Returns the now-active profile."""
    global _ACTIVE_PROFILE, _PROFILE_GENERATION
    _ACTIVE_PROFILE = profile if profile is not None else CalibrationProfile()
    _PROFILE_GENERATION += 1
    return _ACTIVE_PROFILE


def load_calibration(path) -> CalibrationProfile:
    """Load a ``--emit-calibration`` profile and make it active."""
    return set_calibration(CalibrationProfile.from_json(path))


def load_reference_calibration() -> CalibrationProfile:
    """(Re-)install the checked-in reference profile — the explicit form
    of the import-time auto-load (tests that pinned the analytic
    defaults use this to opt back in)."""
    return load_calibration(_REFERENCE_PROFILE)


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """What the planner needs to know about a query.

    output_rows: expected result cardinality (1 for counts; V for
    per-vertex tables; pair-count estimates for motifs).
    iterations: expected supersteps (1 for motifs/degrees).
    row_bytes: bytes per output row.
    state_bytes_per_vertex: per-superstep vertex-state traffic (8 for
    scalar programs; triangle counting's neighborhood bitsets are
    ~V/8 bytes per vertex — the term that pushes it distributed early).
    edge_bytes_factor: message-volume multiplier over the raw edge bytes
    (1 for scalar messages; label propagation's 2C-channel structured
    messages move ~2C*4/12 times the edge list per superstep).
    variant: when an algorithm registers several execution strategies
    (triangle counting's bitset vs ELL-intersect paths), its cost hook
    returns one QuerySpec per variant and ``choose_plan`` picks the
    cheapest feasible (engine, variant) pair.
    """
    algorithm: str
    output_rows: int
    iterations: int = 1
    row_bytes: int = 8
    state_bytes_per_vertex: float = 8.0
    edge_bytes_factor: float = 1.0
    variant: Optional[str] = None


def superstep_specs(algorithm: str, *, output_rows: int, iterations: int,
                    row_bytes: int = 8, state_bytes_per_vertex: float = 8.0,
                    frontier: bool = True) -> tuple:
    """Per-variant QuerySpecs for a superstep-variant algorithm.

    One spec per execution strategy (dense / fused / frontier), differing
    only in ``edge_bytes_factor`` — the active profile's per-variant
    multiplier.  Dense comes first so cost ties keep the oracle path
    (``choose_plan`` prefers earlier specs on ties).
    """
    pr = _ACTIVE_PROFILE
    names = ("dense", "fused", "frontier") if frontier \
        else ("dense", "fused")
    return tuple(
        QuerySpec(algorithm, output_rows, iterations=iterations,
                  row_bytes=row_bytes,
                  state_bytes_per_vertex=state_bytes_per_vertex,
                  edge_bytes_factor=pr.superstep_factor(v), variant=v)
        for v in names)


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One row of the planner's candidate table — a
    (pool, engine, variant, mode) combination with its cost terms.

    ``choose_plan`` records every combination it costed (not just the
    winner) on ``Plan.candidates``, so ``service.explain()`` can show
    the losing placements and why they lost.  ``feasible=False`` rows
    were never in the running (infinite cost, unhealthy pool, engine
    excluded by a capability clamp) and carry the ``note``; exactly one
    row has ``chosen=True``.
    """

    engine: str
    variant: Optional[str] = None
    pool: Optional[str] = None
    mode: str = "full"
    est_s: float = float("inf")
    compute_s: float = float("inf")
    transfer_s: float = 0.0
    feasible: bool = True
    chosen: bool = False
    note: str = ""


def mark_chosen(candidates, engine, variant=None, pool=None,
                mode="full", note="") -> tuple:
    """Re-mark the candidate table after the winner changed outside
    ``choose_plan`` (the service's ``force_engine`` / capability-clamp
    re-plan, ``price_incremental`` mode flips).  Exactly the matching
    (engine, variant, pool, mode) row becomes chosen; if no row matches
    (the override picked a combination the table never costed) a
    synthetic chosen row is appended with ``note``."""
    out, hit = [], False
    for c in candidates:
        chosen = (not hit and c.engine == engine and c.variant == variant
                  and c.pool == pool and c.mode == mode)
        hit = hit or chosen
        if c.chosen != chosen:
            c = dataclasses.replace(c, chosen=chosen)
        out.append(c)
    if not hit:
        out.append(PlanCandidate(engine, variant, pool, mode,
                                 chosen=True, note=note))
    return tuple(out)


@dataclasses.dataclass
class Plan:
    engine: str                   # 'local' | 'distributed'
    est_local_s: float
    est_dist_s: float
    reason: str
    variant: Optional[str] = None  # chosen execution variant, if any
    # -- federation axis ----------------------------------------------------
    # pool: the DevicePool the plan places onto (None on the legacy
    # poolset-free path).  est_s: the chosen pool's *total* estimate —
    # compute (scaled by the pool's compute_scale) plus transfer_s, the
    # data-locality term (0 when the snapshot is resident on the pool,
    # else bytes_coo / pool.link_bandwidth).  ``price_incremental`` also
    # writes est_s when it flips the mode, so ``plan_cost`` always
    # reflects the path the plan actually prescribes.
    pool: Optional[str] = None
    est_s: Optional[float] = None
    transfer_s: float = 0.0
    # -- incremental axis ---------------------------------------------------
    # 'full' recomputes from scratch; 'incremental' seeds a localized
    # repair from the parent snapshot's result + the recorded delta;
    # 'warm' restarts a fixpoint from an ancestor's converged vector.
    # Execution treats non-full modes as advisory: an algorithm hook
    # that declines (removals under an add-only repair, exhausted
    # iteration budget) falls back to the cold run, so the mode affects
    # cost estimates and tiering, never correctness.
    mode: str = "full"
    # -- observability ------------------------------------------------------
    # The full candidate table the planner costed (PlanCandidate rows,
    # the winner marked chosen) — what ``service.explain()`` renders.
    # Empty on hand-built plans; never consulted by execution.
    candidates: tuple = ()


def estimate_local_cost(g: GraphStats, q: QuerySpec,
                        profile: Optional[CalibrationProfile] = None) -> float:
    """One device streams the edge set from HBM each superstep, then
    egresses the output to the host once."""
    pr = profile or _ACTIVE_PROFILE
    if g.bytes_coo + q.state_bytes_per_vertex * g.n_vertices \
            > pr.local_mem_budget:
        return float("inf")
    touched = (g.bytes_coo * q.edge_bytes_factor
               + q.state_bytes_per_vertex * g.n_vertices) * q.iterations
    return pr.scale(q.algorithm) * (
        pr.local_dispatch_s
        + touched / pr.hbm_bw
        + q.output_rows * q.row_bytes / pr.host_egress_bw)


def estimate_dist_cost(g: GraphStats, q: QuerySpec, n_chips: int,
                       vertex_replicated: bool = True,
                       profile: Optional[CalibrationProfile] = None) -> float:
    """Each chip streams E/P edges; every superstep pays a launch/sync and
    a ring all-reduce of the vertex aggregate; output egress parallelizes
    over hosts."""
    pr = profile or _ACTIVE_PROFILE
    n_chips = max(n_chips, 1)
    touched = (g.bytes_coo * q.edge_bytes_factor / n_chips
               + q.state_bytes_per_vertex * g.n_vertices) * q.iterations
    coll = 0.0
    if vertex_replicated and n_chips > 1:
        ring = 2.0 * (n_chips - 1) / n_chips
        coll = (q.state_bytes_per_vertex * g.n_vertices * ring / pr.link_bw) \
            * q.iterations
    egress = q.output_rows * q.row_bytes / (
        pr.host_egress_bw * max(n_chips // 4, 1))
    return pr.scale(q.algorithm) * (
        pr.dist_step_s * q.iterations + touched / pr.hbm_bw + coll + egress)


def plan_cost(plan: Plan) -> float:
    """The estimate for the plan's *chosen* engine — what the service's
    admission/tier classification keys on.  Pool-aware plans carry the
    total (compute-scaled + transfer) in ``est_s``; legacy plans fall
    back to the raw per-engine estimate."""
    if plan.est_s is not None:
        return plan.est_s
    return plan.est_local_s if plan.engine == "local" else plan.est_dist_s


# -- incremental-vs-full pricing -------------------------------------------
#
# The repair wavefront from a delta's touched vertices does not stay on
# those vertices: each superstep it can spill one hop outward.  The
# analytic stand-in multiplies the touched fraction by a constant
# expansion factor — crude, but it creates the crossover the catalog
# needs (a 0.1% delta prices far below a full recompute, a 30% delta
# prices above it).  Warm starts run the *full* iteration body, just
# fewer rounds; power iterations on the daily graph typically restart
# within a constant fraction of the cold iteration count.
INCR_WAVEFRONT_EXPANSION = 4.0
WARM_ITER_FRACTION = 0.5


def full_traffic_cost(g: GraphStats, q: QuerySpec,
                      profile: Optional[CalibrationProfile] = None) -> float:
    """The cold run's edge/state traffic seconds — the *variable* term
    of :func:`estimate_local_cost`, without the fixed dispatch and
    output-egress costs a seeded run pays identically."""
    pr = profile or _ACTIVE_PROFILE
    touched = (g.bytes_coo * q.edge_bytes_factor
               + q.state_bytes_per_vertex * g.n_vertices) * q.iterations
    return pr.scale(q.algorithm) * touched / pr.hbm_bw


def estimate_incremental_cost(g: GraphStats, q: QuerySpec, delta,
                              profile: Optional[CalibrationProfile] = None,
                              ) -> float:
    """Traffic seconds of a localized incremental repair: the repair
    wavefront touches ``frac`` of the per-round edge/state traffic and
    converges in proportionally fewer rounds (it must re-cover the
    touched region, not the whole graph's diameter).  At ``frac=1``
    the estimate degenerates to :func:`full_traffic_cost`, so huge
    deltas always price ``'full'``.  The delta bytes themselves are
    NOT charged here — they were ingested once when the snapshot was
    registered (``delta size x touched-frontier estimate`` is the
    comparison, amortized over every query the version serves).
    ``delta`` needs ``n_touched`` — :class:`repro.core.graph.
    GraphDelta` or anything shaped like it."""
    pr = profile or _ACTIVE_PROFILE
    V = max(g.n_vertices, 1)
    frac = min(1.0, INCR_WAVEFRONT_EXPANSION * delta.n_touched / V)
    iters = max(1.0, q.iterations * frac)
    touched = (g.bytes_coo * q.edge_bytes_factor
               + q.state_bytes_per_vertex * g.n_vertices) * frac * iters
    return pr.scale(q.algorithm) * touched / pr.hbm_bw


def price_incremental(plan: Plan, g: GraphStats, q: QuerySpec,
                      delta=None, seed_mode: Optional[str] = None,
                      profile: Optional[CalibrationProfile] = None) -> Plan:
    """Re-price ``plan`` given an available warm-start seed.

    ``seed_mode`` is what the catalog found: ``'incremental'`` (the
    direct parent's converged result plus the recorded delta) or
    ``'warm'`` (an ancestor's converged vector, no usable delta).  The
    comparison is between the two *traffic* terms — fixed dispatch and
    output egress are identical either way and cancel.  When the
    repair's traffic beats the cold traffic the plan's ``mode`` flips
    and ``est_s`` carries the adjusted total; a delta too large to win
    keeps ``mode='full'`` (ties too — the cold path needs no seed
    plumbing).  Applied exactly once per plan, at the end of the
    planning pipeline.  ``None`` seed_mode returns the plan
    untouched."""
    if seed_mode is None:
        return plan

    def with_mode_row(mode: str, est: float, chosen: bool,
                      note: str = "") -> tuple:
        row = PlanCandidate(plan.engine, plan.variant, plan.pool, mode,
                            est_s=est, compute_s=est - plan.transfer_s,
                            transfer_s=plan.transfer_s, note=note)
        table = plan.candidates + (row,)
        if chosen:
            return mark_chosen(table, plan.engine, plan.variant,
                               plan.pool, mode)
        return table

    full = plan_cost(plan)
    if seed_mode == "incremental" and delta is not None:
        cold_traffic = full_traffic_cost(g, q, profile)
        inc_traffic = estimate_incremental_cost(g, q, delta, profile)
        est = max(full - cold_traffic + inc_traffic, 0.0)
        if inc_traffic < cold_traffic:
            return dataclasses.replace(
                plan, mode="incremental", est_s=est,
                candidates=with_mode_row("incremental", est, True),
                reason=f"incremental repair ({delta.n_touched} touched, "
                       f"{est*1e3:.2f} ms vs full {full*1e3:.2f} ms); "
                       f"{plan.reason}")
        return dataclasses.replace(
            plan,
            candidates=with_mode_row(
                "incremental", est, False,
                note="repair traffic loses to full recompute"),
            reason=f"full recompute beats incremental (traffic "
                   f"{cold_traffic*1e3:.3f} ms vs {inc_traffic*1e3:.3f} "
                   f"ms); {plan.reason}")
    if seed_mode == "warm":
        warm = full * WARM_ITER_FRACTION
        return dataclasses.replace(
            plan, mode="warm", est_s=warm,
            candidates=with_mode_row("warm", warm, True),
            reason=f"warm start from ancestor result "
                   f"(~{warm*1e3:.2f} ms vs cold {full*1e3:.2f} ms); "
                   f"{plan.reason}")
    return plan


def _engine_candidates(q: QuerySpec, tl: float, td: float,
                       winner: str) -> tuple:
    """The legacy path's two candidate rows for one spec."""
    return (
        PlanCandidate("local", q.variant, est_s=tl, compute_s=tl,
                      feasible=tl != float("inf"),
                      chosen=winner == "local",
                      note="" if tl != float("inf")
                      else "exceeds local memory budget"),
        PlanCandidate("distributed", q.variant, est_s=td, compute_s=td,
                      chosen=winner == "distributed"),
    )


def choose_engine(g: GraphStats, q: QuerySpec, n_chips: int) -> Plan:
    tl = estimate_local_cost(g, q)
    td = estimate_dist_cost(g, q, n_chips)
    if tl == float("inf"):
        need = g.bytes_coo + q.state_bytes_per_vertex * g.n_vertices
        return Plan("distributed", tl, td,
                    f"graph + vertex state ({need/1e9:.1f} GB) exceeds "
                    f"local budget", variant=q.variant,
                    candidates=_engine_candidates(q, tl, td, "distributed"))
    if tl <= td:
        why = ("small output" if q.output_rows <= 1024 else "medium graph")
        return Plan("local", tl, td, f"local wins ({why}): "
                    f"{tl*1e3:.2f} ms vs {td*1e3:.2f} ms", variant=q.variant,
                    candidates=_engine_candidates(q, tl, td, "local"))
    return Plan("distributed", tl, td,
                f"distributed wins (scale/output): {td*1e3:.2f} ms vs {tl*1e3:.2f} ms",
                variant=q.variant,
                candidates=_engine_candidates(q, tl, td, "distributed"))


def transfer_seconds(g: GraphStats, pool) -> float:
    """Time to materialize a non-resident snapshot onto ``pool`` — the
    data-locality term the federation planner adds for remote pools."""
    bw = float(getattr(pool, "link_bandwidth", 0.0) or 0.0)
    if bw <= 0:
        return float("inf")
    return g.bytes_coo / bw


def choose_plan(g: GraphStats, specs: Sequence[QuerySpec],
                n_chips: int, pools=None, resident=None,
                engines: Sequence[str] = ("local", "distributed")) -> Plan:
    """Pick the cheapest feasible placement.

    Without ``pools`` (the legacy path) this minimizes over
    (engine, variant): with one spec it is exactly :func:`choose_engine`
    (same Plan, same reason strings); with several — one per registered
    execution variant — every (spec, engine) combination is costed and
    the global minimum wins; a variant whose state fits one device can
    keep a query local that another variant's memory footprint would
    force distributed (triangle counting's ELL-intersect vs bitset
    paths).  Ties prefer earlier specs, so the registration order is
    the tie-break for interactive-scale graphs.

    With ``pools`` (a sequence of :class:`~repro.core.pools.DevicePool`
    or anything shaped like one) the minimum runs over
    **(pool, engine, variant)**: each healthy pool's cost is
    ``compute_scale * engine_estimate(pool chips) + transfer``, where
    the transfer term is zero when the pool's name is in ``resident``
    and ``bytes_coo / link_bandwidth`` otherwise — a resident replica
    is the locality discount the paper's snapshot placement buys.
    ``engines`` restricts the engine axis (the ``force_engine`` /
    capability-clamp re-plan path).  Ties prefer earlier pools, then
    earlier specs, then the local engine — so a trivial one-pool set
    reproduces the legacy choice exactly.
    """
    specs = list(specs)
    if pools is None:
        if len(specs) == 1:
            return choose_engine(g, specs[0], n_chips)
        best, best_cost, table = None, float("inf"), []
        for q in specs:
            plan = choose_engine(g, q, n_chips)
            table += [dataclasses.replace(c, chosen=False)
                      for c in plan.candidates]
            # the distributed estimate is always finite, so every spec
            # has a finite comparison cost and the first seeds ``best``
            cost = plan.est_local_s if plan.engine == "local" \
                else plan.est_dist_s
            if best is None or cost < best_cost:
                best, best_cost = plan, cost
        best = dataclasses.replace(
            best, candidates=mark_chosen(table, best.engine, best.variant))
        if best.variant is not None:
            best = dataclasses.replace(
                best, reason=f"variant {best.variant}: {best.reason}")
        return best

    resident = frozenset(resident or ())
    healthy = [p for p in pools if getattr(p, "healthy", True)]
    if not healthy:
        raise ValueError(
            f"no healthy pool to place onto (pools: "
            f"{[getattr(p, 'name', '?') for p in pools]})")
    best = best_pool = None
    best_cost = float("inf")
    table = []
    for pool in pools:
        pool_ok = getattr(pool, "healthy", True)
        pn = getattr(pool, "n_chips", None) or n_chips
        scale = float(getattr(pool, "compute_scale", 1.0))
        transfer = 0.0 if pool.name in resident else transfer_seconds(g, pool)
        for q in specs:
            tl = estimate_local_cost(g, q)
            td = estimate_dist_cost(g, q, pn)
            for engine, base in (("local", tl), ("distributed", td)):
                total = scale * base + transfer
                if not pool_ok:
                    note = "pool unhealthy"
                elif engine not in engines:
                    note = "engine excluded (forced engine or " \
                           "capability clamp)"
                elif total == float("inf"):
                    note = ("exceeds local memory budget"
                            if base == float("inf")
                            else "no link bandwidth to transfer")
                else:
                    note = ""
                table.append(PlanCandidate(
                    engine, q.variant, pool.name, est_s=total,
                    compute_s=scale * base, transfer_s=transfer,
                    feasible=not note, note=note))
                # infinite totals still seed ``best`` (an over-budget
                # plan must surface so admission can reject it with the
                # estimate attached); unhealthy pools and clamped
                # engines never do.
                if not pool_ok or engine not in engines:
                    continue
                if best is None or total < best_cost:
                    best = Plan(engine, tl, td, "", variant=q.variant,
                                pool=pool.name, est_s=total,
                                transfer_s=transfer)
                    best_pool, best_cost = pool, total
    if best is None:
        raise ValueError(f"no engine among {tuple(engines)} to place onto")
    best.candidates = mark_chosen(table, best.engine, best.variant,
                                  best.pool)
    locality = "resident" if best.transfer_s == 0.0 else \
        f"+{best.transfer_s * 1e3:.2f} ms transfer"
    why = (f"{best.engine} on pool {best_pool.name} ({locality}): "
           f"{best_cost * 1e3:.2f} ms est")
    if best.variant is not None:
        why = f"variant {best.variant}: {why}"
    best.reason = why
    return best


def best_spec_for_engine(g: GraphStats, specs: Sequence[QuerySpec],
                         engine: str, n_chips: int = 1) -> QuerySpec:
    """Cheapest feasible variant *given* an engine — how an engine called
    directly (no platform/plan in sight) resolves a variant, and how the
    platform re-picks after ``force_engine`` or a capability clamp."""
    specs = list(specs)

    def cost(q):
        if engine == "local":
            return estimate_local_cost(g, q)
        return estimate_dist_cost(g, q, n_chips)

    return min(specs, key=cost)


# Query specs come from each algorithm's registered cost hook --------------

def specs_for(algorithm: str, g: GraphStats, count_only: bool = False,
              **params) -> tuple[QuerySpec, ...]:
    """All of an algorithm's QuerySpecs — one per execution variant.

    ``params`` are merged over the schema defaults, so user-supplied
    caps (``max_iters``) and planner hints (``expected_pairs``,
    ``n_channels``) flow into the estimate.  Algorithms without a cost
    hook get a conservative per-vertex-output, one-superstep spec.
    Single-variant cost hooks return a bare QuerySpec; multi-variant
    hooks return a sequence with ``variant`` set on every entry.
    """
    defn = registry.get(algorithm)
    merged = defn.validate(params, partial=True)
    if defn.cost is None:
        return (QuerySpec(algorithm, 1 if count_only else g.n_vertices),)
    spec = defn.cost(g, merged, count_only)
    if isinstance(spec, QuerySpec):
        return (spec,)
    return tuple(spec)


def spec_for(algorithm: str, g: GraphStats, count_only: bool = False,
             **params) -> QuerySpec:
    """The algorithm's *primary* spec (first registered variant) — the
    single-spec view most callers and calibration sweeps want; variant
    routing goes through :func:`specs_for` + :func:`choose_plan`."""
    return specs_for(algorithm, g, count_only, **params)[0]
