"""Hypothesis property tests for the Pallas kernels (optional dep)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep: hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.ell_combine.ops import ell_spmv  # noqa: E402
from repro.kernels.ell_combine.ref import ell_combine_ref  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    v=st.integers(1, 80),
    k=st.integers(1, 40),
    density=st.floats(0.0, 1.0),
    op=st.sampled_from(["sum", "min", "max"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_combine_property(v, k, density, op, seed):
    """Kernel == oracle for arbitrary shapes/masks (hypothesis)."""
    rng = np.random.default_rng(seed)
    vx = v + rng.integers(1, 50)
    nbr = jnp.asarray(rng.integers(0, vx, (v, k)), jnp.int32)
    mask = jnp.asarray(rng.random((v, k)) < density)
    w = jnp.asarray(rng.standard_normal((v, k)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(vx), jnp.float32)
    got = np.asarray(ell_spmv(nbr, mask, w, x, op=op))
    want = np.asarray(ell_combine_ref(nbr, mask, w, x, op=op))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
