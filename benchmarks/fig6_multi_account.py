"""Fig. 6 reproduction: multi-account detection running time —
GraphFrames-equivalent motif finding (ours) vs the legacy 3-step
Scalding join pipeline.  The paper reports ~17x at production scale.

Methodology notes (single CPU host; the paper compares cluster runs):
* graph construction (ETL) is timed separately for both systems — the
  paper's "2-3 h graph generation" vs "motif finding" split;
* the engine phase is the jit-compiled motif expansion (ours) vs the
  materialized sort-merge join cascade (legacy);
* we report the full-pair query and the count-only query (the class the
  local engine serves without materializing results at all);
* the measured ratio GROWS with scale — consistent with the paper's 17x
  at 30.86B edges (our largest local scale is ~6 orders smaller).
"""
from __future__ import annotations

import jax

from benchmarks.common import time_fn, time_host, csv_row
from repro.core import graph as G
from repro.core.algorithms.two_hop import (two_hop_pairs,
                                           two_hop_count_upper_bound)
from repro.core.algorithms.legacy import legacy_multi_account
from repro.data import synthetic as S


def run(out=print):
    rows = []
    cap = 48
    for n_users, n_ids in [(5_000, 2_000), (20_000, 8_000),
                           (50_000, 20_000)]:
        u, i = S.safety_bipartite_graph(n_users, n_ids, seed=2,
                                        hub_degree=cap)
        # --- ETL phase (shared input, both engines build from it) ------
        ell = G.build_ell(u, i, n_ids, cap, direction="in")
        import jax.numpy as jnp
        nbr = jnp.where(ell.mask, ell.nbr, n_users)
        ell = G.GraphELL(nbr, ell.mask, ell.w, ell.n_vertices,
                         ell.n_edges, ell.n_edges_total)

        import functools
        pairs_fn = jax.jit(functools.partial(two_hop_pairs,
                                             n_users=n_users, dedup=True))
        t_ours, (_, _, count) = time_fn(pairs_fn, ell)
        expand_fn = jax.jit(functools.partial(two_hop_pairs,
                                              n_users=n_users, dedup=False))
        t_expand, _ = time_fn(expand_fn, ell)     # no global dedup sort
        count_fn = jax.jit(
            lambda m: two_hop_count_upper_bound(m.sum(axis=1)))
        t_count, _ = time_fn(count_fn, ell.mask)
        t_legacy, legacy_pairs = time_host(
            legacy_multi_account, u, i, max_adjacent_nodes=cap, iters=1)

        ratio = t_legacy / t_ours
        rows.append((n_users, t_ours, t_legacy, ratio))
        out(csv_row(f"fig6/motif_ours_u{n_users}", t_ours,
                    f"pairs={int(count)}"))
        out(csv_row(f"fig6/motif_nodedup_u{n_users}", t_expand,
                    f"ratio={t_legacy/max(t_expand,1e-9):.1f}x"))
        out(csv_row(f"fig6/motif_count_u{n_users}", t_count,
                    f"count_fast_path={t_legacy/max(t_count,1e-9):.0f}x"))
        out(csv_row(f"fig6/legacy_3step_u{n_users}", t_legacy,
                    f"speedup={ratio:.1f}x(paper:17x)"))
    return rows


if __name__ == "__main__":
    run()
