"""PageRank on the BSP engine.

The paper's recommendation teams run PageRank on the user-follow graph;
the legacy Scalding implementation takes >11 hours per iteration.  Here a
whole run (power iterations + dangling-mass redistribution + convergence
check) is one XLA program.

Formulation (matches ``networkx.pagerank`` so tests can cross-check):

    x' = (1-a)/V + a * (A_norm^T x + dangling_mass / V)

with ``A_norm[u, v] = w(u, v) / outdeg(u)`` and
``dangling_mass = sum_{outdeg(u)=0} x[u]``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core import registry as R
from repro.core.partition import ShardedCOO, partition
from repro.core.pregel import PregelSpec, run_pregel


def _normalize_and_partition(
    g: G.GraphCOO, n_data: int, n_model: int
) -> tuple[ShardedCOO, jax.Array]:
    """Fold 1/outdeg into edge weights; return sharded edges + dangling mask."""
    outdeg = G.out_degrees(g)
    dangling = (outdeg == 0).astype(jnp.float32)
    inv = jnp.where(outdeg > 0, 1.0 / jnp.maximum(outdeg, 1.0), 0.0)
    src_c = jnp.clip(g.src, 0, g.n_vertices - 1)
    w_norm = g.w * inv[src_c]
    g_norm = G.GraphCOO(g.src, g.dst, w_norm, g.n_vertices, g.n_edges)
    return partition(g_norm, n_data, n_model), dangling


def pagerank(
    g: G.GraphCOO,
    alpha: float = 0.85,
    tol: float = 1e-8,
    max_iters: int = 100,
    mesh=None,
    n_data: int = 1,
    n_model: int = 1,
    sharded: Optional[ShardedCOO] = None,
    dangling: Optional[jax.Array] = None,
    init: Optional[jax.Array] = None,
):
    """Returns (ranks [V] summing to 1, iterations_run).

    ``init`` optionally replaces the uniform starting vector (same
    padded layout as the state) — the warm-start seam.  Power iteration
    contracts to the same fixpoint from any probability vector, so a
    warm start changes iterations, never the converged ranks beyond
    ``tol``."""
    if sharded is None:
        sharded, dangling = _normalize_and_partition(g, n_data, n_model)
    V = g.n_vertices
    v_local = sharded.v_local
    n_model_eff = sharded.n_model

    # Vertex state layout: dangling flag rides along per owned vertex.
    if n_model_eff > 1:
        d_pad = jnp.zeros(n_model_eff * v_local, jnp.float32).at[:V].set(dangling)
    else:
        d_pad = dangling

    def message(x_src, w):
        return x_src * w

    def global_value(x, ids, valid):
        # dangling mass owned by this vertex shard
        d = d_pad[ids] if n_model_eff > 1 else d_pad
        return jnp.sum(jnp.where(valid, x * d, 0.0))

    def apply(x, agg, ids, dangling_mass):
        return (1.0 - alpha) / V + alpha * (agg + dangling_mass / V)

    def halt(old, new, valid):
        # per-shard L1 budget; exact when vertices are replicated
        budget = tol * V / n_model_eff
        return jnp.sum(jnp.where(valid, jnp.abs(new - old), 0.0)) < budget

    spec = PregelSpec(
        message=message, combine="sum", apply=apply, identity=0.0,
        halt=halt, global_value=global_value,
    )
    if init is None:
        init = jnp.full((n_model_eff * v_local,) if n_model_eff > 1
                        else (V,), 1.0 / V, jnp.float32)
    state, iters = run_pregel(spec, sharded, init, max_iters, mesh=mesh)
    return state[:V], iters


# ------------------------------------------------------------ registration

def _engine_run(eng, alpha, tol, max_iters):
    """Registry runner: the 1/outdeg-normalized partition is derived
    state both engines cache across queries."""
    key = "pagerank/normalized"
    if key not in eng.cache:
        eng.cache[key] = _normalize_and_partition(
            eng.coo, eng.n_data, eng.n_model)
    sharded, dangling = eng.cache[key]
    return pagerank(eng.coo, alpha=alpha, tol=tol, max_iters=max_iters,
                    mesh=eng.mesh, sharded=sharded, dangling=dangling)


def _warm_start(eng, params, seed):
    """Restart the power iteration from an ancestor snapshot's converged
    ranks: resize to this graph's V (new vertices get the uniform
    prior), renormalize to a probability vector, and run the standard
    iteration.  The contraction mapping lands on the same ranks within
    ``tol`` — only the iteration count shrinks.  Declines (``None``) on
    a malformed seed, falling back to the cold run."""
    prev = np.asarray(getattr(seed, "value", seed))
    V = eng.coo.n_vertices
    if prev.ndim != 1 or prev.size == 0 or V == 0 \
            or prev.dtype.kind != "f":
        return None
    x = np.full(V, 1.0 / V, dtype=np.float64)
    n = min(prev.shape[0], V)
    x[:n] = prev[:n]
    total = float(x.sum())
    if not np.isfinite(total) or total <= 0.0:
        return None
    x = (x / total).astype(np.float32)
    key = "pagerank/normalized"
    if key not in eng.cache:
        eng.cache[key] = _normalize_and_partition(
            eng.coo, eng.n_data, eng.n_model)
    sharded, dangling = eng.cache[key]
    if sharded.n_model > 1:
        init = jnp.zeros(sharded.n_model * sharded.v_local,
                         jnp.float32).at[:V].set(x)
    else:
        init = jnp.asarray(x)
    ranks, iters = pagerank(
        eng.coo, alpha=params["alpha"], tol=params["tol"],
        max_iters=params["max_iters"], mesh=eng.mesh,
        sharded=sharded, dangling=dangling, init=init)
    return ranks, int(iters)


def _cost(g: P.GraphStats, params: dict, count_only: bool) -> P.QuerySpec:
    # power iteration typically converges well before the cap
    iters = min(40, params.get("max_iters") or 40)
    return P.QuerySpec("pagerank", 1 if count_only else g.n_vertices,
                       iterations=iters)


R.register(R.AlgorithmDef(
    name="pagerank",
    run=_engine_run,
    params=(
        R.Param("alpha", 0.85, check=lambda a: 0.0 < a < 1.0),
        R.Param("tol", 1e-8, check=lambda t: t > 0.0),
        R.Param("max_iters", 100, check=lambda n: n >= 1, normalize=int),
    ),
    cost=_cost,
    example_params={"max_iters": 20},
    warm_start=_warm_start,
    doc="Power-iteration PageRank with dangling-mass redistribution.",
))


def pagerank_reference(src, dst, n_vertices, alpha=0.85, tol=1e-8, max_iters=100):
    """Pure-numpy oracle (same formulation) for tests."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    V = n_vertices
    outdeg = np.bincount(src, minlength=V).astype(np.float64)
    x = np.full(V, 1.0 / V)
    for it in range(max_iters):
        contrib = np.where(outdeg[src] > 0, x[src] / np.maximum(outdeg[src], 1), 0.0)
        agg = np.bincount(dst, weights=contrib, minlength=V)
        dm = x[outdeg == 0].sum()
        new = (1 - alpha) / V + alpha * (agg + dm / V)
        if np.abs(new - x).sum() < tol * V:
            return new, it + 1
        x = new
    return x, max_iters
