"""End-to-end observability: span traces, superstep profiles, and the
planner's estimate-vs-actual feedback loop (ISSUE 10).

The acceptance bar: every ticket of a drained mixed-tier workload has a
complete span tree (admission, full plan-candidate table, queue wait,
attempts, superstep counters, resolution); the hard lifecycles —
retry→success, dead-letter with the exception chain, fused groups
sharing one execute span, spill recording both placements — all
materialize in the tree; the Chrome trace export validates against the
trace-event schema; ``metrics_text()`` round-trips ``metrics()``; and
tracing never changes a single result byte.
"""
import math

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import obs
from repro.core import planner as P
from repro.core import pools as PL
from repro.core import registry as R
from repro.core.engines import LocalEngine
from repro.core.query import GraphQuery
from repro.core.runtime import LatencyHistogram, RetryPolicy
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

N = 200


@pytest.fixture(scope="module")
def graph():
    src, dst = S.user_follow_graph(N, 4.0, seed=7)
    return G.build_coo(src, dst, N)


FLAKY = "_obs_flaky"


@pytest.fixture()
def flaky_algorithm():
    R.register(R.AlgorithmDef(
        name=FLAKY,
        run=lambda eng, tag=0: (np.arange(8, dtype=np.float64) + tag, None),
        params=(R.Param("tag", default=0),),
        engines=("local",),
        doc="observability-harness flaky algorithm",
    ), replace=True)
    yield FLAKY
    R.uninstall_fault(None)
    R.unregister(FLAKY)


def _traced_service(graph, **kw):
    kw.setdefault("trace_depth", 32)
    svc = GraphAnalyticsService(**kw)
    svc.add_graph("g", graph)
    return svc


def _bits(v):
    if isinstance(v, dict):
        return b"{" + b";".join(
            str(k).encode() + b"=" + _bits(v[k]) for k in sorted(v)) + b"}"
    if isinstance(v, (tuple, list)):
        return b"(" + b";".join(_bits(x) for x in v) + b")"
    return np.asarray(v).tobytes()


# ---------------------------------------------------------------------------
# The span tree
# ---------------------------------------------------------------------------

def test_span_tree_full_lifecycle(graph):
    """submit → admission → plan → queue-wait → attempt/execute →
    resolve, every span present and closed, wait measured."""
    svc = _traced_service(graph)
    t = svc.submit("g", GraphQuery.bfs([0]))
    svc.result(t)
    tr = svc.tracer.trace(t.ticket_id)
    for name in ("ticket", "submit", "admission", "plan", "queue-wait",
                 "attempt", "execute", "resolve"):
        span = tr.find(name)
        assert span is not None, name
        assert span.t1 is not None, name
    assert tr.root.attrs["status"] == "done"
    qw = tr.find("queue-wait")
    assert qw.attrs["wait_s"] == pytest.approx(qw.duration_s)
    adm = tr.find("admission")
    assert adm.attrs["tier"] == t.tier
    assert adm.attrs["est_s"] == pytest.approx(t.est_s)
    text = svc.explain(t)
    for needle in ("ticket #", "admission", "queue-wait", "attempt",
                   "resolve", "status=done"):
        assert needle in text


def test_plan_span_records_all_candidates(graph):
    """The plan span carries the planner's *full* table — every
    (engine, variant) the legacy chooser costed, exactly one chosen,
    and the chosen row is the plan that actually ran."""
    svc = _traced_service(graph)
    t = svc.submit("g", GraphQuery.bfs([0]))
    plan_span = svc.tracer.trace(t.ticket_id).find("plan")
    cands = plan_span.attrs["candidates"]
    # bfs registers 3 variants x 2 engines
    assert len(cands) == 6
    assert sum(c["chosen"] for c in cands) == 1
    chosen = next(c for c in cands if c["chosen"])
    assert chosen["engine"] == t.plan.engine
    assert chosen["variant"] == t.plan.variant
    assert chosen["est_s"] == min(c["est_s"] for c in cands
                                  if c["feasible"])
    losers = [c for c in cands if not c["chosen"]]
    assert losers and all(c["est_s"] >= chosen["est_s"] for c in losers
                          if c["feasible"])
    text = svc.explain(t)
    assert "<- chosen" in text
    assert "vs chosen" in text          # losers annotated with the gap


def test_plan_candidates_span_pools(graph):
    """On a poolset the table enumerates (pool, engine) pairs with the
    transfer term split out, and infeasible rows say why."""
    pools = PL.PoolSet([
        PL.DevicePool("onprem"),
        PL.DevicePool("cloud", compute_scale=0.5),
    ])
    svc = GraphAnalyticsService(pools=pools, trace_depth=8)
    svc.add_graph("g", graph, pools=["onprem"])   # resident on one pool
    t = svc.submit("g", GraphQuery.pagerank())
    cands = svc.tracer.trace(t.ticket_id).find("plan").attrs["candidates"]
    assert {c["pool"] for c in cands} == {"onprem", "cloud"}
    chosen = next(c for c in cands if c["chosen"])
    assert chosen["pool"] == t.plan.pool
    nonresident = [c for c in cands if c["pool"] == "cloud"]
    assert any(c["transfer_s"] > 0 for c in nonresident)
    for c in cands:
        assert c["est_s"] == pytest.approx(c["compute_s"]
                                           + c["transfer_s"])


def test_incremental_mode_candidates_and_explain(graph):
    """A lineage-seeded ticket's table includes the mode rows the
    pricer weighed (incremental chosen vs the full recompute), and
    explain() shows the incremental routing."""
    sym = G.build_coo(np.asarray(graph.src)[: graph.n_edges],
                      np.asarray(graph.dst)[: graph.n_edges],
                      N, symmetrize=True)
    svc = GraphAnalyticsService(trace_depth=8)
    svc.add_snapshot("g", sym, as_of=0)
    q = GraphQuery.of("connected_components")
    svc.call("g", q, as_of=0)                  # the parent seed
    svc.add_snapshot("g", as_of=1, added=[[0, 7], [7, 0]])
    t = svc.submit("g", q)
    assert t.plan.mode == "incremental"
    cands = svc.tracer.trace(t.ticket_id).find("plan").attrs["candidates"]
    modes = {c["mode"] for c in cands}
    assert "incremental" in modes
    chosen = next(c for c in cands if c["chosen"])
    assert chosen["mode"] == "incremental"
    svc.drain()
    text = svc.explain(t)
    assert "mode=incremental" in text
    assert "incremental" in text and "<- chosen" in text


# ---------------------------------------------------------------------------
# Hard lifecycles
# ---------------------------------------------------------------------------

def test_retry_then_success_attempt_spans(graph, flaky_algorithm):
    """2 injected failures then success: three attempt spans, the
    failed ones carrying the error, plus a retry event per backoff."""
    svc = _traced_service(
        graph, interactive_threshold_s=0.0,
        retry=RetryPolicy(max_attempts=3, base_s=1e-4, cap_s=1e-3))
    R.install_fault(FLAKY, R.FailNTimes(2))
    t = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t.status == "done"
    tr = svc.tracer.trace(t.ticket_id)
    attempts = tr.find_all("attempt")
    assert [a.attrs["attempt"] for a in attempts] == [1, 2, 3]
    assert "error" in attempts[0].attrs and "error" in attempts[1].attrs
    assert "error" not in attempts[2].attrs
    retries = [(name, attrs) for (_, name, attrs) in tr.root.events
               if name == "retry"]
    assert [a["after_attempt"] for _, a in retries] == [1, 2]
    assert all(a["sleep_s"] >= 1e-4 for _, a in retries)
    assert tr.root.attrs["status"] == "done"


def test_dead_letter_exception_chain_on_final_attempt(graph,
                                                      flaky_algorithm):
    """Dead-letter: the final attempt span carries the full __cause__
    chain (one entry per attempt), and the resolve span says so."""
    svc = _traced_service(
        graph, interactive_threshold_s=0.0,
        retry=RetryPolicy(max_attempts=3, base_s=1e-4, cap_s=1e-3))
    R.install_fault(FLAKY, R.FailAlways())
    t = svc.submit("g", GraphQuery.of(FLAKY))
    svc.drain()
    assert t.status == "dead-letter"
    tr = svc.tracer.trace(t.ticket_id)
    last = tr.find_all("attempt")[-1]
    assert len(last.attrs["error_chain"]) == 3
    assert all("FaultInjected" in entry
               for entry in last.attrs["error_chain"])
    resolve = tr.find("resolve")
    assert resolve.attrs["status"] == "dead-letter"
    assert "error" in resolve.attrs
    assert tr.root.attrs["status"] == "dead-letter"
    text = svc.explain(t)
    assert "cause[0]" in text and "cause[2]" in text


def test_fused_group_shares_one_execute_span(graph):
    """K fused tickets point at the SAME execute span (one execution,
    K tickets), which carries one per-ticket child each."""
    svc = _traced_service(graph, interactive_threshold_s=0.0)
    ts = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 5, 9)]
    svc.drain()
    execs = [svc.tracer.trace(t.ticket_id).find("execute") for t in ts]
    assert len({id(e) for e in execs}) == 1       # the same Span object
    assert len({e.span_id for e in execs}) == 1
    ex = execs[0]
    assert ex.attrs["fused"] is True
    assert ex.attrs["batch_size"] == len(ts)
    assert ex.attrs["group"] == [t.ticket_id for t in ts]
    members = [c for c in ex.children if c.name == "ticket"]
    assert [c.attrs["ticket_id"] for c in members] \
        == [t.ticket_id for t in ts]
    assert [c.attrs["index"] for c in members] == [0, 1, 2]
    assert "superstep" in ex.attrs                # profiled once, shared


def test_spill_records_both_placements(graph):
    """A spilled ticket's plan span keeps the original placement next
    to the spill target — where the planner wanted it AND where it
    actually went."""
    svc = GraphAnalyticsService(
        pools=PL.PoolSet([PL.DevicePool("onprem", capacity=1),
                          PL.DevicePool("cloud", capacity=16)]),
        interactive_threshold_s=0.0, trace_depth=16)
    svc.add_graph("g", graph)
    ts = [svc.submit("g", GraphQuery("bfs", params={"sources": (i,)}))
          for i in range(3)]
    assert [t.pool for t in ts] == ["onprem", "cloud", "cloud"]
    kept = svc.tracer.trace(ts[0].ticket_id).find("plan")
    assert "spilled" not in kept.attrs
    spilt = svc.tracer.trace(ts[1].ticket_id).find("plan")
    assert spilt.attrs["spilled"] is True
    assert spilt.attrs["original_placement"]["pool"] == "onprem"
    assert spilt.attrs["pool"] == "cloud"
    chosen = next(c for c in spilt.attrs["candidates"] if c["chosen"])
    assert chosen["pool"] == "cloud"
    svc.drain()
    text = svc.explain(ts[1])
    assert "spilled=True" in text and "original_placement" in text


def test_cache_hit_skips_execution_spans(graph):
    """A cache-served ticket resolves with a cache-hit event and no
    attempt span — and the cached result never claims the superstep
    counters of the run that populated it."""
    svc = _traced_service(graph, interactive_threshold_s=0.0)
    a = svc.submit("g", GraphQuery.bfs([3]))
    svc.drain()
    b = svc.submit("g", GraphQuery.bfs([3]))
    svc.drain()
    assert "superstep" in svc.result(a).meta
    rb = svc.result(b)
    assert rb.meta.get("cache") == "hit"
    assert "superstep" not in rb.meta
    tr = svc.tracer.trace(b.ticket_id)
    assert tr.find("attempt") is None
    assert any(name == "cache-hit" for (_, name, _) in tr.root.events)
    assert tr.root.attrs["status"] == "done"


# ---------------------------------------------------------------------------
# Superstep profiling
# ---------------------------------------------------------------------------

def test_superstep_counters_per_variant(graph):
    """Profiled runs report iterations / halt / message volume for
    every superstep strategy; the frontier adds per-round occupancy.
    Profiling never changes the answer."""
    eng = LocalEngine(graph)
    defn = R.get("bfs")
    ref = np.asarray(eng.run(defn, {"sources": (0,)},
                             variant="dense").value)
    for variant in ("dense", "fused", "frontier"):
        r = eng.run(defn, {"sources": (0,)}, variant=variant,
                    profile=True)
        ss = r.meta["superstep"]
        assert ss["variant"] == variant
        assert ss["iterations"] >= 1
        assert ss["halt_step"] == ss["iterations"]
        assert ss["halted"] == (ss["iterations"] < ss["max_iters"])
        assert ss["message_bytes"] > 0
        assert np.asarray(r.value).tobytes() == ref.tobytes()
        if variant == "frontier":
            occ = ss["frontier_occupancy"]
            assert len(occ) == ss["iterations"]
            assert all(c >= 0 for c in occ)
        # profiling is opt-in: the unprofiled run carries no counters
        bare = eng.run(defn, {"sources": (0,)}, variant=variant)
        assert "superstep" not in bare.meta


def test_mixed_tier_drain_every_ticket_explained(graph):
    """The acceptance workload: a drained mixed-tier mix where every
    ticket's explain() shows candidates, queue wait, and (for executed
    tickets) the superstep counters."""
    qs = [GraphQuery.bfs([0], count_only=True),     # interactive
          GraphQuery.bfs([1]), GraphQuery.bfs([2]),  # fused batch
          GraphQuery.pagerank(max_iters=5)]          # fixpoint batch
    probe = _traced_service(graph)
    ests = sorted(P.plan_cost(probe.context("g").plan(q)) for q in qs)
    # split the tiers between the cheapest and the rest
    svc = _traced_service(
        graph, interactive_threshold_s=(ests[0] + ests[1]) / 2)
    ts = [svc.submit("g", q) for q in qs]
    assert {t.tier for t in ts} == {"interactive", "batch"}
    svc.drain()
    for t in ts:
        tr = svc.tracer.trace(t.ticket_id)
        assert tr.root.attrs["status"] == "done"
        assert tr.find("plan").attrs["candidates"]
        assert tr.find("queue-wait").attrs["wait_s"] >= 0
        text = svc.explain(t)
        assert "candidates (pool/engine/variant/mode):" in text
        assert "wait_s=" in text
    # pregel-backed tickets carry superstep counters on their execute
    for t in ts[1:3]:
        ex = svc.tracer.trace(t.ticket_id).find("execute")
        assert ex.attrs["superstep"]["iterations"] >= 1


# ---------------------------------------------------------------------------
# Tracing must not perturb anything
# ---------------------------------------------------------------------------

def test_tracing_is_invisible_in_results(graph):
    """Byte-identical values, identical iteration counts, identical
    scheduling counters — traced vs untraced."""
    def run(trace_depth):
        svc = GraphAnalyticsService(interactive_threshold_s=0.0,
                                    trace_depth=trace_depth)
        svc.add_graph("g", graph)
        qs = [GraphQuery.bfs([s]) for s in (0, 5, 9)] \
            + [GraphQuery.pagerank(max_iters=4),
               GraphQuery.degree_stats()]
        ts = [svc.submit("g", q) for q in qs]
        svc.drain(workers=2)
        rs = [svc.result(t) for t in ts]
        counters = svc.metrics()["counters"]
        return ([_bits(r.value) for r in rs],
                [r.iterations for r in rs], counters)
    off_bits, off_iters, off_counters = run(0)
    on_bits, on_iters, on_counters = run(64)
    assert on_bits == off_bits
    assert on_iters == off_iters
    assert on_counters == off_counters


def test_trace_ring_is_bounded(graph):
    svc = _traced_service(graph, trace_depth=2,
                          interactive_threshold_s=0.0, cache_size=0)
    ts = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 1, 2, 3)]
    svc.drain()
    counters = svc.tracer.counters_snapshot()
    assert counters["retained"] == 2
    assert counters["evicted"] == 2
    assert counters["tickets"] == 4
    assert svc.tracer.trace(ts[0].ticket_id) is None
    with pytest.raises(KeyError, match="aged out"):
        svc.explain(ts[0])
    svc.explain(ts[-1])                      # newest still retained
    with pytest.raises(ValueError, match="trace_depth"):
        obs.Tracer(trace_depth=0)


def test_explain_requires_tracing(graph):
    svc = GraphAnalyticsService()
    svc.add_graph("g", graph)
    t = svc.submit("g", GraphQuery.bfs([0]))
    svc.drain()
    assert svc.metrics()["trace"]["enabled"] == 0
    with pytest.raises(RuntimeError, match="tracing is off"):
        svc.explain(t)


def test_observer_seam_records_fault_and_transfer_events(graph,
                                                         flaky_algorithm):
    """Registry fault injections and ledger transfers reach the tracer
    through the observer seam; with no observers, emit() is a no-op."""
    obs.emit("fault", algorithm="nobody-listens")   # must not blow up
    pools = PL.PoolSet([PL.DevicePool("onprem"),
                        PL.DevicePool("cloud", compute_scale=1e-9)])
    svc = GraphAnalyticsService(
        pools=pools, interactive_threshold_s=0.0, trace_depth=8,
        retry=RetryPolicy(max_attempts=2, base_s=1e-4, cap_s=1e-3))
    # resident only on onprem: the compute-favoured cloud pool must
    # pull the snapshot across the link, charging a transfer
    svc.add_graph("g", graph, pools=["onprem"])
    R.install_fault(FLAKY, R.FailNTimes(1))
    t = svc.submit("g", GraphQuery.of(FLAKY))
    assert t.pool == "cloud"
    svc.drain()
    assert t.status == "done"
    faults = [(kind, attrs) for (_, kind, attrs) in svc.tracer.events
              if kind == "fault"]
    assert any(a["error"] is not None for _, a in faults)   # the injection
    assert any(a["error"] is None for _, a in faults)       # the success
    assert all(a["algorithm"] == FLAKY for _, a in faults)
    transfers = [attrs for (_, kind, attrs) in svc.tracer.events
                 if kind == "transfer"]
    assert transfers and all(a["bytes"] > 0 for a in transfers)
    # the executed ticket also carries the transfer as a span event
    tr = svc.tracer.trace(t.ticket_id)
    assert any(name == "transfer" for (_, name, _) in tr.root.events)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def test_chrome_trace_export_and_schema(graph, tmp_path):
    svc = _traced_service(graph, interactive_threshold_s=0.0)
    ts = [svc.submit("g", GraphQuery.bfs([s])) for s in (0, 5)]
    svc.drain()
    path = tmp_path / "trace.json"
    doc = svc.tracer.export_chrome_trace(str(path))
    n = obs.validate_chrome_trace(str(path))       # re-parse from disk
    assert n == len(doc["traceEvents"]) > 0
    by_tid = {}
    for ev in doc["traceEvents"]:
        by_tid.setdefault(ev["tid"], []).append(ev)
    assert set(by_tid) == {t.ticket_id for t in ts}
    # the fused execute span appears once per member row, same span_id
    exec_ids = {tid: [e["args"]["span_id"] for e in evs
                      if e["name"] == "execute"]
                for tid, evs in by_tid.items()}
    assert all(len(ids) == 1 for ids in exec_ids.values())
    assert len({ids[0] for ids in exec_ids.values()}) == 1


@pytest.mark.parametrize("bad,match", [
    ('{"no": []}', "traceEvents"),
    ('{"traceEvents": [{"ph": "X"}]}', "missing"),
    ('{"traceEvents": [{"name": "x", "ph": "Q", "ts": 0, '
     '"pid": 1, "tid": 1}]}', "phase"),
    ('{"traceEvents": [{"name": "x", "ph": "X", "ts": 0, '
     '"pid": 1, "tid": 1}]}', "dur"),
], ids=["top-level", "fields", "phase", "dur"])
def test_chrome_trace_validator_rejects(bad, match):
    with pytest.raises(ValueError, match=match):
        obs.validate_chrome_trace(bad)


# ---------------------------------------------------------------------------
# Metrics exposition
# ---------------------------------------------------------------------------

def test_metrics_text_roundtrips_metrics(graph):
    """Every numeric leaf of metrics() appears in the exposition and
    parses back to the same value (None <-> NaN)."""
    svc = _traced_service(graph, interactive_threshold_s=0.0)
    for s in (0, 5):
        svc.submit("g", GraphQuery.bfs([s]))
    svc.drain()
    parsed = obs.parse_prometheus(svc.metrics_text())
    leaves: list = []
    obs._flatten(svc.metrics(), (), leaves)
    checked = 0
    for path, value in leaves:
        name = obs._metric_name("gas", path)
        if value is None:
            assert math.isnan(parsed[name]), name
        elif isinstance(value, (bool, int, float)):
            assert parsed[name] == pytest.approx(float(value)), name
        else:
            continue                          # strings ride as comments
        checked += 1
    assert checked >= 50                      # the surface is wide
    assert parsed["gas_trace_enabled"] == 1
    assert parsed["gas_accuracy_samples"] >= 1
    assert parsed["gas_counters_executed"] >= 1


def test_latency_window_exact_flag():
    h = LatencyHistogram(max_samples=4)
    for x in (0.1, 0.2, 0.3):
        h.observe(x)
    snap = h.snapshot()
    assert snap["window_exact"] is True       # whole history retained
    assert snap["window_size"] == 3
    for x in (0.4, 0.5):
        h.observe(x)
    snap = h.snapshot()
    assert snap["window_exact"] is False      # oldest samples aged out
    assert snap["window_size"] == 4
    assert snap["count"] == 5                 # buckets keep everything
    assert snap["buckets"]["le_inf"] == 5
    assert snap["p50_s"] in (0.3, 0.4)        # window-local quantile


# ---------------------------------------------------------------------------
# Plan accuracy -> calibration feedback
# ---------------------------------------------------------------------------

def test_accuracy_meter_records_per_key(graph):
    svc = _traced_service(graph, interactive_threshold_s=0.0,
                          cache_size=0)
    for s in (0, 1):
        svc.submit("g", GraphQuery.bfs([s]))
    svc.drain()
    svc.call("g", GraphQuery.pagerank(max_iters=4))
    acc = svc.metrics()["accuracy"]
    assert acc["samples"] >= 2
    assert acc["mean_abs_rel_err"] is not None
    assert any(k.startswith("bfs|") for k in acc["by_key"])
    assert any(k.startswith("pagerank|") for k in acc["by_key"])
    for row in acc["by_key"].values():
        assert row["n"] >= 1
        assert row["est_s_mean"] > 0 and row["wall_s_mean"] > 0
        assert row["wall_over_est"] > 0


def test_fused_group_records_one_accuracy_sample(graph):
    svc = _traced_service(graph, interactive_threshold_s=0.0)
    for s in (0, 5, 9):
        svc.submit("g", GraphQuery.bfs([s]))
    svc.drain()
    acc = svc._accuracy
    samples = [s for key, dq in acc._samples.items()
               if key[0] == "bfs" for s in dq]
    assert len(samples) == 1                  # one fused run, one sample
    (est, wall, mode, width) = samples[0]
    assert width == 3 and est > 0 and wall > 0


def test_calibration_refit_from_production_traces(graph, tmp_path):
    """The loop closes: PlanAccuracyMeter samples feed
    emit_calibration directly, yielding a profile whose per-algorithm
    scale is the measured/modeled ratio from live traffic."""
    from benchmarks.algo_suite import emit_calibration
    svc = _traced_service(graph, interactive_threshold_s=0.0,
                          cache_size=0)
    for s in range(4):
        svc.submit("g", GraphQuery.bfs([s]))
    svc.drain()
    samples = svc._accuracy.calibration_samples()
    assert "bfs" in samples and samples["bfs"]
    for wall, est in samples["bfs"]:
        assert wall > 0 and est > 0
    profile = emit_calibration(str(tmp_path / "calib.json"), samples,
                               out=lambda *a, **k: None)
    ratios = sorted(w / e for w, e in samples["bfs"])
    assert profile.algo_time_scale["bfs"] == pytest.approx(
        float(np.median(ratios)))


def test_accuracy_meter_bounds_and_shape():
    m = obs.PlanAccuracyMeter(max_samples=3)
    for i in range(5):
        m.record("bfs", "local", "dense", None,
                 est_s=1.0, wall_s=2.0 + i)
    snap = m.snapshot()
    assert snap["samples"] == 3               # rolling window
    row = snap["by_key"]["bfs|local|dense|-"]
    assert row["n"] == 3
    assert row["wall_over_est"] == pytest.approx(5.0)  # mean of 4,5,6
    assert snap["mean_abs_rel_err"] == pytest.approx(4.0)
    assert m.calibration_samples() == {"bfs": [(4.0, 1.0), (5.0, 1.0),
                                               (6.0, 1.0)]}


def test_infeasible_candidates_carry_the_reason():
    """At paper scale the local engine exceeds its memory budget: its
    candidate row survives in the table, marked infeasible with the
    reason, while distributed is chosen."""
    g = P.GraphStats(n_vertices=2_410_000_000, n_edges=1_500_000_000,
                     bytes_coo=1_500_000_000 * 12)
    q = P.spec_for("connected_components", g)
    plan = P.choose_engine(g, q, 256)
    assert plan.engine == "distributed"
    assert plan.candidates
    assert sum(c.chosen for c in plan.candidates) == 1
    local = next(c for c in plan.candidates if c.engine == "local")
    assert not local.feasible
    assert not math.isfinite(local.est_s)
    assert local.note == "exceeds local memory budget"
