"""Pool-crossover sweep: where does hybrid-cloud placement flip?

The federation planner prices every (pool, engine, variant) placement
as ``compute_scale * engine_estimate + transfer``, with the transfer
term zero on pools where the snapshot is *resident* and
``bytes_coo / link_bandwidth`` elsewhere.  This sweep reproduces the
paper's core hybrid-cloud trade-off as a measurable crossover:

  * **residency axis** — a snapshot resident on-prem only, cloud only,
    or both; with a compute-advantaged cloud pool
    (``compute_scale < 1``) the interesting case is "resident on-prem,
    faster cloud": cheap links ship the snapshot to the faster pool,
    expensive links pin the work to the data.
  * **link-bandwidth axis** — sweeping the cross-pool byte rate finds
    the crossover bandwidth at which the planner flips from the
    resident pool to the remote compute-advantaged pool, per graph
    scale (bigger snapshots need fatter links to justify moving).
  * **measured walls** — for the smallest scale the sweep actually
    executes on a two-pool service both ways and asserts the results
    are byte-identical (the federation contract), recording the
    transfer ledger the first remote execution charges.

Results land in ``BENCH_pool_crossover.json`` (``--out`` overrides),
starting the federation perf series.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.core import graph as G
from repro.core import planner as P
from repro.core import pools as PL
from repro.core.query import GraphQuery
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

SIZES = (2_000, 20_000, 100_000)
#: cross-pool byte rates swept: 1 MB/s .. 100 GB/s in decade steps
BANDWIDTHS = tuple(10.0 ** e for e in range(6, 12))
CLOUD_SCALE = 0.5          # cloud chips price compute at half the cost
ALGORITHM = "pagerank"
RESIDENCY = ("both", "onprem", "cloud")


def _pools(link_bandwidth: float) -> PL.PoolSet:
    return PL.PoolSet([
        PL.DevicePool("onprem", link_bandwidth=link_bandwidth),
        PL.DevicePool("cloud", link_bandwidth=link_bandwidth,
                      compute_scale=CLOUD_SCALE),
    ])


def _placement(coo, residency, link_bandwidth):
    """Plan one query on a fresh two-pool service; no execution."""
    svc = GraphAnalyticsService(pools=_pools(link_bandwidth))
    svc.add_graph("g", coo,
                  pools=None if residency == "both" else [residency])
    plan = svc.context("g").plan(GraphQuery(ALGORITHM))
    return {
        "pool": plan.pool,
        "engine": plan.engine,
        "variant": plan.variant,
        "est_s": plan.est_s,
        "transfer_s": plan.transfer_s,
    }


def _measured_parity(coo, out):
    """Execute the same query pinned-by-residency to each pool and
    check the bytes agree — the contract the sweep's estimates assume.
    Also returns the transfer the ledger charges when the planner ships
    the snapshot to the non-resident faster pool."""
    q = GraphQuery(ALGORITHM)
    values, walls = {}, {}
    for home in ("onprem", "cloud"):
        svc = GraphAnalyticsService(pools=_pools(1e12), cache_size=0)
        svc.add_graph("g", coo, pools=[home])
        # huge bandwidth: placement goes wherever compute is cheapest,
        # but *execution* happens through the home pool's twin too —
        # force it by planning, then reading the chosen pool
        t, r = time_fn(lambda: np.asarray(svc.call("g", q).value))
        values[home] = r.tobytes()
        walls[home] = t
        led = svc.metrics()["pools"]
        out(csv_row(f"pool_crossover/exec_home_{home}", t,
                    f"transfers={sum(v['transfers'] for v in led.values())}"))
    assert values["onprem"] == values["cloud"], \
        "federation contract violated: results differ across pools"
    return walls


def run(out=print):
    result = {"algorithm": ALGORITHM, "cloud_compute_scale": CLOUD_SCALE,
              "bandwidth_sweep": list(BANDWIDTHS), "sweep": [],
              "crossover_bandwidth": {}, "measured": {}}
    for n_vertices in SIZES:
        src, dst = S.user_follow_graph(n_vertices, 4.0, seed=1)
        coo = G.build_coo(src, dst, n_vertices)
        bytes_coo = P.GraphStats.of(coo).bytes_coo
        for residency in RESIDENCY:
            placements = []
            for bw in BANDWIDTHS:
                p = _placement(coo, residency, bw)
                placements.append({"link_bandwidth": bw, **p})
            result["sweep"].append({
                "n_vertices": n_vertices,
                "bytes_coo": bytes_coo,
                "residency": residency,
                "placements": placements,
            })
            # the headline: resident on-prem, compute-advantaged cloud —
            # the bandwidth where placement leaves the data's pool
            if residency == "onprem":
                cross = next((pl["link_bandwidth"] for pl in placements
                              if pl["pool"] == "cloud"), None)
                result["crossover_bandwidth"][str(n_vertices)] = cross
                out(csv_row(f"pool_crossover/v{n_vertices}_crossover_bw",
                            0.0, f"flips_to_cloud_at_Bps={cross}"))
    walls = _measured_parity(
        G.build_coo(*S.user_follow_graph(SIZES[0], 4.0, seed=1), SIZES[0]),
        out)
    result["measured"] = {"n_vertices": SIZES[0], "wall_s": walls,
                          "parity": "byte-identical"}
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pool_crossover.json",
                    help="JSON output path")
    args = ap.parse_args(argv)
    result = run()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
