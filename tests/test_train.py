"""Training-substrate tests: optimizer behaviour, microbatch equivalence,
gradient compression, checkpoint/restore, data pipeline determinism.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig, lr_at
from repro.train.train_step import (
    make_train_step, init_train_state)
from repro.train.compression import CompressionConfig, compress_grads, \
    init_error_state
from repro.train.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer)
from repro.data.tokens import SyntheticTokens, shard_for_host, Prefetcher


def tiny_model():
    cfg = reduced_config(get_config("smollm_360m"))
    return build_model(cfg), cfg


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 99]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
    assert lrs[2] == pytest.approx(1e-3, rel=0.05)


def test_training_reduces_loss():
    """A few hundred steps on the synthetic corpus must show learning."""
    model, cfg = tiny_model()
    data = SyntheticTokens(cfg.vocab_size, 16, 8, seed=0)
    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=300)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    losses = []
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.7, losses[-5:]


def test_microbatch_equivalence():
    """mb=1 and mb=4 must give (nearly) identical updates."""
    model, cfg = tiny_model()
    data = SyntheticTokens(cfg.vocab_size, 16, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    opt = AdamWConfig(peak_lr=1e-3)
    s1 = init_train_state(model, jax.random.PRNGKey(0))
    s4 = init_train_state(model, jax.random.PRNGKey(0))
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(s1, batch)
    s4, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(s4, batch)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s4.params)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_convergence(kind):
    """Compressed training converges on the synthetic task (error
    feedback keeps the bias bounded)."""
    model, cfg = tiny_model()
    comp = CompressionConfig(kind=kind, topk_fraction=0.25)
    data = SyntheticTokens(cfg.vocab_size, 16, 8, seed=2)
    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=300),
        compression=comp))
    state = init_train_state(model, jax.random.PRNGKey(0), compression=comp)
    losses = []
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.75


def test_int8_compression_error_feedback_unbiased():
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 64)), jnp.float32)}
    err = init_error_state(grads)
    comp = CompressionConfig(kind="int8")
    acc = jnp.zeros_like(grads["w"])
    for _ in range(50):
        wire, err, _ = compress_grads(grads, err, comp)
        acc = acc + wire["w"]
    # long-run average of wire grads == true grad (error feedback)
    np.testing.assert_allclose(np.asarray(acc / 50),
                               np.asarray(grads["w"]), atol=2e-3)


def test_checkpoint_roundtrip(tmp_path):
    model, cfg = tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(3))
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 7, state)
    assert latest_step(root) == 7
    restored, step = restore_checkpoint(root, state)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state.params, restored.params)


def test_checkpoint_gc_keeps_latest(tmp_path):
    model, _ = tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    root = str(tmp_path / "ckpt")
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(root, s, state, keep=2)
    from repro.train.checkpoint import list_steps
    assert list_steps(root) == [4, 5]


def test_async_checkpointer(tmp_path):
    model, _ = tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    root = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(root)
    ck.submit(3, state)
    ck.wait()
    assert latest_step(root) == 3


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written unsharded restores under explicit device
    placement (the mesh-reshape path)."""
    model, _ = tiny_model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 1, state)
    dev = jax.devices()[0]
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), state)
    restored, _ = restore_checkpoint(root, state, shardings=shardings)
    leaf = jax.tree_util.tree_leaves(restored.params)[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)


def test_data_pipeline_determinism_and_sharding():
    d1 = SyntheticTokens(100, 8, 4, seed=5)
    d2 = SyntheticTokens(100, 8, 4, seed=5)
    b1, b2 = d1.batch_at(10), d2.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    s0 = shard_for_host(b1, 2, 0)
    s1 = shard_for_host(b1, 2, 1)
    assert s0["tokens"].shape[0] == 2
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])


def test_prefetcher():
    data = SyntheticTokens(50, 4, 2, seed=0)
    it = iter(data)
    pf = Prefetcher(it, depth=2)
    batches = [next(pf) for _ in range(3)]
    assert len(batches) == 3
    pf.close()
