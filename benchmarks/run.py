# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks.common.csv_row).
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig5_engine_crossover, fig6_multi_account,
                            fig7_connected_users, table1_maxadjacentnodes,
                            algo_suite, kernels_bench, roofline_report)
    print("name,us_per_call,derived")
    ok = True
    for mod in (fig5_engine_crossover, fig6_multi_account,
                fig7_connected_users, table1_maxadjacentnodes,
                algo_suite, kernels_bench, roofline_report):
        try:
            mod.run(out=print)
        except Exception:   # noqa: BLE001 — keep the harness going
            ok = False
            print(f"{mod.__name__},0.0,ERROR")
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
