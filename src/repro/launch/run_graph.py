"""Graph-analytics job launcher — the platform CLI the paper's interface
layer would call.

    PYTHONPATH=src python -m repro.launch.run_graph \
        --job cc --vertices 20000 --count-only
    PYTHONPATH=src python -m repro.launch.run_graph \
        --job two-hop --vertices 5000 --engine distributed
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import graph as G
from repro.core.query import GraphQuery, GraphPlatform
from repro.data import synthetic as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--job", choices=["pagerank", "cc", "two-hop", "stats"],
                    default="cc")
    ap.add_argument("--vertices", type=int, default=20_000)
    ap.add_argument("--mean-degree", type=float, default=5.0)
    ap.add_argument("--count-only", action="store_true")
    ap.add_argument("--engine", choices=["auto", "local", "distributed"],
                    default="auto")
    ap.add_argument("--n-data", type=int, default=1,
                    help="edge shards for the distributed engine")
    args = ap.parse_args()

    n = args.vertices
    if args.job == "two-hop":
        u, i = S.safety_bipartite_graph(n, max(n // 4, 10), seed=0)
        coo = G.build_coo(u, i, int(max(u.max(), i.max())) + 1)
        query = GraphQuery.two_hop(n_users=n, count_only=args.count_only)
    else:
        src, dst = S.user_follow_graph(n, args.mean_degree, seed=0)
        sym = args.job == "cc"
        coo = G.build_coo(src, dst, n, symmetrize=sym)
        query = {"pagerank": GraphQuery.pagerank(),
                 "cc": GraphQuery.connected_components(
                     count_only=args.count_only),
                 "stats": GraphQuery.degree_stats()}[args.job]

    platform = GraphPlatform(
        coo, n_data=args.n_data,
        force_engine=None if args.engine == "auto" else args.engine)
    plan = platform.plan(query)
    print(f"[plan] engine={plan.engine} | {plan.reason}")
    t0 = time.time()
    r = platform.query(query)
    dt = time.time() - t0
    val = r.value
    if hasattr(val, "shape") and getattr(val, "size", 2) > 8:
        val = f"array{tuple(np.asarray(val).shape)}"
    print(f"[done] engine={r.engine} iters={r.iterations} "
          f"wall={dt:.3f}s result={val}")


if __name__ == "__main__":
    main()
