"""Train a smollm-family model on the synthetic corpus for a few hundred
steps with checkpointing — the downstream-ML consumer of the platform.

Reduced config by default (CPU-friendly); pass --full for the real
360M-parameter config on accelerator hosts.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] if len(sys.argv) > 1 else [])

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

# Reuse the production driver — examples should exercise the same path
# operators run.
sys.argv = [
    "train", "--arch", "smollm-360m", "--steps", str(args.steps),
    "--batch", "8", "--seq", "64", "--lr", "3e-3",
    "--ckpt-dir", "/tmp/train_lm_example",
] + ([] if args.full else ["--reduced"])

from repro.launch.train import main  # noqa: E402
main()
