"""Shared test fixtures.

The planner auto-loads the checked-in reference ``CalibrationProfile``
(``repro/core/calibration/reference_profile.json``) at import — the
production default.  The unit suites, however, pin their expectations
(variant crossovers, tier thresholds, admission estimates) to the
*analytic* constants, so every test runs with calibration reset to the
analytic defaults; the reference profile's own coverage lives in the
dedicated roundtrip tests (``tests/test_pools.py``), which opt back in
explicitly via ``planner.load_reference_calibration()``.
"""
import pytest

from repro.core import planner as P


@pytest.fixture(autouse=True)
def _analytic_calibration():
    """Pin the analytic planner constants around every test."""
    P.set_calibration(None)
    yield
    P.set_calibration(None)
