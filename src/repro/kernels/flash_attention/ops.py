"""Public wrapper: GQA folding, padding, CPU interpret routing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_k: int = 512):
    """q: [B, Hq, S, D], k/v: [B, Hkv, S, D] -> [B, Hq, S, D].

    GQA: each kv head serves Hq/Hkv query heads; we fold the group into
    the leading grid dimension so each k/v tile is loaded once per group.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    # [B, Hkv, group, S, D] -> [(B Hkv group), S, D]
    qg = q.reshape(b, hkv, group, s, d).reshape(b * hkv * group, s, d)
    kg = jnp.repeat(k.reshape(b * hkv, s, d), group, axis=0)
    vg = jnp.repeat(v.reshape(b * hkv, s, d), group, axis=0)

    out = flash_attention_pallas(
        qg, kg, vg, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_on_cpu())
    return out.reshape(b, hkv, group, s, d).reshape(b, hq, s, d)
