"""Algorithm-suite sweep: per-workload local-vs-distributed crossover in
the Fig. 5 style, across the full vertex-program library.

For every algorithm behind the unified query layer this measures, at
each graph scale:

  * LocalEngine wall time (the Neo4j-analogue interactive path);
  * DistributedEngine wall time (edge-partitioned BSP, n_data=4 — on a
    one-device box this exposes the partitioning/launch overhead whose
    amortization is exactly the Fig. 5 story);
  * the count-only fast-path time where the algorithm has one (the
    paper's '<2 s count vs ~10 min table' pattern);
  * the planner's projected crossover scale for a 256-chip mesh — each
    algorithm crosses at a different V because its iteration count,
    state bytes and message volume differ (triangle counting's bitset
    state crosses earliest, degree-like scans latest).

Results double as calibration input for the planner constants.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import time_fn, csv_row
from repro.core import graph as G
from repro.core import planner as P
from repro.core.engines import LocalEngine, DistributedEngine
from repro.core.query import GraphQuery
from repro.data import synthetic as S


# (name, engine-method runner, count-only runner or None, needs symmetric)
_SUITE = [
    ("bfs", lambda e: e.bfs([0]).value,
     lambda e: e.reachable_count([0]).value, False),
    ("sssp", lambda e: e.sssp(0).value, None, False),
    ("pagerank", lambda e: e.pagerank(max_iters=20).value, None, False),
    ("connected_components", lambda e: e.connected_components().value,
     lambda e: e.num_components().value, True),
    ("label_propagation", lambda e: e.label_propagation(max_iters=15).value,
     lambda e: e.num_communities(max_iters=15).value, True),
    ("triangle_count", lambda e: e.triangle_count().value, None, True),
    ("k_core", lambda e: e.k_core(3).value,
     lambda e: e.k_core_size(3).value, True),
]


def _build(n_vertices: int, symmetric: bool) -> G.GraphCOO:
    src, dst = S.user_follow_graph(n_vertices, 4.0, seed=1)
    keep = src != dst
    return G.build_coo(src[keep], dst[keep], n_vertices,
                       symmetrize=symmetric)


def run(out=print):
    rows = []
    for n_vertices in [2_000, 20_000]:
        graphs = {sym: _build(n_vertices, sym) for sym in (False, True)}
        locals_ = {sym: LocalEngine(g) for sym, g in graphs.items()}
        dists = {sym: DistributedEngine(g, n_data=4)
                 for sym, g in graphs.items()}
        for name, table_fn, count_fn, sym in _SUITE:
            if name == "triangle_count" and n_vertices > 5_000:
                # O(V^2/32) bitset state: interactive-scale only on one
                # device; the planner routes larger V distributed.
                continue
            t_local, r_local = time_fn(lambda: table_fn(locals_[sym]))
            t_dist, r_dist = time_fn(lambda: table_fn(dists[sym]))
            a, b = np.asarray(r_local), np.asarray(r_dist)
            assert a.shape == b.shape, name
            if np.issubdtype(a.dtype, np.floating):
                # summation order differs across edge shards
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7,
                                           err_msg=name)
            else:
                assert (a == b).all(), name
            out(csv_row(f"algo_suite/{name}_local_v{n_vertices}", t_local,
                        f"bsp_ratio={t_dist / t_local:.2f}x"))
            if count_fn is not None:
                t_count, _ = time_fn(lambda: count_fn(locals_[sym]))
                out(csv_row(f"algo_suite/{name}_count_v{n_vertices}",
                            t_count,
                            f"count_vs_table={t_local / max(t_count, 1e-9):.2f}x"))
            rows.append((name, n_vertices, t_local, t_dist))

    # planner-projected crossover per algorithm on the production mesh —
    # the per-workload Fig. 5 family
    for name, _, _, _ in _SUITE:
        cross = None
        for v in [10**4, 10**5, 10**6, 10**7, 10**8, 10**9, 10**10]:
            stats = P.GraphStats(v, v * 5, v * 5 * 12)
            plan = P.choose_engine(stats, P.spec_for(name, stats), 256)
            if plan.engine == "distributed":
                cross = v
                break
        out(csv_row(f"algo_suite/crossover_{name}", 0.0,
                    f"crossover_at_V={cross}"))
    return rows


if __name__ == "__main__":
    run()
