"""Three-term roofline from dry-run AOT artifacts (no real hardware).

    compute_s    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory_s     = HLO_bytes_per_chip / HBM_BW
    collective_s = link_bytes_per_chip / LINK_BW

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``jax.stages.Compiled.cost_analysis()`` reports the *partitioned* (i.e.
per-device) module's flops/bytes; verified empirically in
tests/test_roofline.py with a sharded matmul of known size.  Collective
bytes come from parsing the post-SPMD HLO (utils/hlo.py) with ring
factors; we assume each mesh axis maps to its own ICI ring (v5e 2-D torus
has independent link pairs per dimension), so a chip's collective time is
total ring-weighted bytes over one link's bandwidth — conservative for
overlapping axes, exact for single-axis collectives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.utils import hlo as hlo_utils

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_link_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_chip: float = 0.0       # 6ND/chips (useful compute)
    useful_ratio: float = 0.0               # model_flops / hlo_flops
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_raw: dict = dataclasses.field(default_factory=dict)
    memory_per_device_gb: float = 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the binding roofline term: how close
        the *useful* work runs to the hardware ceiling if perfectly
        overlapped.  This is the score we hillclimb."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops_per_chip / PEAK_FLOPS_BF16
        return useful_s / self.bound_s if useful_s > 0 else 0.0

    def row(self) -> dict:
        return {
            "name": self.name, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "mem_gb": self.memory_per_device_gb,
        }


def analyze(
    name: str,
    cost: dict,
    hlo_text: str,
    chips: int,
    model_flops_global: float = 0.0,
    default_group: Optional[int] = None,
    memory_bytes: float = 0.0,
) -> RooflineReport:
    """cost = compiled.cost_analysis(); hlo_text = compiled.as_text()."""
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = hlo_utils.parse_collectives(hlo_text, default_group or chips)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = stats.total_link_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global / max(chips, 1)
    return RooflineReport(
        name=name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=nbytes,
        coll_link_bytes_per_chip=stats.total_link_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops_per_chip=mf,
        useful_ratio=(mf / flops) if flops > 0 else 0.0,
        coll_counts=stats.counts, coll_raw=stats.raw_bytes,
        memory_per_device_gb=memory_bytes / 1e9,
    )


def lm_model_flops(n_params: int, tokens: int, training: bool = True,
                   active_params: Optional[int] = None) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for inference forward.
    For MoE pass active_params (routed-active parameter count)."""
    n = active_params if active_params is not None else n_params
    mult = 6.0 if training else 2.0
    return mult * n * tokens


def fmt_seconds(s: float) -> str:
    if s <= 0:
        return "0"
    exp = int(math.floor(math.log10(s)))
    if exp < -6:
        return f"{s*1e9:.2f}ns"
    if exp < -3:
        return f"{s*1e6:.2f}us"
    if exp < 0:
        return f"{s*1e3:.2f}ms"
    return f"{s:.3f}s"
