"""Mistral-Large-2407 123B [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, dense llama-arch.
The largest dense arch in the pool — FSDP + TP required to fit v5e.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1000000.0,
    mlp_act="silu",
    tie_embeddings=False,
    fsdp=True,
)
