"""GraphQuery — the unified interface layer (paper Section III-A).

The paper's stack puts "a unified user interface ... and code templates"
above the engines so users never pick Spark-vs-Neo4j by hand.  This is
that layer: a small declarative query object + ``GraphPlatform`` which
owns both engines and routes through the cost-based planner.

    platform = GraphPlatform(coo, mesh=mesh)
    r = platform.query(GraphQuery.connected_components(count_only=True))
    r.value, r.engine, r.meta['plan']
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import graph as G
from repro.core import planner as P
from repro.core.engines import LocalEngine, DistributedEngine, QueryResult


@dataclasses.dataclass(frozen=True)
class GraphQuery:
    """One declarative query; ``algorithm`` is any name ``planner.spec_for``
    knows: pagerank | connected_components | two_hop | degree_stats |
    bfs | sssp | label_propagation | triangle_count | k_core.

    ``count_only=True`` selects the engine's count-only fast path (the
    paper's '<2 s count vs ~10 min table' query class) where one exists.
    """

    algorithm: str
    count_only: bool = False
    params: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def pagerank(cls, alpha=0.85, tol=1e-8, max_iters=100):
        return cls("pagerank", False,
                   {"alpha": alpha, "tol": tol, "max_iters": max_iters})

    @classmethod
    def connected_components(cls, count_only=False, max_iters=200):
        return cls("connected_components", count_only, {"max_iters": max_iters})

    @classmethod
    def two_hop(cls, n_users: int, count_only=False, dedup=True):
        return cls("two_hop", count_only, {"n_users": n_users, "dedup": dedup})

    @classmethod
    def degree_stats(cls):
        return cls("degree_stats", True, {})

    @classmethod
    def bfs(cls, sources, count_only=False, max_iters=None):
        """Hop distances from a source set; ``count_only`` returns the
        size of the reachable set instead of the distance table.
        ``max_iters=None`` guarantees convergence."""
        return cls("bfs", count_only,
                   {"sources": tuple(sources), "max_iters": max_iters})

    @classmethod
    def sssp(cls, source: int, max_iters=None):
        """Single-source weighted shortest paths (non-negative weights)."""
        return cls("sssp", False, {"source": source, "max_iters": max_iters})

    @classmethod
    def label_propagation(cls, count_only=False, max_iters=30,
                          n_channels=64):
        """Community detection; ``count_only`` returns ``num_communities``."""
        return cls("label_propagation", count_only,
                   {"max_iters": max_iters, "n_channels": n_channels})

    @classmethod
    def triangle_count(cls):
        """Global triangle count (inherently count-only)."""
        return cls("triangle_count", True, {})

    @classmethod
    def k_core(cls, k: int, count_only=False, max_iters=None):
        """k-core membership; ``count_only`` returns the core size."""
        return cls("k_core", count_only, {"k": k, "max_iters": max_iters})


class GraphPlatform:
    """Owns both engines; routes each query through the planner."""

    def __init__(self, coo: G.GraphCOO, mesh=None, n_data: int = 1,
                 n_model: int = 1, local_max_degree: int = 128,
                 force_engine: Optional[str] = None):
        self.coo = coo
        self.mesh = mesh
        self.stats = P.GraphStats.of(coo)
        self.force_engine = force_engine
        self._local: Optional[LocalEngine] = None
        self._dist: Optional[DistributedEngine] = None
        self._local_max_degree = local_max_degree
        self._n_data, self._n_model = n_data, n_model
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self.n_chips = 1
            for s in mesh.devices.shape:
                self.n_chips *= s
        else:
            self.n_chips = max(n_data * n_model, 1)

    # lazy engine construction: building ELL/partitions is ETL work we
    # only pay when the planner actually routes there.
    @property
    def local(self) -> LocalEngine:
        if self._local is None:
            self._local = LocalEngine(self.coo, self._local_max_degree)
        return self._local

    @property
    def distributed(self) -> DistributedEngine:
        if self._dist is None:
            self._dist = DistributedEngine(self.coo, mesh=self.mesh,
                                           n_data=self._n_data,
                                           n_model=self._n_model)
        return self._dist

    def plan(self, q: GraphQuery) -> P.Plan:
        spec = P.spec_for(q.algorithm, self.stats, count_only=q.count_only,
                          n_channels=q.params.get("n_channels", 64))
        plan = P.choose_engine(self.stats, spec, self.n_chips)
        if self.force_engine:
            plan = dataclasses.replace(plan, engine=self.force_engine,
                                       reason=f"forced: {self.force_engine}")
        return plan

    def query(self, q: GraphQuery) -> QueryResult:
        plan = self.plan(q)
        eng = self.local if plan.engine == "local" else self.distributed
        if q.algorithm == "pagerank":
            r = eng.pagerank(**q.params)
        elif q.algorithm == "connected_components":
            r = (eng.num_components(**q.params) if q.count_only
                 else eng.connected_components(**q.params))
        elif q.algorithm == "two_hop":
            if q.count_only:
                r = eng.two_hop_count()
            else:
                r = eng.two_hop_pairs(q.params["n_users"],
                                      dedup=q.params.get("dedup", True))
        elif q.algorithm == "degree_stats":
            r = eng.degree_stats()
        elif q.algorithm == "bfs":
            sources = list(q.params["sources"])
            max_iters = q.params.get("max_iters")
            r = (eng.reachable_count(sources, max_iters=max_iters)
                 if q.count_only else eng.bfs(sources, max_iters=max_iters))
        elif q.algorithm == "sssp":
            r = eng.sssp(q.params["source"],
                         max_iters=q.params.get("max_iters"))
        elif q.algorithm == "label_propagation":
            kw = {"max_iters": q.params.get("max_iters", 30),
                  "n_channels": q.params.get("n_channels", 64)}
            r = (eng.num_communities(**kw) if q.count_only
                 else eng.label_propagation(**kw))
        elif q.algorithm == "triangle_count":
            r = eng.triangle_count()
        elif q.algorithm == "k_core":
            kw = {"max_iters": q.params.get("max_iters")}
            r = (eng.k_core_size(q.params["k"], **kw) if q.count_only
                 else eng.k_core(q.params["k"], **kw))
        else:
            raise ValueError(q.algorithm)
        r.meta["plan"] = plan
        return r
