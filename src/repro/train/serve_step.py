"""Serving step factories: prefill and single-token decode.

Both are pure functions for jit/AOT:  decode is
(params, tokens, cache, index) -> (logits, cache) — the function the
``decode_32k`` / ``long_500k`` dry-run cells lower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, batch, cache_len: int):
        return model.prefill(params, batch, cache_len=cache_len)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, tokens, cache, index):
        return model.decode_step(params, tokens, cache, index)
    return decode_step


def greedy_generate(model, params, batch, steps: int, cache_len: int):
    """Greedy decoding loop (host loop; each step jit-compiled once).
    Returns generated token array [B, steps]."""
    logits, cache = model.prefill(params, batch, cache_len=cache_len)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    start = batch["tokens"].shape[1]
    if getattr(model.cfg, "prefix_len", 0):
        start += model.cfg.prefix_len
    out = [tok]
    step_fn = jax.jit(model.decode_step)
    for i in range(steps - 1):
        logits, cache = step_fn(params, tok, cache, jnp.int32(start + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
