"""Pure-jnp oracle for the ELL gather+combine kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_IDENTITY = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


@partial(jax.jit, static_argnames=("op",))
def ell_combine_ref(nbr, mask, w, x, op: str = "sum"):
    """y[v] = reduce_k{ op }( mask[v,k] ? f(w[v,k], x[nbr[v,k]]) : id ).

    f = multiply for 'sum' (weighted SpMV); f = identity-on-x for
    'min'/'max' (label propagation — weights ignored).
    nbr: [V, K] int32 (invalid slots may hold any index; mask guards).
    x:   [Vx]  gather source (Vx >= max index + 1).
    """
    vals = x[jnp.clip(nbr, 0, x.shape[0] - 1)]            # [V, K]
    ident = jnp.asarray(_IDENTITY[op], dtype=vals.dtype)
    if op == "sum":
        contrib = jnp.where(mask, vals * w, 0.0)
        return jnp.sum(contrib, axis=1)
    contrib = jnp.where(mask, vals, ident)
    red = jnp.min if op == "min" else jnp.max
    return red(contrib, axis=1)
