"""Config system: architectures, input shapes, parallelism knobs.

``ModelConfig`` is a frozen dataclass (hashable -> usable as a static jit
argument).  One file per assigned architecture lives next to this module;
``get_config(name)`` resolves them.  ``reduced_config`` shrinks any arch
to a CPU-smoke-testable size while preserving every structural feature
(family, GQA ratio, MoE routing, local/global pattern, ...).
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    # --- attention variants -------------------------------------------
    rope_theta: float = 10000.0
    window: int = 0            # sliding-window size for local layers
    local_global_period: int = 0   # gemma2: every Nth layer is global
    global_layers: tuple = ()      # hymba: explicit global layer ids
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_norms: bool = False       # gemma2 post-attn/post-mlp norms
    mlp_act: str = "silu"          # silu | gelu
    tie_embeddings: bool = True
    # --- MoE -----------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_wire_int8: bool = False    # quantize token->expert dispatch wire
    # --- SSM / hybrid ----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- enc-dec (whisper) ----------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0       # precomputed frame embeddings (stub frontend)
    # --- vlm (paligemma) --------------------------------------------------
    prefix_len: int = 0        # precomputed patch embeddings (stub frontend)
    # --- execution -------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "chunked"   # ref | chunked | flash
    attn_chunk: int = 1024
    # --- parallelism ------------------------------------------------------
    fsdp: bool = False           # shard params+opt over data axis
    sub_quadratic: bool = False  # eligible for long_500k

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm_head table rows padded to 256 (Megatron-style)
        so the vocab dim shards evenly on any production mesh; padded
        logits are masked to -inf at unembed."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        """Analytic parameter count (drives 6ND roofline numbers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe":
            mlp = 3 * d * f * self.n_experts + d * self.n_experts  # + router
        elif self.family == "ssm":
            mlp = 0
        else:
            mlp = 3 * d * f
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            # one mLSTM + one sLSTM block per pair (see models/xlstm.py)
            di = self.ssm_expand * d
            mlstm = 2 * d * di + 3 * di * di + di * 2 * self.n_heads \
                + di * d
            slstm = 4 * d * di + 4 * di + di * d
            per_layer = (mlstm + slstm + 2 * d) / 2
        if self.family == "hybrid":
            di = self.ssm_expand * d
            ssm = 2 * d * di + di * d + di * self.ssm_state * 2
            per_layer = attn + 3 * d * f + ssm + 2 * d
        total = per_layer * self.n_layers + v * d
        if not self.tie_embeddings:
            total += v * d
        if self.family == "encdec":
            enc_layer = 4 * d * d + 3 * d * f + 2 * d
            cross = 4 * d * d + d
            total += enc_layer * self.n_encoder_layers + cross * self.n_layers
        return int(total)

    def active_param_count(self) -> int:
        """Routed-active params (MoE): replaces E experts by top_k."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        return int(full - 3 * d * f * (self.n_experts - self.top_k)
                   * self.n_layers)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCHS = [
    "hymba_1p5b", "mistral_large_123b", "gemma2_2b", "smollm_360m",
    "granite_8b", "olmoe_1b_7b", "dbrx_132b", "xlstm_125m",
    "whisper_large_v3", "paligemma_3b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "hymba-1.5b": "hymba_1p5b", "mistral-large-123b": "mistral_large_123b",
    "gemma2-2b": "gemma2_2b", "smollm-360m": "smollm_360m",
    "granite-8b": "granite_8b", "olmoe-1b-7b": "olmoe_1b_7b",
    "dbrx-132b": "dbrx_132b", "xlstm-125m": "xlstm_125m",
    "whisper-large-v3": "whisper_large_v3", "paligemma-3b": "paligemma_3b",
})


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""


def reduced_config(cfg: ModelConfig, n_layers: int = 2, d_model: int = 64,
                   n_heads: int = 4, vocab: int = 128) -> ModelConfig:
    """Shrink to smoke-test size, preserving structure."""
    kv = max(1, n_heads * cfg.n_kv_heads // max(cfg.n_heads, 1))
    updates = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=kv, d_head=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=vocab,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16),
        prefix_len=min(cfg.prefix_len, 8),
        global_layers=tuple(g for g in cfg.global_layers if g < n_layers),
        dtype="float32", remat=False, attn_chunk=16,
    )
    return dataclasses.replace(cfg, **updates)
