"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices before first jax init, smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

from repro.utils import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 two-pod (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return compat.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Development mesh over however many devices exist."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = max(1, min(n_model, n // n_data))
    return compat.make_mesh((n_data, n_model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
