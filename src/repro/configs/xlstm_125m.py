"""xLSTM-125M [arXiv:2405.04517]: sLSTM + mLSTM blocks.

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0 -> blocks are pure token
mixers with in/out projections (no separate FFN).  Even layers mLSTM
(matrix memory, chunk-parallelizable), odd layers sLSTM (scalar memory,
strictly recurrent).  Recurrent state is O(1) per token -> long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_head=192,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    tie_embeddings=True,
    sub_quadratic=True,
)
