"""Service-runtime sweep: drain throughput and interactive-tier latency
vs worker count.

The concurrent runtime's pitch is that a worker pool over the
per-(engine, tier) queues overlaps the two engines while interactive
tickets preempt batch at dequeue time.  This sweep measures that claim
on a seeded mixed-tier workload over a two-snapshot catalog (one graph
pinned to each engine, so the pool has two independent execution
streams):

  * end-to-end ``drain`` wall time and throughput (tickets/s) at each
    worker count (1 = the serial reference schedule);
  * interactive- and batch-tier p50/p99 submit→resolution latency from
    ``service.metrics()`` — the numbers the "interactive beats batch"
    test asserts qualitatively;
  * fusion width, as a sanity check that batch coalescing survives
    concurrency.

Results land in ``BENCH_service_runtime.json`` (``--out`` overrides),
starting the perf trajectory for the runtime.  Caching is disabled for
the sweep — a warm result cache would answer repeated queries without
executing anything and turn the measurement into a cache benchmark.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import graph as G
from repro.core import planner as P
from repro.core.query import GraphQuery
from repro.core.service import GraphAnalyticsService
from repro.data import synthetic as S

WORKER_SWEEP = (1, 2, 4, 8)
N_VERTICES = 2_000
N_TICKETS = 120
SEED = 1234


def _build_graphs():
    src, dst = S.user_follow_graph(N_VERTICES, 6.0, seed=7)
    g_local = G.build_coo(src, dst, N_VERTICES)
    src, dst = S.user_follow_graph(N_VERTICES, 4.0, seed=13)
    g_dist = G.build_coo(src, dst, N_VERTICES)
    return g_local, g_dist


def _service(g_local, g_dist, threshold=None, trace_depth=0):
    svc = GraphAnalyticsService(cache_size=0,
                                interactive_threshold_s=threshold,
                                trace_depth=trace_depth)
    svc.add_graph("local_g", g_local, force_engine="local")
    svc.add_graph("dist_g", g_dist, n_data=4, force_engine="distributed")
    return svc


def _workload(n_tickets=N_TICKETS, seed=SEED):
    """Seeded ticket mix: fusable traversals, fixpoints, cheap counts."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_tickets):
        name = ("local_g", "dist_g")[int(rng.integers(0, 2))]
        kind = int(rng.integers(0, 5))
        if kind == 0:
            q = GraphQuery.bfs([int(rng.integers(0, N_VERTICES))])
        elif kind == 1:
            q = GraphQuery.sssp(int(rng.integers(0, N_VERTICES)))
        elif kind == 2:
            q = GraphQuery.pagerank(max_iters=int(rng.integers(5, 20)))
        elif kind == 3:
            q = GraphQuery.degree_stats()
        else:
            q = GraphQuery.bfs([int(rng.integers(0, N_VERTICES))],
                               count_only=True)
        out.append((name, q))
    return out


def _median_threshold(svc, workload):
    """Tier split at the workload's median plan estimate, so both tiers
    carry real traffic in every sweep point."""
    ests = [P.plan_cost(svc.context(name).plan(q)) for name, q in workload]
    return float(np.median(ests))


def _sweep_point(g_local, g_dist, threshold, workload, workers,
                 trace_depth=0):
    svc = _service(g_local, g_dist, threshold, trace_depth=trace_depth)
    tickets = [svc.submit(name, q) for name, q in workload]
    t0 = time.perf_counter()
    svc.drain(workers=workers)
    wall = time.perf_counter() - t0
    bad = [t for t in tickets if t.status != "done"]
    assert not bad, f"{len(bad)} tickets not done at workers={workers}"
    m = svc.metrics()
    lat = m["tier_latency_s"]
    return {
        "workers": workers,
        "wall_s": wall,
        "throughput_qps": len(tickets) / wall,
        "interactive": {"count": lat["interactive"]["count"],
                        "p50_s": lat["interactive"]["p50_s"],
                        "p99_s": lat["interactive"]["p99_s"]},
        "batch": {"count": lat["batch"]["count"],
                  "p50_s": lat["batch"]["p50_s"],
                  "p99_s": lat["batch"]["p99_s"]},
        "fusion": {"batches": m["fusion"]["batches"],
                   "tickets": m["fusion"]["tickets"],
                   "mean_width": m["fusion"]["mean_width"]},
    }


def _trace_overhead(g_local, g_dist, threshold, workload, workers=1,
                    repeats=5):
    """Tracing-overhead point: the same drain with the tracer off and
    with every ticket traced + superstep-profiled.  The observability
    contract is that the on/off delta stays under 5% — spans are a
    handful of dict writes per ticket against pregel executions that
    run for milliseconds.  Measured on the serial reference drain
    (``workers=1``): concurrent walls are dominated by thread
    scheduling jitter, which would drown the recording cost this point
    exists to isolate."""
    deltas, offs, ons = [], [], []
    for _ in range(repeats):
        # paired off/on runs back to back: machine-load drift over the
        # sweep cancels inside each pair, and the median pair is robust
        # to a single noisy repeat
        off = _sweep_point(g_local, g_dist, threshold, workload,
                           workers=workers, trace_depth=0)["wall_s"]
        on = _sweep_point(g_local, g_dist, threshold, workload,
                          workers=workers,
                          trace_depth=len(workload))["wall_s"]
        offs.append(off)
        ons.append(on)
        deltas.append((on - off) / off * 100.0)
    return {
        "workers": workers,
        "repeats": repeats,
        "wall_off_s": float(np.median(offs)),
        "wall_on_s": float(np.median(ons)),
        "overhead_pct": float(np.median(deltas)),
    }


def run(out=print):
    g_local, g_dist = _build_graphs()
    workload = _workload()
    threshold = _median_threshold(_service(g_local, g_dist), workload)
    out(f"# {N_TICKETS} tickets, 2 graphs (V={N_VERTICES}), "
        f"tier threshold {threshold:.3g}s")
    # warm pass: compile every pregel program once so the timed points
    # measure scheduling, not tracing (the JIT cache is process-global)
    _sweep_point(g_local, g_dist, threshold, workload, workers=2)
    # the profiled superstep variants have their own jit keys — warm
    # them too, so the traced overhead point measures recording, not
    # compilation
    _sweep_point(g_local, g_dist, threshold, workload, workers=2,
                 trace_depth=len(workload))
    points = []
    for w in WORKER_SWEEP:
        p = _sweep_point(g_local, g_dist, threshold, workload, workers=w)
        points.append(p)
        out(f"workers={w}: {p['wall_s']:.3f}s wall, "
            f"{p['throughput_qps']:.1f} qps, interactive p50 "
            f"{p['interactive']['p50_s']:.4f}s p99 "
            f"{p['interactive']['p99_s']:.4f}s")
    overhead = _trace_overhead(g_local, g_dist, threshold, workload)
    out(f"tracing overhead (workers={overhead['workers']}): "
        f"{overhead['wall_off_s']:.3f}s off vs "
        f"{overhead['wall_on_s']:.3f}s on -> "
        f"{overhead['overhead_pct']:+.2f}%")
    assert overhead["overhead_pct"] < 5.0, \
        f"tracing overhead {overhead['overhead_pct']:.2f}% >= 5%"
    return {
        "benchmark": "service_runtime",
        "workload": {"tickets": N_TICKETS, "seed": SEED,
                     "n_vertices": N_VERTICES,
                     "tier_threshold_s": threshold,
                     "graphs": ["local_g (local)",
                                "dist_g (distributed, n_data=4)"]},
        "sweep": points,
        "trace_overhead": overhead,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_service_runtime.json",
                    help="result JSON path")
    args = ap.parse_args(argv)
    result = run()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
