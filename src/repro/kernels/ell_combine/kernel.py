"""Pallas TPU kernel: ELL gather + monoid combine.

This is the hot loop of both flagship paper workloads — one PageRank
power iteration and one hash-to-min CC round are exactly

    y[v] = reduce_k( op, mask[v,k] ? f(w[v,k], x[nbr[v,k]]) : identity )

over the fixed-width (MaxAdjacentNodes) neighbor matrix.

TPU mapping
-----------
* Grid over row tiles of ``R`` vertices.  Each step loads a ``(R, K)``
  tile of ``nbr``/``mask``/``w`` into VMEM and keeps the *whole* gather
  source ``x`` VMEM-resident (vertex states are O(V) floats; for the
  sharded engine V is the per-shard vertex range, which fits VMEM for
  v_local <= ~1M — the ops wrapper enforces the budget).
* The gather ``x[nbr]`` is a dynamic-gather over the VMEM-resident
  vector — lane-aligned because K is padded to 128 and R to 8 sublanes.
* The reduce is a VPU row-reduction; no MXU involvement (SpMV is
  bandwidth-bound, the roofline term we optimize is HBM streaming of the
  (R, K) tiles, which this layout makes perfectly sequential).

VMEM budget per step: R*K*(4+4+1) bytes for the tile + 4*Vx for x
(+ R*4 out).  Default R=512, K<=1024, Vx<=1M -> ~8.6 MB < 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_IDENTITY = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _ell_kernel(nbr_ref, mask_ref, w_ref, x_ref, y_ref, *, op: str):
    nbr = nbr_ref[...]                       # (R, K) int32
    msk = mask_ref[...]                      # (R, K) bool (stored int8)
    x = x_ref[...]                           # (Vx,) f32 — VMEM resident
    vals = jnp.take(x, jnp.clip(nbr, 0, x.shape[0] - 1), axis=0)
    if op == "sum":
        w = w_ref[...]
        contrib = jnp.where(msk != 0, vals * w, 0.0)
        y_ref[...] = jnp.sum(contrib, axis=1)
    else:
        ident = jnp.asarray(_IDENTITY[op], vals.dtype)
        contrib = jnp.where(msk != 0, vals, ident)
        red = jnp.min if op == "min" else jnp.max
        y_ref[...] = red(contrib, axis=1)


@functools.partial(jax.jit, static_argnames=("op", "block_rows", "interpret"))
def ell_combine_pallas(nbr, mask, w, x, *, op: str = "sum",
                       block_rows: int = 512, interpret: bool = False):
    """Tiled pallas_call. Caller guarantees:
    V % block_rows == 0, K % 128 == 0 (ops.py pads), x fits VMEM."""
    V, K = nbr.shape
    grid = (V // block_rows,)
    return pl.pallas_call(
        functools.partial(_ell_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # nbr tile
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # mask tile
            pl.BlockSpec((block_rows, K), lambda i: (i, 0)),   # w tile
            pl.BlockSpec(x.shape, lambda i: (0,)),             # x resident
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((V,), x.dtype),
        interpret=interpret,
    )(nbr, mask.astype(jnp.int8), w, x)
